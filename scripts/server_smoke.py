#!/usr/bin/env python
"""CI server smoke: the ISSUE 9 acceptance criteria, over a real socket.

Boots `python -m repro.server` (wall-clock engine) as a subprocess, then
asserts, end to end:

  1. N >= 8 concurrent SSE streams complete with well-formed framing
     (accepted -> token* -> finish) over localhost.
  2. Token text is IDENTICAL to a virtual-clock reference engine run fed
     the same prompts at the same arrivals (hard gate).
  3. Wall-clock TTFT/TDS/QoE distributions agree with the reference
     within the CI-generous tolerance gates (serving.tolerance).
  4. GET /metrics parses as Prometheus text and reflects the traffic.
  5. SIGTERM mid-stream drains gracefully: live streams still finish
     cleanly, the process prints "DRAINED done" and exits 0.

Run:  PYTHONPATH=src python scripts/server_smoke.py
(The Makefile `server-smoke` target and the CI server-smoke job wrap
this in a timeout.)
"""
import asyncio
import os
import pathlib
import signal
import subprocess
import sys
import threading
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import QoESpec                                  # noqa: E402
from repro.core.request import ReqState, Request                # noqa: E402
from repro.obs.metrics import parse_prometheus                  # noqa: E402
from repro.serving import (Tolerance, ToleranceSpec,            # noqa: E402
                           compare_requests)
from repro.server import (ServerConfig, astream, build_engine,  # noqa: E402
                          fetch, stream)

N_CONCURRENT = 8
OUT_LEN = 12
PROMPT_LEN = 9
SPEC = QoESpec(ttft=1.0, tds=4.8)
# same CI-generous gates as tests/test_tolerance.py's in-process
# differential: wide enough for shared-runner sleep jitter, tight enough
# to catch a host that cannot keep the smoke-model schedule at all
GATES = ToleranceSpec(
    ttft_mean_diff=Tolerance(abs_tol=0.5),
    ttft_p95_diff=Tolerance(abs_tol=1.0),
    ttft_max_diff=Tolerance(abs_tol=2.0),
    tds_mean_diff=Tolerance(abs_tol=2.0, rel_tol=0.5),
    qoe_mean_diff=Tolerance(abs_tol=0.30),
    qoe_max_diff=Tolerance(abs_tol=0.60),
    qoe_mean_of=Tolerance(abs_tol=0.30),
)


def start_server():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.server", "--port", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
    lines = []
    port_box = {}
    ready = threading.Event()

    def reader():
        for line in proc.stdout:
            print(f"[server] {line.rstrip()}", flush=True)
            lines.append(line)
            if line.startswith("LISTENING"):
                port_box["port"] = int(line.split()[1])
                ready.set()
        ready.set()

    threading.Thread(target=reader, daemon=True).start()
    if not ready.wait(timeout=300) or "port" not in port_box:
        proc.kill()
        raise SystemExit("server never printed LISTENING")
    return proc, port_box["port"], lines


def prompts_for(rids):
    return {rid: np.random.default_rng((7, rid)).integers(
        0, 1 << 14, PROMPT_LEN).tolist() for rid in rids}


def as_request(rid, prompt_len, evs):
    acc = next(d for k, d in evs if k == "accepted")
    toks = [d for k, d in evs if k == "token"]
    r = Request(rid=rid, arrival=float(acc["arrival"]),
                prompt_len=prompt_len, output_len=OUT_LEN, spec=SPEC)
    r.emit_times = [float(d["t"]) for d in toks]
    r.output_tokens = [int(d["token"]) for d in toks]
    r.generated = len(toks)
    r.state = ReqState.FINISHED
    return r


def differential_round(port):
    rids = list(range(N_CONCURRENT))
    prompts = prompts_for(rids)

    async def fan_out():
        return await asyncio.gather(*[
            astream("127.0.0.1", port,
                    {"prompt_tokens": prompts[rid], "max_tokens": OUT_LEN,
                     "rid": rid})
            for rid in rids])

    results = asyncio.run(fan_out())
    cand = []
    for rid, evs in zip(rids, results):
        kinds = [k for k, _ in evs]
        assert kinds[0] == "accepted", kinds
        assert kinds[-1] == "finish", kinds
        assert kinds.count("token") == OUT_LEN, kinds
        cand.append(as_request(rid, PROMPT_LEN, evs))
    print(f"streamed {len(cand)} concurrent SSE responses")

    # virtual-clock reference: identical engine build, identical prompts,
    # the server's actual arrival stamps
    cfg, ref_eng = build_engine(ServerConfig(clock="virtual"))
    ref = [Request(rid=r.rid, arrival=r.arrival, prompt_len=PROMPT_LEN,
                   output_len=OUT_LEN, spec=SPEC,
                   prompt_tokens=np.asarray(prompts[r.rid], np.int32))
           for r in cand]
    ref_eng.run(ref, max_iterations=4000)
    rep = compare_requests(ref, cand, GATES)
    print(rep.summary())
    rep.assert_ok()


def metrics_round(port):
    status, text = fetch("127.0.0.1", port, "/metrics")
    assert status == 200, status
    parsed = parse_prometheus(text)
    n = parsed[("requests_submitted_total", ())]
    assert n >= N_CONCURRENT, n
    assert parsed[("sse_events_flushed_total", ())] > 0
    print(f"/metrics parses: {len(parsed)} samples, "
          f"{int(n)} requests submitted")


def drain_round(proc, port):
    """SIGTERM with live streams: every stream must still finish."""
    results = {}
    barrier = threading.Barrier(N_CONCURRENT + 1)

    def client(i):
        evs = []
        for ev in stream("127.0.0.1", port,
                         {"prompt_len": 6, "max_tokens": 24,
                          "rid": 100 + i}):
            evs.append(ev)
            if ev[0] == "accepted":
                barrier.wait(timeout=60)
        results[i] = evs

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_CONCURRENT)]
    for t in threads:
        t.start()
    barrier.wait(timeout=60)             # all N streams admitted and live
    proc.send_signal(signal.SIGTERM)
    for t in threads:
        t.join(timeout=120)
    for i, evs in sorted(results.items()):
        kinds = [k for k, _ in evs]
        assert kinds[-1] == "finish", (i, kinds)
        assert kinds.count("token") == 24, (i, kinds)
    print(f"drained {len(results)} live streams cleanly after SIGTERM")


def main():
    proc, port, lines = start_server()
    try:
        st, _ = fetch("127.0.0.1", port, "/healthz")
        assert st == 200
        differential_round(port)
        metrics_round(port)
        drain_round(proc, port)
        code = proc.wait(timeout=60)
        assert code == 0, f"server exited {code}"
        assert any("DRAINED done" in ln for ln in lines), lines[-3:]
    finally:
        if proc.poll() is None:
            proc.kill()
    print("OK: server smoke passed (SSE framing, token identity, "
          "tolerance gates, /metrics, graceful drain)")


if __name__ == "__main__":
    main()
