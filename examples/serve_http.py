"""Serve over HTTP/SSE: the wire-protocol frontend, end to end.

Everything before PR 9 drove the serving stack in-process; this example
is the repo's serving loop as an actual *service*. It boots a wall-clock
`ServingServer` (the smoke-model engine paced to its LatencyModel
schedule in real time), talks to it the way any HTTP client would, and
shows the three wire surfaces:

1. `POST /v1/stream` — a prompt goes in as JSON, the response comes back
   as server-sent events mapping the `StreamHandle` lifecycle 1:1:
   `accepted`, then one `token` frame per emission (with the server
   emit time AND the §5 buffer-paced visible time), then `finish` with
   TTFT/TDS/QoE. Passing `"network": "satellite"` (or any scenario from
   `repro.core.NETWORK_SCENARIOS`) routes the visible-time pacing
   through that link model — the same token timeline, experienced
   through a 300 ms pipe.
2. `GET /metrics` — the live MetricsRegistry as Prometheus text.
3. Graceful drain — `shutdown(drain=True)` finishes live streams first;
   SIGTERM does the same for `python -m repro.server`.

The equivalent curl session against a standalone server:

    $ PYTHONPATH=src python -m repro.server --port 8080 &
    # ... wait for "LISTENING 8080" ...
    $ curl -N -X POST http://127.0.0.1:8080/v1/stream \\
           -H 'Content-Type: application/json' \\
           -d '{"prompt_len": 8, "max_tokens": 6}'
    $ curl http://127.0.0.1:8080/metrics | head
    $ kill -TERM %1          # graceful drain, exits after "DRAINED done"

Artifacts (out/): the captured SSE transcript, a Prometheus metrics
snapshot, and the server-side trace as JSONL.

Run:  PYTHONPATH=src python examples/serve_http.py
"""
import json
import pathlib

from repro.server import ServerConfig, ServingServer, collect, fetch

OUT = pathlib.Path(__file__).resolve().parents[1] / "out"


def main():
    OUT.mkdir(exist_ok=True)
    print("=== 1. boot a wall-clock server (smoke engine, real-time "
          "pacing) ===")
    srv = ServingServer(ServerConfig(clock="wall", warmup=True))
    port = srv.start()
    print(f"listening on 127.0.0.1:{port} "
          f"(clock={srv.backend.clock}, warmup done)\n")

    print("=== 2. stream one request over SSE ===")
    events = collect("127.0.0.1", port,
                     {"prompt_len": 8, "max_tokens": 10})
    for kind, data in events:
        print(f"  {kind:<9} {json.dumps(data)}")
    fin = events[-1][1]
    print(f"  -> TTFT {fin['ttft']:.3f}s, QoE {fin['qoe']:.3f}\n")

    print("=== 3. the same stream through a satellite link (§5 buffer + "
          "network model) ===")
    sat = collect("127.0.0.1", port,
                  {"prompt_len": 8, "max_tokens": 10,
                   "network": "satellite"})
    tok0 = next(d for k, d in sat if k == "token")
    print(f"  first token emitted at t={tok0['t']:.3f}s, visible at "
          f"t={tok0['visible']:.3f}s (>=300ms propagation)\n")

    print("=== 4. GET /metrics (Prometheus text) ===")
    _, prom = fetch("127.0.0.1", port, "/metrics")
    for line in prom.splitlines():
        if line.startswith(("requests_", "sse_", "connection_")):
            print(f"  {line}")
    print()

    print("=== 5. graceful drain ===")
    phase = srv.shutdown(drain=True)
    print(f"  drain phase: {phase}")

    (OUT / "serve_http_stream.json").write_text(
        json.dumps([{"event": k, **d} for k, d in events], indent=2) + "\n")
    (OUT / "serve_http_metrics.prom").write_text(prom)
    (OUT / "serve_http_trace.jsonl").write_text(srv.trace.to_jsonl())
    print(f"\nartifacts: {OUT / 'serve_http_stream.json'}, "
          f"{OUT / 'serve_http_metrics.prom'}, "
          f"{OUT / 'serve_http_trace.jsonl'}")


if __name__ == "__main__":
    main()
