"""Training driver (deliverable b): train a Llama-family model on the
synthetic corpus. Default config (~20M params) finishes a few hundred
steps in minutes on this CPU container; --big selects the ~100M config
(appropriately sized for a real accelerator).

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300] [--big]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.training import (
    OptimizerConfig,
    build_train_step,
    init_train_state,
    packed_batches,
    save_checkpoint,
)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--big", action="store_true", help="~100M config")
ap.add_argument("--checkpoint", default="/tmp/repro_small.npz")
args = ap.parse_args()

if args.big:
    # ~100M params: 12L x 512d Llama-style (GQA 8/4, SwiGLU, RoPE)
    cfg = ModelConfig(
        name="llama-100m", kind="dense", num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=4, d_ff=1536, vocab_size=32_000,
    )
else:
    # ~20M: CPU-friendly, same family
    cfg = ModelConfig(
        name="llama-20m", kind="dense", num_layers=6, d_model=320,
        num_heads=8, num_kv_heads=4, d_ff=960, vocab_size=16_000,
    )
model = Model(cfg)
params, opt = init_train_state(model, jax.random.PRNGKey(0))
n = sum(p.size for p in jax.tree.leaves(params))
print(f"{cfg.name}: {n/1e6:.1f}M params, {args.batch}x{args.seq} tokens/step")

ocfg = OptimizerConfig(lr=6e-4, warmup_steps=args.steps // 20,
                       total_steps=args.steps)
step_fn = jax.jit(build_train_step(model, ocfg))
data = packed_batches(cfg.vocab_size, args.batch, args.seq, seed=0)

t0, first_loss = time.time(), None
for step in range(1, args.steps + 1):
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    params, opt, m = step_fn(params, opt, batch)
    loss = float(m["loss"])
    first_loss = first_loss or loss
    if step % 20 == 0 or step == 1:
        tps = args.batch * args.seq * step / (time.time() - t0)
        print(f"step {step:4d}  loss {loss:.4f}  "
              f"gnorm {float(m['grad_norm']):.2f}  tok/s {tps:,.0f}")

save_checkpoint(args.checkpoint, params, opt, step=args.steps)
print(f"\nloss {first_loss:.3f} -> {loss:.3f}; checkpoint: {args.checkpoint}")
