"""Quickstart: the Andes user timeline, through the unified serving API.

The paper defines Quality-of-Experience on the USER's timeline (§4):
first token promptly (TTFT), then tokens at a digestible pace (TDS), with
a client-side buffer (§5) re-smoothing server burstiness. `ServingClient`
is that abstraction as an API:

1. Submit a prompt with a QoE expectation (+ optional SLO contract).
2. Iterate the returned StreamHandle: each TokenEvent carries the server
   emit time AND the buffer-paced time the user actually sees it.
3. Read Eq. 1 QoE / TTFT off the handle when the stream ends.

The same client fronts the discrete-event simulator, this real JAX model
engine, its speculative variant, or a whole multi-replica cluster
(examples/serve_cluster.py).

A second thread (PR 6): the observability layer. Attaching a
`TraceRecorder` + `MetricsObserver` records every lifecycle event and
rolls up TTFT/TDS/QoE metrics WITHOUT changing a single emitted token or
timestamp (the tests pin that bit-for-bit); this script prints one
request's traced token timeline, dumps a metrics snapshot, and writes
the trace (JSONL + Perfetto-loadable Chrome JSON) and metrics
(Prometheus text + JSON) artifacts next to the working directory.

A third thread (PR 7): the scheduling-policy arena. Any policy behind
the `SchedulingPolicy` protocol — the paper's QoE knapsack, FCFS, the
VTC/WSC fairness counters, the burst-preemptive buffer-slack policy —
drives the same backends; step 6 runs a two-policy head-to-head on a
synchronized-burst adversarial trace and scores it with the arena's
fairness/goodput report (the full sweep is `make bench-arena`).

A fourth thread (PR 9): the same stack over an actual wire. `make
serve` boots an HTTP/SSE frontend (`python -m repro.server`) whose
`POST /v1/stream` maps this example's StreamHandle lifecycle 1:1 onto
server-sent events, paced in real time by a `clock="wall"` engine:

    $ PYTHONPATH=src python -m repro.server --port 8080 &
    $ curl -N -X POST http://127.0.0.1:8080/v1/stream \
           -d '{"prompt_len": 8, "max_tokens": 6}'
    $ curl http://127.0.0.1:8080/metrics | head   # live Prometheus text
    $ kill -TERM %1                               # graceful drain

See examples/serve_http.py for the full walkthrough (network-degraded
§5 pacing, metrics, drain; artifacts under out/) and
`serving/tolerance.py` for how wall-clock runs are verified against the
virtual-clock reference used here.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json
import pathlib

import jax
import numpy as np

from repro.api import ServingClient, SLOContract, SubmitOptions
from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, TPU_V5E, make_scheduler
from repro.models import Model
from repro.obs import (MetricsObserver, MetricsRegistry, TraceRecorder,
                       register_backend_gauges)
from repro.serving import ServingEngine

# --- 1. a tiny Llama-family model behind the Andes scheduler ----------------
cfg = get_smoke_config("llama3-8b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
lat = LatencyModel(cfg, TPU_V5E)
engine = ServingEngine(model, params,
                       make_scheduler("andes", kv_capacity=160, lat=lat),
                       lat, num_slots=3, max_seq=64, capacity_tokens=160)

# --- 1b. observability: trace + metrics riding along, zero behavior change --
trace = TraceRecorder()                       # every lifecycle event, typed
registry = MetricsRegistry()
engine.attach_observer(trace)
engine.attach_observer(MetricsObserver(registry))
register_backend_gauges(registry, engine)     # live KV occupancy gauges

# --- 2. one client session; a burst of prompts with QoE expectations --------
client = ServingClient(engine)                # composes with the observers
rng = np.random.default_rng(0)
reading = QoESpec(ttft=1.0, tds=4.8)          # 1 s first token, reading pace
handles = []
for i in range(8):
    prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(8, 24)))
    handles.append(client.submit(
        prompt,
        SubmitOptions(
            spec=reading, max_tokens=16, arrival=i * 0.02,  # bursty arrivals
            # a per-tenant SLO contract: what "served well" means, and how
            # much this tenant's QoE weighs in fleet pricing
            contract=SLOContract(ttft_target=2.0, qoe_floor=0.9, weight=1.0),
        ),
        on_preempt=lambda h, t: print(
            f"   (req {h.rid} preempted at t={t:.2f}s)"),
    ))

# --- 3. the user timeline: server emits vs buffer-paced visibility ----------
print(f"{'req':>4} {'TTFT':>6} {'QoE':>6}  visible at (buffer-paced, s)")
for h in handles:
    shown = [round(ev.visible_time, 2) for ev in h]   # iterating drives
    print(f"{h.rid:>4} {h.ttft():6.2f} {h.qoe():6.2f}  "
          f"{shown[:6]}{'...' if len(shown) > 6 else ''}")

print(f"\navg QoE {client.avg_qoe():.3f} | "
      f"{engine.preemptions} preemptions | "
      f"{engine.total_tokens} tokens generated")

# --- 4. what the trace saw: one request's token timeline --------------------
rid = handles[0].rid
print(f"\ntraced timeline of request {rid}:")
for ev in trace.events:
    if ev.rid == rid and ev.kind not in ("sync", "dispatch"):
        extra = {k: v for k, v in ev.data.items() if k != "scores"}
        print(f"   t={ev.t:7.3f}s  {ev.kind:<12} {extra}")

# --- 5. final metrics snapshot, and the artifacts on disk -------------------
print("\nmetrics snapshot:")
for name in ("requests_finished_total", "tokens_emitted_total",
             "weighted_attainment", "kv_peak_utilization"):
    print(f"   {name:<28} {registry.value(name):g}")
total_preempts = sum(v for _, _, v
                     in registry.get("preemptions_total").samples())
print(f"   preemptions_total            {total_preempts:g}")
ttft = registry.get("ttft_seconds")
print(f"   ttft_seconds                 count {ttft.count()} "
      f"mean {ttft.sum() / max(ttft.count(), 1):.2f}s")

out = pathlib.Path("out")           # gitignored: run artifacts stay out of
out.mkdir(exist_ok=True)            # the repo root / version control
trace.save_jsonl(out / "quickstart_trace.jsonl")
trace.save_chrome_trace(out / "quickstart_trace.perfetto.json")
(out / "quickstart_metrics.prom").write_text(registry.to_prometheus())
(out / "quickstart_metrics.json").write_text(
    json.dumps(registry.to_json(), indent=2) + "\n")
print("\nwrote out/quickstart_trace.jsonl / out/quickstart_trace.perfetto.json "
      "(load in ui.perfetto.dev) and out/quickstart_metrics.{prom,json}")

# --- 6. policy arena head-to-head: Andes vs FCFS on a synchronized burst ----
# Same trace, same simulator, two scheduling policies behind one protocol.
# The burst trace packs half the arrivals into rhythmic spikes — exactly
# where FCFS's head-of-line blocking hurts and the QoE knapsack shines.
# Scored at the arena's paper-scale latency model (OPT-66B on 4xA100) so
# the spikes actually contend; `make bench-arena` runs the full sweep.
from repro.configs import get_config
from repro.core import A100_4X, SchedulerConfig, fairness_report
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_adversarial_workload

ARENA_KV = 12_000
arena_lat = LatencyModel(get_config("opt-66b"), A100_4X)
print("\npolicy arena (burst trace, 150 requests):")
print(f"{'policy':>8} {'avg QoE':>8} {'goodput tok/s':>14} {'Jain':>6}")
for policy in ("fcfs", "andes"):
    sched = make_scheduler(policy, ARENA_KV, arena_lat, SchedulerConfig())
    sim = ServingSimulator(sched, arena_lat,
                           SimConfig(kv_capacity_tokens=ARENA_KV))
    res = sim.run(make_adversarial_workload("burst", 150, 6.0, seed=0))
    rep = fairness_report(res.requests, res.makespan)
    print(f"{policy:>8} {rep['avg_qoe']:8.3f} "
          f"{rep['goodput_tok_s']:14.1f} {rep['jains_index']:6.3f}")
print("full sweep (6 policies x 3 adversarial traces): make bench-arena")
