"""Quickstart: the Andes QoE pipeline in ~60 lines.

1. Define a request's QoE expectation (TTFT + TDS).
2. Serve a small real model with the Andes scheduler under contention.
3. Watch the client-side token buffer pace delivery and compute Eq.1 QoE.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import (
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    TPU_V5E,
    TokenBuffer,
    make_scheduler,
)
from repro.models import Model
from repro.serving import Request, ServingEngine

# --- 1. a tiny Llama-family model ------------------------------------------
cfg = get_smoke_config("llama3-8b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
lat = LatencyModel(cfg, TPU_V5E)

# --- 2. a burst of requests with reading-speed QoE expectations -------------
rng = np.random.default_rng(0)
requests = []
for i in range(8):
    plen = int(rng.integers(8, 24))
    requests.append(Request(
        rid=i,
        arrival=i * 0.02,                      # bursty arrivals
        prompt_len=plen,
        output_len=16,
        spec=QoESpec(ttft=1.0, tds=4.8),       # 1s first token, 4.8 tok/s
        prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
    ))

# --- 3. Andes: QoE-aware preemptive scheduling over limited KV --------------
scheduler = make_scheduler("andes", kv_capacity=160, lat=lat,
                           cfg=SchedulerConfig())
engine = ServingEngine(model, params, scheduler, lat,
                       num_slots=3, max_seq=64, capacity_tokens=160)
done = engine.run(requests)

# --- 4. client-side token buffer + Eq.1 QoE ---------------------------------
print(f"{'req':>4} {'TTFT':>6} {'QoE':>6}  delivery (buffer-paced, s)")
for r in done:
    buf = TokenBuffer(r.spec.tds)
    shown = [round(buf.push(t), 2) for t in r.emit_times]
    print(f"{r.rid:>4} {r.final_ttft():6.2f} {r.final_qoe():6.2f}  "
          f"{shown[:6]}{'...' if len(shown) > 6 else ''}")
print(f"\navg QoE {np.mean([r.final_qoe() for r in done]):.3f} | "
      f"{engine.preemptions} preemptions | "
      f"{engine.total_tokens} tokens generated")
