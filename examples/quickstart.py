"""Quickstart: the Andes user timeline, through the unified serving API.

The paper defines Quality-of-Experience on the USER's timeline (§4):
first token promptly (TTFT), then tokens at a digestible pace (TDS), with
a client-side buffer (§5) re-smoothing server burstiness. `ServingClient`
is that abstraction as an API:

1. Submit a prompt with a QoE expectation (+ optional SLO contract).
2. Iterate the returned StreamHandle: each TokenEvent carries the server
   emit time AND the buffer-paced time the user actually sees it.
3. Read Eq. 1 QoE / TTFT off the handle when the stream ends.

The same client fronts the discrete-event simulator, this real JAX model
engine, its speculative variant, or a whole multi-replica cluster
(examples/serve_cluster.py).

A second thread (PR 6): the observability layer. Attaching a
`TraceRecorder` + `MetricsObserver` records every lifecycle event and
rolls up TTFT/TDS/QoE metrics WITHOUT changing a single emitted token or
timestamp (the tests pin that bit-for-bit); this script prints one
request's traced token timeline, dumps a metrics snapshot, and writes
the trace (JSONL + Perfetto-loadable Chrome JSON) and metrics
(Prometheus text + JSON) artifacts next to the working directory.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json
import pathlib

import jax
import numpy as np

from repro.api import ServingClient, SLOContract, SubmitOptions
from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, TPU_V5E, make_scheduler
from repro.models import Model
from repro.obs import (MetricsObserver, MetricsRegistry, TraceRecorder,
                       register_backend_gauges)
from repro.serving import ServingEngine

# --- 1. a tiny Llama-family model behind the Andes scheduler ----------------
cfg = get_smoke_config("llama3-8b")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
lat = LatencyModel(cfg, TPU_V5E)
engine = ServingEngine(model, params,
                       make_scheduler("andes", kv_capacity=160, lat=lat),
                       lat, num_slots=3, max_seq=64, capacity_tokens=160)

# --- 1b. observability: trace + metrics riding along, zero behavior change --
trace = TraceRecorder()                       # every lifecycle event, typed
registry = MetricsRegistry()
engine.attach_observer(trace)
engine.attach_observer(MetricsObserver(registry))
register_backend_gauges(registry, engine)     # live KV occupancy gauges

# --- 2. one client session; a burst of prompts with QoE expectations --------
client = ServingClient(engine)                # composes with the observers
rng = np.random.default_rng(0)
reading = QoESpec(ttft=1.0, tds=4.8)          # 1 s first token, reading pace
handles = []
for i in range(8):
    prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(8, 24)))
    handles.append(client.submit(
        prompt,
        SubmitOptions(
            spec=reading, max_tokens=16, arrival=i * 0.02,  # bursty arrivals
            # a per-tenant SLO contract: what "served well" means, and how
            # much this tenant's QoE weighs in fleet pricing
            contract=SLOContract(ttft_target=2.0, qoe_floor=0.9, weight=1.0),
        ),
        on_preempt=lambda h, t: print(
            f"   (req {h.rid} preempted at t={t:.2f}s)"),
    ))

# --- 3. the user timeline: server emits vs buffer-paced visibility ----------
print(f"{'req':>4} {'TTFT':>6} {'QoE':>6}  visible at (buffer-paced, s)")
for h in handles:
    shown = [round(ev.visible_time, 2) for ev in h]   # iterating drives
    print(f"{h.rid:>4} {h.ttft():6.2f} {h.qoe():6.2f}  "
          f"{shown[:6]}{'...' if len(shown) > 6 else ''}")

print(f"\navg QoE {client.avg_qoe():.3f} | "
      f"{engine.preemptions} preemptions | "
      f"{engine.total_tokens} tokens generated")

# --- 4. what the trace saw: one request's token timeline --------------------
rid = handles[0].rid
print(f"\ntraced timeline of request {rid}:")
for ev in trace.events:
    if ev.rid == rid and ev.kind not in ("sync", "dispatch"):
        extra = {k: v for k, v in ev.data.items() if k != "scores"}
        print(f"   t={ev.t:7.3f}s  {ev.kind:<12} {extra}")

# --- 5. final metrics snapshot, and the artifacts on disk -------------------
print("\nmetrics snapshot:")
for name in ("requests_finished_total", "tokens_emitted_total",
             "weighted_attainment", "kv_peak_utilization"):
    print(f"   {name:<28} {registry.value(name):g}")
total_preempts = sum(v for _, _, v
                     in registry.get("preemptions_total").samples())
print(f"   preemptions_total            {total_preempts:g}")
ttft = registry.get("ttft_seconds")
print(f"   ttft_seconds                 count {ttft.count()} "
      f"mean {ttft.sum() / max(ttft.count(), 1):.2f}s")

out = pathlib.Path(".")
trace.save_jsonl(out / "quickstart_trace.jsonl")
trace.save_chrome_trace(out / "quickstart_trace.perfetto.json")
(out / "quickstart_metrics.prom").write_text(registry.to_prometheus())
(out / "quickstart_metrics.json").write_text(
    json.dumps(registry.to_json(), indent=2) + "\n")
print("\nwrote quickstart_trace.jsonl / quickstart_trace.perfetto.json "
      "(load in ui.perfetto.dev) and quickstart_metrics.{prom,json}")
