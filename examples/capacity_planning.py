"""Capacity planning with the QoE-aware serving model (beyond-paper
utility): given an arch + hardware + QoE trace, find the max request rate
each scheduler sustains at avg QoE >= 0.9, and the implied cost per 1M
requests — the paper's §1 "reduce cost per request" argument, quantified.

Run:  PYTHONPATH=src python examples/capacity_planning.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import A100_4X, LatencyModel, SchedulerConfig, make_scheduler
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_workload

HOURLY_COST = 4 * 2.5          # $/h for 4xA100
M = 65_000


def capacity(sched_name: str, trace: str) -> float:
    cfg = get_config("opt-66b")
    lat = LatencyModel(cfg, A100_4X)
    lo, hi = 0.5, 8.0
    for _ in range(7):                      # bisection on request rate
        mid = 0.5 * (lo + hi)
        wl = make_workload(800, mid, seed=3, qoe_trace=trace)
        sched = make_scheduler(sched_name, M, lat, SchedulerConfig())
        res = ServingSimulator(sched, lat,
                               SimConfig(kv_capacity_tokens=M)).run(wl)
        if res.avg_qoe() >= 0.9:
            lo = mid
        else:
            hi = mid
    return lo


for trace in ("reading", "voice"):
    print(f"\nQoE trace: {trace}")
    caps = {}
    for name in ("fcfs", "andes"):
        caps[name] = capacity(name, trace)
        cost = HOURLY_COST / (caps[name] * 3600) * 1e6
        print(f"  {name:>6}: capacity {caps[name]:.2f} req/s "
              f"-> ${cost:,.0f} per 1M requests")
    print(f"  Andes serves {caps['andes']/caps['fcfs']:.2f}x the load on the "
          f"same GPUs (paper: 1.25x text, ~2x voice)")
