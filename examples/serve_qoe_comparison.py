"""End-to-end driver (deliverable b): serve a batched request trace at the
paper's OPT-66B deployment point and compare FCFS / Round-Robin / Andes on
QoE, TTFT, throughput, and preemption — Figure 10 in one script.

Run:  PYTHONPATH=src python examples/serve_qoe_comparison.py [--rate 4.2]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import A100_4X, LatencyModel, SchedulerConfig, make_scheduler
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_workload

ap = argparse.ArgumentParser()
ap.add_argument("--rate", type=float, default=4.2)
ap.add_argument("--requests", type=int, default=1200)
ap.add_argument("--kv-capacity", type=int, default=65_000)
args = ap.parse_args()

cfg = get_config("opt-66b")
lat = LatencyModel(cfg, A100_4X)
print(f"OPT-66B on 4xA100 | rate {args.rate} req/s | "
      f"M = {args.kv_capacity} KV tokens\n")

hdr = (f"{'scheduler':>12} {'avgQoE':>7} {'p10':>6} {'p50':>6} "
       f"{'TTFTp50':>8} {'TTFTp90':>8} {'tok/s':>7} {'preempt':>8}")
print(hdr)
print("-" * len(hdr))
for name in ("fcfs", "round_robin", "andes"):
    wl = make_workload(args.requests, args.rate, seed=7)
    sched = make_scheduler(name, args.kv_capacity, lat, SchedulerConfig())
    res = ServingSimulator(
        sched, lat, SimConfig(kv_capacity_tokens=args.kv_capacity)
    ).run(wl)
    q, t = res.qoes(), res.ttfts()
    print(f"{name:>12} {res.avg_qoe():7.3f} {np.percentile(q,10):6.2f} "
          f"{np.percentile(q,50):6.2f} {np.percentile(t,50):8.2f} "
          f"{np.percentile(t,90):8.2f} {res.throughput():7.1f} "
          f"{res.preemption_freq():8.2f}")

print("\nAndes keeps TTFT ~sub-second and lifts the QoE floor while paying "
      "only a few % of throughput — the paper's Figure 10/Table 4 story.")
