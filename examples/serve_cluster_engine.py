"""Cluster serving with REAL-model replicas (engine-as-oracle walkthrough).

examples/serve_cluster.py drives the fleet with discrete-event simulator
replicas — fine for paper-scale sweeps, but the scheduler's fidelity then
rests on the simulator being right. Since the ServingEngine is steppable
it satisfies the same `SteppableBackend` protocol, so the identical
cluster layer (router, admission, autoscaler untouched) can run replicas
that execute an actual JAX model (granite-class smoke config, virtual
clock) and emit real tokens. Three vignettes:

  1. a 1-replica engine-backed cluster reproduces the bare engine
     bit-for-bit — the cluster layer never perturbs the engine;
  2. a 2-replica all-engine fleet vs the identically-configured
     simulator fleet: per-request TTFT/QoE agreement (the fleet-level
     cross-validation that lets simulator sweeps stand in for runs this
     CPU container cannot execute);
  3. a mixed fleet — replica 0 a real engine, replica 1 a simulator —
     serving one trace through one router.

Every backend here — bare engine and fleets alike — is driven through
the unified `repro.api.ServingClient` (one submit/stream surface;
bit-identical to direct driving, tests/test_api.py).

Run:  PYTHONPATH=src python examples/serve_cluster_engine.py
"""
from __future__ import annotations

import jax
import numpy as np

from repro.api import ServingClient
from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, SchedulerConfig, TPU_V5E, make_scheduler
from repro.core.request import Request
from repro.cluster import (
    ClusterConfig,
    ClusterSimulator,
    engine_backend,
    mixed_backends,
    simulator_backend,
)
from repro.models import Model
from repro.serving import ServingEngine
from repro.workload.arrivals import gamma_arrivals

CFG = get_smoke_config("granite-3-2b")
MODEL = Model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
LAT = LatencyModel(CFG, TPU_V5E)
NUM_SLOTS, MAX_SEQ = 4, 64
CAP = 150   # tight KV budget: exercises queueing + preemption


def mk_workload(n=24, rate=12.0, seed=3):
    rng = np.random.default_rng(seed)
    arrivals = gamma_arrivals(rate, n, rng, cv=3.0)
    wl = []
    for i in range(n):
        plen = int(rng.integers(8, 32))
        wl.append(Request(
            rid=i, arrival=float(arrivals[i]), prompt_len=plen,
            output_len=int(rng.integers(8, 24)),
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, CFG.vocab_size, plen)))
    return wl


def clone(wl):
    return [r.clone() for r in wl]


def serve(backend, wl):
    """Drive any backend (bare engine or fleet) through one client."""
    return ServingClient(backend).serve(wl)


def engine_factory():
    return engine_backend(MODEL, PARAMS, num_slots=NUM_SLOTS,
                          max_seq=MAX_SEQ, capacity_tokens=CAP)


def vignette_invariance():
    print("=== 1. One engine replica in the cluster ≡ the bare engine ===")
    wl = mk_workload()
    bare = ServingEngine(
        MODEL, PARAMS, make_scheduler("andes", CAP, LAT, SchedulerConfig()),
        LAT, num_slots=NUM_SLOTS, max_seq=MAX_SEQ, capacity_tokens=CAP)
    out = sorted(serve(bare, clone(wl)).requests, key=lambda r: r.rid)

    res = serve(ClusterSimulator(LAT, ClusterConfig(
        n_replicas=1, router="round_robin", kv_capacity_tokens=CAP,
        backend_factory=engine_factory(),
    )), clone(wl))
    routed = sorted(res.admitted, key=lambda r: r.rid)
    exact = all(a.emit_times == b.emit_times
                and a.output_tokens == b.output_tokens
                for a, b in zip(routed, out))
    print(f"  {len(out)} requests, engine preemptions={bare.preemptions}, "
          f"timelines bit-for-bit identical: {exact}\n")


def vignette_sim_vs_engine_fleet():
    print("=== 2. Engine fleet vs simulator fleet (same trace/router) ===")
    wl = mk_workload()
    common = dict(n_replicas=2, router="round_robin",
                  kv_capacity_tokens=CAP)
    res_sim = serve(ClusterSimulator(LAT, ClusterConfig(**common)),
                    clone(wl))
    res_eng = serve(ClusterSimulator(LAT, ClusterConfig(
        **common, backend_factory=engine_factory())), clone(wl))
    t_sim = {r.rid: r.final_ttft() for r in res_sim.admitted}
    t_eng = {r.rid: r.final_ttft() for r in res_eng.admitted}
    dt = max(abs(t_sim[i] - t_eng[i]) for i in t_sim)
    print(f"  avg QoE  engine={res_eng.avg_qoe():.3f}  "
          f"sim={res_sim.avg_qoe():.3f}")
    print(f"  max per-request TTFT delta {dt * 1e3:.1f} ms  "
          f"(tokens from the real model: {res_eng.total_tokens()})\n")


def vignette_mixed_fleet():
    print("=== 3. Mixed fleet: replica 0 real engine, replica 1 simulator ===")
    wl = mk_workload(n=30, rate=16.0, seed=5)
    res = serve(ClusterSimulator(LAT, ClusterConfig(
        n_replicas=2, router="round_robin", kv_capacity_tokens=CAP,
        backend_factory=mixed_backends([engine_factory(),
                                        simulator_backend]),
    )), clone(wl))
    for rid, rres in sorted(res.replica_results.items()):
        kind = "engine" if rid % 2 == 0 else "sim"
        print(f"  replica {rid} ({kind:6s}): {len(rres.requests):3d} reqs, "
              f"{rres.total_tokens:4d} tokens, "
              f"avg QoE {rres.avg_qoe():.3f}")
    print(f"  fleet avg QoE {res.avg_qoe():.3f}\n")


if __name__ == "__main__":
    vignette_invariance()
    vignette_sim_vs_engine_fleet()
    vignette_mixed_fleet()
