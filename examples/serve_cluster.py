"""Cluster serving walkthrough: router, admission, autoscaling, contracts.

Builds on examples/quickstart.py one level up: the same `ServingClient`
submit/stream surface, but the backend is a whole fleet of replicas (each
running the paper's Andes scheduler) fed by a bursty multi-tenant trace.
Four vignettes:

  1. Router shoot-out on a heterogeneous fleet (4xA100 + 4xA40): blind
     round-robin vs queue-feedback JSQ vs the QoE-aware router that prices
     replica capability and predicted marginal QoE gain.
  2. Admission control under deep surge: shedding negative-gain requests
     protects the QoE of everyone actually served (§6.4, fleet-wide).
  3. Autoscaling on the QoE-SLO signal: the fleet grows under a burst and
     drains back when it passes, finishing in-flight requests.
  4. Per-tenant SLO contracts: a high-weight tenant buys shed-protection
     under surge through the one QoE-pricing surface (core.pricing).

Run:  PYTHONPATH=src python examples/serve_cluster.py
"""
from __future__ import annotations

import numpy as np

from repro.api import ServingClient, SLOContract
from repro.configs import get_config
from repro.core import A40_4X, A100_4X, LatencyModel
from repro.cluster import (
    AdmissionConfig,
    AutoscalerConfig,
    ClusterConfig,
    ClusterSimulator,
)
from repro.workload import make_multitenant_workload, make_workload

MODEL = get_config("opt-66b")
A100 = LatencyModel(MODEL, A100_4X)
A40 = LatencyModel(MODEL, A40_4X)


def serve(lat, cfg, wl):
    """One client session over a fleet: submit the trace, drain, report."""
    return ServingClient(ClusterSimulator(lat, cfg)).serve(wl)


def vignette_router():
    print("=== 1. Routers on a heterogeneous fleet (1x 4xA100 + 1x 4xA40) ===")
    wl_args = dict(n=400, rate=4.5, seed=1, arrival="gamma", cv=3.0)
    for router in ("round_robin", "jsq", "qoe"):
        cfg = ClusterConfig(n_replicas=2, router=router,
                            kv_capacity_tokens=40_000)
        res = serve([A100, A40], cfg, make_workload(**wl_args))
        per_rep = {rid: len(r.requests)
                   for rid, r in res.replica_results.items()}
        print(f"  {router:12s} avg QoE {res.avg_qoe():.3f}   "
              f"p10 {np.percentile(res.qoes(), 10):.3f}   "
              f"requests per replica {per_rep}")
    print("  (round-robin overloads the A40; JSQ reacts to queues; the QoE"
          " router prices capability up front)\n")


def vignette_admission():
    print("=== 2. Admission control under deep surge (2 replicas, tight KV) ===")
    for policy in ("none", "shed", "defer"):
        cfg = ClusterConfig(
            n_replicas=2, router="qoe", kv_capacity_tokens=12_000,
            admission=AdmissionConfig(policy=policy),
        )
        wl = make_workload(300, 20.0, seed=2, arrival="gamma", cv=3.0)
        res = serve(A100, cfg, wl)
        print(f"  {policy:6s} served QoE {res.avg_qoe(include_shed=False):.3f}"
              f"   incl-shed {res.avg_qoe():.3f}"
              f"   shed {len(res.shed):3d}   defers {res.n_defer_events}")
    print("  (admitting everything drags everyone down; shedding the"
          " negative-gain tail protects the served)\n")


def vignette_autoscaler():
    print("=== 3. Autoscaling on the QoE-SLO signal ===")
    cfg = ClusterConfig(
        n_replicas=1, router="qoe", kv_capacity_tokens=20_000,
        autoscaler=AutoscalerConfig(
            min_replicas=1, max_replicas=4,
            provision_delay=5.0, cooldown=10.0, window=15.0,
        ),
    )
    wl = make_multitenant_workload(300, 8.0, seed=3, arrival="gamma", cv=3.0)
    res = serve(A100, cfg, wl)
    print(f"  peak replicas {res.peak_replicas}, avg QoE {res.avg_qoe():.3f}, "
          f"per-tenant {{{', '.join(f'{k}: {v:.3f}' for k, v in res.per_tenant_avg_qoe().items())}}}")
    for e in res.scale_events:
        print(f"    t={e.t:7.1f}s  {e.action:10s}  replica {e.replica_id}")
    print("  (scale-ups after SLO dips + provision delay; drained replicas"
          " finish their in-flight requests before retiring)\n")


def vignette_contracts():
    print("=== 4. Per-tenant SLO contracts under surge (weight-priced admission) ===")
    contracts = {
        0: ("gold ", SLOContract(ttft_target=2.0, qoe_floor=0.9, weight=4.0)),
        1: ("scrap", SLOContract(qoe_floor=0.5, weight=0.25)),
    }
    wl = make_workload(300, 25.0, seed=4, arrival="gamma", cv=3.0)
    for i, r in enumerate(wl):
        r.tenant = i % 2
        r.contract = contracts[r.tenant][1]
    cfg = ClusterConfig(
        n_replicas=2, router="qoe", kv_capacity_tokens=8_000,
        admission=AdmissionConfig(policy="shed"),
    )
    res = serve(A100, cfg, wl)
    shed = {t: sum(r.tenant == t for r in res.shed) for t in contracts}
    att = res.per_tenant_attainment(default_floor=0.9)
    for t, (name, c) in contracts.items():
        print(f"  {name} (weight {c.weight:4.2f})  shed {shed[t]:3d}   "
              f"contract attainment {att[t]:.3f}")
    print(f"  fleet contract-weighted attainment "
          f"{res.contract_attainment():.3f}")
    print("  (admission prices weight x marginal QoE gain: the gold tenant"
          " is shed last, the scrap tier absorbs the surge)")


if __name__ == "__main__":
    vignette_router()
    vignette_admission()
    vignette_autoscaler()
    vignette_contracts()
