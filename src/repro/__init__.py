"""repro: Andes (QoE-aware LLM text streaming) as a multi-pod JAX framework.

Public surface:
    repro.api       — unified serving client: sessions, token streams,
                      per-tenant SLO contracts over any backend
    repro.core      — QoE metric, schedulers, QoE pricing, latency model
                      (the paper)
    repro.serving   — engine, simulator, KV manager, requests
    repro.models    — 10-architecture model zoo behind one Model API
    repro.kernels   — Pallas TPU kernels + oracles
    repro.training  — optimizer, train step, data, checkpoints
    repro.workload  — arrivals, length distributions, QoE traces
    repro.configs   — architecture + input-shape registry
    repro.launch    — mesh, dry-run, serve/train launchers
"""

__version__ = "1.0.0"
