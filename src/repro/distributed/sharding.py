"""Sharding rules: param/batch/cache pytrees -> PartitionSpecs.

Scheme (DESIGN.md §6): 2-D logical parallelism on top of the physical mesh
  * "model"  — tensor parallel: attention heads / d_ff / d_inner / experts
  * fsdp     — ("pod","data"): batch for activations, FSDP for weights
                (every weight matrix is additionally sharded on its
                non-tensor-parallel dim so 405B params + AdamW state fit)

Rules are name-based over the param tree and downgrade gracefully: a dim
that does not divide by its mesh-axis size is replicated instead (GSPMD
would accept uneven shards, but even sharding keeps the roofline terms
clean). Cache/batch rules handle the decode shapes, including the
batch=1 long-context case where the KV sequence axis is sharded instead of
batch (sequence parallelism over the cache).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _fit(mesh, dim: int, axis):
    """axis if it divides dim, else None (replicate)."""
    return axis if (axis is not None and dim % _axis_size(mesh, axis) == 0) else None


def _fsdp(mesh):
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes if len(axes) > 1 else axes[0]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

# (name fragment, spec builder over trailing dims)
def _rule_for(key_path: str):
    """Returns (n_base_dims, builder(mesh, shape_tail) -> spec_tail)."""
    k = key_path

    def two(in_ax, out_ax):
        return 2, lambda mesh, s: (
            _fit(mesh, s[0], in_ax(mesh)), _fit(mesh, s[1], out_ax(mesh)))

    fsdp = _fsdp
    mdl = lambda mesh: "model"

    if k.endswith("embed|table"):
        return two(mdl, fsdp)            # (V, d): vocab on model, d FSDP
    if "lm_head" in k:
        return two(fsdp, mdl)            # (d, V)
    if any(t in k for t in ("|wq", "|wk", "|wv", "|up", "|gate", "|in_proj",
                            "vision_proj")):
        if "experts|" in k:              # (E, d, fe)
            return 3, lambda mesh, s: (
                _fit(mesh, s[0], "model"), _fit(mesh, s[1], _fsdp(mesh)), None)
        return two(fsdp, mdl)
    if any(t in k for t in ("|wo", "|down", "|out_proj")):
        if "experts|" in k:              # (E, fe, d)
            return 3, lambda mesh, s: (
                _fit(mesh, s[0], "model"), None, _fit(mesh, s[1], _fsdp(mesh)))
        return two(mdl, fsdp)
    if k.endswith("|router"):
        return two(fsdp, lambda m: None)  # (d, E): E small, replicated
    if k.endswith("|x_proj") or k.endswith("|dt_proj"):
        return two(mdl, lambda m: None) if k.endswith("|x_proj") \
            else two(lambda m: None, mdl)
    if k.endswith("|conv_w"):
        return 2, lambda mesh, s: (None, _fit(mesh, s[1], "model"))
    if k.endswith("|A_log") and True:
        return 0, None                    # handled by dim count below
    return 0, None


def param_specs(mesh, params_tree) -> Dict:
    """PartitionSpec pytree matching `params_tree` (arrays or SDS)."""
    fsdp = _fsdp(mesh)

    def spec_one(path, leaf):
        key = "|".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        shape = leaf.shape
        nbase, builder = _rule_for(key)
        if builder is not None and len(shape) >= nbase:
            lead = (None,) * (len(shape) - nbase)
            tail = builder(mesh, shape[len(shape) - nbase:])
            return P(*(lead + tuple(tail)))
        # 1-D-ish leaves: shard big vectors on model, replicate small ones
        if shape and shape[-1] >= 1024:
            lead = (None,) * (len(shape) - 1)
            return P(*(lead + (_fit(mesh, shape[-1], "model"),)))
        if key.endswith("|A_log") and len(shape) >= 2 and shape[-2] >= 1024:
            lead = (None,) * (len(shape) - 2)
            return P(*(lead + (_fit(mesh, shape[-2], "model"), None)))
        return P()

    return jax.tree_util.tree_map_with_path(spec_one, params_tree)


# ---------------------------------------------------------------------------
# Batches and caches
# ---------------------------------------------------------------------------

def batch_specs(mesh, batch_tree, cfg: Optional[ModelConfig] = None) -> Dict:
    """Shard the leading batch dim of every batch leaf on the data axes."""
    dp = _fsdp(mesh)

    def spec_one(path, leaf):
        bdim = leaf.shape[0] if leaf.shape else 1
        first = _fit(mesh, bdim, dp)
        # fall back to single "data" axis if the combined axes don't divide
        if first is None and isinstance(dp, tuple):
            first = _fit(mesh, bdim, "data")
        return P(*((first,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_one, batch_tree)


def cache_specs(mesh, cache_tree, cfg: ModelConfig) -> Dict:
    """Decode-cache shardings.

    KV (L, B, S, KV, hd): batch on data axes; heads on "model" when they
    divide, else head_dim on "model", else replicate. If batch itself does
    not divide (long_500k has B=1), the *sequence* axis takes the data axes
    instead (cache sequence parallelism).
    """
    dp = _fsdp(mesh)

    def kv_spec(shape):
        L, B, S, KV, HD = shape
        b_ax = _fit(mesh, B, dp)
        if b_ax is None and isinstance(dp, tuple):
            b_ax = _fit(mesh, B, "data")
        s_ax = None
        if b_ax is None:
            s_ax = _fit(mesh, S, dp)     # sequence parallelism fallback
        head_ax = _fit(mesh, KV, "model")
        hd_ax = None if head_ax else _fit(mesh, HD, "model")
        return P(None, b_ax, s_ax, head_ax, hd_ax)

    def spec_one(path, leaf):
        key = "|".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        shape = leaf.shape
        if key in ("k", "v", "cross_k", "cross_v"):
            return kv_spec(shape)
        if key == "length" or key == "enc_length":
            return P(_fit(mesh, shape[0], dp))
        if key == "ssm_h":
            # (L, B, di, N) or (L, B, NH, HD, N)
            b_ax = _fit(mesh, shape[1], dp)
            inner = _fit(mesh, shape[2], "model")
            return P(*((None, b_ax, inner) + (None,) * (len(shape) - 3)))
        if key == "ssm_conv":
            b_ax = _fit(mesh, shape[1], dp)
            return P(None, b_ax, None, _fit(mesh, shape[3], "model"))
        return P()

    return jax.tree_util.tree_map_with_path(spec_one, cache_tree)


def make_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
