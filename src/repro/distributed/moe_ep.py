"""Expert-parallel MoE dispatch with shard_map (beyond-paper, §Perf).

The GSPMD-compiled capacity-MoE (models/moe.py) lets XLA choose the
collectives; measured on qwen2-moe prefill it all-gathers every token to
every expert shard (~175 GB/dev) before selecting. This version writes the
communication explicitly with `shard_map` over the (data..., model) mesh:

  * tokens are sharded over the data axes and *replicated* over "model"
    (that is already the activation layout) — so each device can select the
    tokens routed to ITS local experts with zero communication;
  * each device runs its E/tp experts on its data shard's tokens (expert
    FLOPs are thereby sharded over the full mesh);
  * the only collective is one `psum` over "model" to combine the partial
    per-token outputs (each token's k experts live on ≤ k model shards).

Per-device traffic drops from gather(all tokens) + reduce(outputs) to just
reduce(outputs). Capacity is per (data shard × expert), which totals to the
same global 1.25·k·T slots as the baseline.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models.layers import mlp_apply


def moe_apply_ep(p, x: jax.Array, cfg: ModelConfig, mesh,
                 valid=None) -> Tuple[jax.Array, jax.Array]:
    """Drop-in for moe_apply under an explicit mesh. x (B, S, d)."""
    m = cfg.moe
    tp = int(mesh.shape["model"])
    # pad experts up to a multiple of the model axis (router never routes
    # to the pad experts — only their zero weights are carried)
    e_pad = (-m.num_experts) % tp
    experts = p["experts"]
    if e_pad:
        experts = jax.tree.map(
            lambda w: jnp.pad(w, ((0, e_pad),) + ((0, 0),) * (w.ndim - 1)),
            experts,
        )
    e_total = m.num_experts + e_pad
    e_local = e_total // tp
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_spec = dp if len(dp) > 1 else dp[0]

    def local_fn(xl, vl, router, gate, up, down):
        # xl (B_loc, S, d) — this data shard's tokens (same on every model
        # shard); gate/up/down (E/tp, ...) — this model shard's experts.
        b, s, d = xl.shape
        t = b * s
        xt = xl.reshape(t, d)
        logits = (xt @ router).astype(jnp.float32)            # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, m.top_k)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
        if vl is not None:
            vt = vl.reshape(t)
            top_w = top_w * vt[:, None]
            top_e = jnp.where(vt[:, None], top_e, e_total)
            probs = probs * vt[:, None]

        # aux loss: identical on every model shard (inputs replicated);
        # average over data shards
        me = jnp.mean(probs, axis=0)
        onehot_full = jax.nn.one_hot(top_e, m.num_experts)
        ce = jnp.mean(jnp.sum(onehot_full, axis=1), axis=0) / m.top_k
        aux = m.num_experts * jnp.sum(me * ce) * m.router_aux_loss_coef
        aux = jax.lax.pmean(aux, dp) if dp else aux

        # ---- select slots routed to LOCAL experts -----------------------
        e0 = jax.lax.axis_index("model") * e_local
        local_e = top_e - e0                                   # (T_loc, k)
        is_local = (local_e >= 0) & (local_e < e_local)
        local_e = jnp.where(is_local, local_e, e_local)        # waste row
        onehot = jax.nn.one_hot(local_e, e_local)              # (T,k,E_loc)

        # live module-attribute lookup, NOT a from-import: the capacity
        # knob must stay shared with the GSPMD reference. A value bound at
        # import time silently diverges when callers (the no-drop
        # differential test, notably) retune moe.CAPACITY_FACTOR — the EP
        # path then drops tokens the reference keeps, which surfaced as a
        # ~1.6e-3 "numerical drift" in the divisible case that was really
        # a few dropped tokens.
        cap = int(moe_lib.CAPACITY_FACTOR * t * m.top_k / m.num_experts) + 1
        cap = min(cap, t)
        flat_e = local_e.reshape(t * m.top_k)
        flat_w = (top_w * is_local).reshape(t * m.top_k)
        flat_oh = onehot.reshape(t * m.top_k, e_local)
        pos_in_e = jnp.cumsum(flat_oh, axis=0) - 1.0
        slot_pos = jnp.sum(pos_in_e * flat_oh, axis=-1).astype(jnp.int32)
        keep = (slot_pos < cap) & (flat_e < e_local)
        slot_pos = jnp.where(keep, slot_pos, cap)

        token_idx = jnp.repeat(jnp.arange(t), m.top_k)
        buf = jnp.zeros((e_local, cap + 1, d), xl.dtype)
        buf = buf.at[jnp.minimum(flat_e, e_local - 1), slot_pos].add(
            jnp.where(keep[:, None], xt[token_idx], 0.0)
        )
        buf = buf[:, :cap]

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate)) \
            * jnp.einsum("ecd,edf->ecf", buf, up)
        out = jnp.einsum("ecf,efd->ecd", h, down)

        gathered = out[jnp.minimum(flat_e, e_local - 1),
                       jnp.minimum(slot_pos, cap - 1)]
        gathered = gathered * (flat_w * keep)[:, None]
        y = jnp.zeros((t, d), xl.dtype).at[token_idx].add(gathered)
        # the ONLY cross-shard collective: combine partial expert outputs
        y = jax.lax.psum(y, "model")
        return y.reshape(b, s, d), aux

    vspec = P(dp_spec, None) if valid is not None else None
    args_in = (
        P(dp_spec, None, None),          # x: data-sharded, model-replicated
        vspec,
        P(None, None),                   # router replicated
        P("model", None, None),          # experts: E over model
        P("model", None, None),
        P("model", None, None),
    )
    if valid is None:
        def wrapper(xl, router, gate, up, down):
            return local_fn(xl, None, router, gate, up, down)
        y, aux = shard_map(
            wrapper, mesh=mesh,
            in_specs=(args_in[0],) + args_in[2:],
            out_specs=(P(dp_spec, None, None), P()),
            check_rep=False,
        )(x, p["router"], experts["gate"], experts["up"], experts["down"])
    else:
        y, aux = shard_map(
            local_fn, mesh=mesh, in_specs=args_in,
            out_specs=(P(dp_spec, None, None), P()),
            check_rep=False,
        )(x, valid, p["router"], experts["gate"], experts["up"],
          experts["down"])

    if "shared" in p:
        b, s, d = x.shape
        y = y + mlp_apply(p["shared"], x.reshape(-1, d)).reshape(b, s, d)
    return y, aux
