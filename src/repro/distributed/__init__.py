from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    make_shardings,
    param_specs,
)

__all__ = ["param_specs", "batch_specs", "cache_specs", "make_shardings"]
