"""repro.server — HTTP/SSE wire frontend over the serving stack (PR 9).

  ServingServer  — asyncio HTTP server: POST /v1/stream (SSE token
                   streams mapping StreamHandle 1:1), GET /metrics
                   (Prometheus text), GET /healthz; per-connection
                   backpressure, disconnect-cancel, graceful drain.
  ServerConfig   — knobs (host/port, arch, clock mode, queue depth).
  build_engine   — the smoke ServingEngine a standalone server runs.
  format_sse / SSEParser — wire framing + incremental decoder.
  stream / fetch — minimal blocking client helpers (tests, examples).

Run one: `python -m repro.server --port 8080` (SIGTERM drains).
"""
from repro.server.app import ServerConfig, ServingServer, build_engine
from repro.server.client import astream, collect, fetch, stream
from repro.server.sse import SSEParser, format_sse

__all__ = [
    "ServingServer", "ServerConfig", "build_engine",
    "format_sse", "SSEParser", "stream", "collect", "astream", "fetch",
]
