"""CLI entry point: `python -m repro.server [--port N] [--clock wall]`.

Builds the smoke-model engine described by the flags, binds, prints
`LISTENING <port>` on stdout (the CI smoke job and Makefile `serve`
target wait for that line), and serves until SIGTERM/SIGINT — which
triggers a graceful drain: new streams get 503, live ones finish within
--drain-timeout, then the process exits 0.
"""
from __future__ import annotations

import argparse
import signal
import sys
import threading

from repro.server.app import ServerConfig, ServingServer


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.server",
                                description="Andes HTTP/SSE serving frontend")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="0 = OS-assigned (printed as LISTENING <port>)")
    p.add_argument("--arch", default="llama3-8b")
    p.add_argument("--clock", choices=("wall", "virtual"), default="wall")
    p.add_argument("--scheduler", default="andes")
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=64)
    p.add_argument("--queue-depth", type=int, default=256)
    p.add_argument("--drain-timeout", type=float, default=10.0)
    p.add_argument("--no-warmup", action="store_true")
    args = p.parse_args(argv)

    cfg = ServerConfig(host=args.host, port=args.port, arch=args.arch,
                       clock=args.clock, scheduler=args.scheduler,
                       num_slots=args.slots, max_seq=args.max_seq,
                       queue_depth=args.queue_depth,
                       drain_timeout=args.drain_timeout,
                       warmup=not args.no_warmup)
    server = ServingServer(cfg)
    stop = threading.Event()

    def on_signal(signum, frame):
        stop.set()

    signal.signal(signal.SIGTERM, on_signal)
    signal.signal(signal.SIGINT, on_signal)

    port = server.start()
    print(f"LISTENING {port}", flush=True)
    stop.wait()
    phase = server.shutdown(drain=True)
    print(f"DRAINED {phase}", flush=True)
    return 0 if phase == "done" else 1


if __name__ == "__main__":
    sys.exit(main())
