"""Asyncio HTTP/SSE serving frontend over ServingClient (ISSUE 9 tentpole).

This is the wire edge of the repo: the first place the serving stack
talks to something it does not control — a real socket, a real client,
real time. The moving parts:

* **Engine pump thread.** The backend (a `ServingEngine`, usually with
  `clock="wall"` so its emissions happen at LatencyModel pace in real
  time) plus its `ServingClient` live on one dedicated thread, because
  `step()` may *sleep* to hold the schedule and must never block the
  event loop. Commands (submit / cancel / stop) reach it through a
  `queue.Queue`; after every step it flushes newly emitted tokens to the
  owning connections via `loop.call_soon_threadsafe`.

* **Asyncio loop thread.** A stdlib `asyncio.start_server` HTTP/1.1
  frontend (no third-party deps — CI installs none):

      POST /v1/stream   JSON body -> SSE stream of lifecycle frames
                        (accepted / token / preempt / finish / shed /
                        cancel), mapping StreamHandle events 1:1.
      GET  /metrics     Prometheus text from the live MetricsRegistry.
      GET  /healthz     liveness + clock mode + live-connection count.

* **Backpressure.** Each connection owns a bounded `asyncio.Queue`; a
  consumer that stops reading long enough to fill it is *evicted* — its
  request cancelled on the engine (freeing the KV slot for paying
  traffic) and its stream closed with an `evicted` frame. A client
  disconnect mid-stream does the same through the reader-EOF path.

* **Graceful drain.** `shutdown(drain=True)` (what SIGTERM triggers in
  `python -m repro.server`) stops admitting new streams (503), lets live
  ones finish within `drain_timeout`, then stops the pump and the loop.
  Every phase fires the `Observer.drain` hook; connection lifecycle and
  flush volume go through `Observer.connection` / `Observer.sse_flush`,
  so the trace/metrics layers see the wire exactly like they see the
  scheduler.

Wall-clock timestamps in SSE frames are engine-relative seconds (the
same clock as the trace events), so a captured stream can be compared
frame-for-frame against a virtual-clock reference run with the
`serving.tolerance` harness — the acceptance gate the CI server smoke
job enforces.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import math
import queue
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.api import ServingClient, SubmitOptions
from repro.core import QoESpec, make_network
from repro.core.request import Request
from repro.core.token_buffer import TokenBuffer
from repro.obs import MetricsObserver, MetricsRegistry, TraceRecorder, compose
from repro.obs.metrics import register_backend_gauges
from repro.server.sse import format_sse

_MAX_HEADER_BYTES = 65536
_MAX_BODY_BYTES = 1 << 20


@dataclasses.dataclass
class ServerConfig:
    """Knobs for a ServingServer (CLI flags in `python -m repro.server`)."""
    host: str = "127.0.0.1"
    port: int = 0                  # 0 = OS-assigned; read server.port after start
    arch: str = "llama3-8b"        # smoke-config architecture
    clock: str = "wall"            # "wall" = real-time pacing (the point)
    scheduler: str = "andes"
    num_slots: int = 4
    max_seq: int = 64
    queue_depth: int = 256         # per-connection SSE backpressure bound
    drain_timeout: float = 10.0    # graceful-shutdown budget (seconds)
    warmup: bool = True            # absorb jit compile before first request
    default_spec: QoESpec = dataclasses.field(
        default_factory=lambda: QoESpec(ttft=1.0, tds=4.8))


def build_engine(config: ServerConfig):
    """Construct the smoke-model ServingEngine a standalone server runs.

    Split out so tests and the bench can build the identical engine with
    `clock="virtual"` for the differential reference."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core import TPU_V5E, LatencyModel, make_scheduler
    from repro.models import Model
    from repro.serving import ServingEngine

    cfg = get_smoke_config(config.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler(config.scheduler, config.num_slots * config.max_seq,
                           lat)
    eng = ServingEngine(model, params, sched, lat,
                        num_slots=config.num_slots, max_seq=config.max_seq,
                        clock=config.clock)
    return cfg, eng


class _Conn:
    """Per-connection state, bridging the pump thread and the loop.

    The pump thread owns `handle`, `cursor`, `buf`, and `marks`; the loop
    thread owns `queue` and the writer. `dead` is a one-way flag either
    side may set (GIL-atomic) meaning "stop producing for this stream"."""

    def __init__(self, conn_id: int, depth: int):
        self.conn_id = conn_id
        self.handle = None                    # set by pump on submit
        self.cursor = 0                       # emit_times consumed so far
        self.buf: Optional[TokenBuffer] = None
        self.marks: List[Dict[str, Any]] = [] # preempt/shed frames, in order
        self.queue: "asyncio.Queue" = asyncio.Queue(maxsize=depth)
        self.dead = False
        self.final_sent = False


class ServingServer:
    """The HTTP/SSE frontend. Sync lifecycle: start() / shutdown().

    Pass a prebuilt `backend` (anything ServingClient accepts) to serve
    it directly, or leave it None to build the smoke engine described by
    `config`. The server owns a TraceRecorder + MetricsRegistry attached
    alongside any observers the backend already has.
    """

    def __init__(self, config: Optional[ServerConfig] = None, *,
                 backend=None, model_cfg=None):
        self.config = config if config is not None else ServerConfig()
        if backend is None:
            model_cfg, backend = build_engine(self.config)
        self.model_cfg = model_cfg
        self.backend = backend
        self.registry = MetricsRegistry()
        self.trace = TraceRecorder()
        backend.attach_observer(
            compose(self.trace, MetricsObserver(self.registry)))
        register_backend_gauges(self.registry, backend)
        self.client = ServingClient(backend)
        self.port: Optional[int] = None
        self._cmds: "queue.Queue" = queue.Queue()
        self._conns: Dict[int, _Conn] = {}     # pump-owned registry
        self._next_conn = 0
        self._draining = False
        self._started = False
        self._stopped = threading.Event()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._ready = threading.Event()
        self._loop_thread: Optional[threading.Thread] = None
        self._pump_thread: Optional[threading.Thread] = None
        self._asyncio_server = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> int:
        """Bind, start the loop and pump threads, return the bound port."""
        if self._started:
            return self.port
        self._loop_thread = threading.Thread(target=self._loop_main,
                                             name="sse-loop", daemon=True)
        self._loop_thread.start()
        if not self._ready.wait(timeout=30):
            raise RuntimeError("server event loop failed to start")
        if self.port is None:
            raise RuntimeError("server failed to bind")
        if self.config.warmup:
            self._warmup()
        self._pump_thread = threading.Thread(target=self._pump,
                                             name="engine-pump", daemon=True)
        self._pump_thread.start()
        self._started = True
        return self.port

    def _warmup(self) -> None:
        """Run one tiny request through the backend so jit compilation
        happens before the socket accepts traffic — otherwise the first
        client's wall TTFT eats the compile time (the same reason the
        tolerance tests warm their wall engines)."""
        run = getattr(self.backend, "run", None)
        if run is None or self.model_cfg is None:
            return
        rng = np.random.default_rng(0)
        wl = [Request(rid=-(i + 1), arrival=0.0, prompt_len=5, output_len=3,
                      spec=self.config.default_spec,
                      prompt_tokens=rng.integers(
                          0, self.model_cfg.vocab_size, 5))
              for i in range(2)]
        run(wl, max_iterations=500)
        # fresh clock for real traffic: without this, wall_now() would
        # carry the warmup's compile seconds into every arrival stamp
        reset = getattr(self.backend, "reset", None)
        if reset is not None:
            reset()

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> str:
        """Stop serving. With `drain`, refuse new streams (503) and wait
        up to `timeout` (default config.drain_timeout) for live ones to
        finish. Returns the terminal drain phase: "done" or "timeout"."""
        if not self._started or self._stopped.is_set():
            return "done"
        timeout = self.config.drain_timeout if timeout is None else timeout
        self._draining = True
        t = self._now()
        self._observer_call("drain", t, "begin", len(self._conns),
                            self._live_count())
        phase = "done"
        if drain and self._conns:
            self._observer_call("drain", self._now(), "waiting",
                                len(self._conns), self._live_count())
            deadline = time.monotonic() + timeout
            while self._conns and time.monotonic() < deadline:
                time.sleep(0.02)
            phase = "done" if not self._conns else "timeout"
        self._cmds.put(("stop",))
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=10)
        if self._loop is not None:
            self._loop.call_soon_threadsafe(self._loop.stop)
        if self._loop_thread is not None:
            self._loop_thread.join(timeout=10)
        self._observer_call("drain", self._now(), phase, len(self._conns),
                            self._live_count())
        self._stopped.set()
        return phase

    def _live_count(self) -> int:
        try:
            return len(self.backend.live)
        except Exception:
            return 0

    def _now(self) -> float:
        wall = getattr(self.backend, "wall_now", None)
        return float(wall() if callable(wall) else self.backend.now)

    def _observer_call(self, hook: str, *args) -> None:
        obs = getattr(self.backend, "obs", None) or self.backend.observer
        if obs is not None:
            getattr(obs, hook)(*args)

    # ------------------------------------------------------------ loop side
    def _loop_main(self) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop

        async def boot():
            self._asyncio_server = await asyncio.start_server(
                self._serve_conn, self.config.host, self.config.port)
            self.port = self._asyncio_server.sockets[0].getsockname()[1]
            self._ready.set()

        loop.run_until_complete(boot())
        try:
            loop.run_forever()
        finally:
            self._asyncio_server.close()
            loop.run_until_complete(self._asyncio_server.wait_closed())
            loop.close()

    async def _serve_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            if not line:
                return
            try:
                method, path, _ = line.decode("latin-1").split()
            except ValueError:
                await self._respond(writer, 400, {"error": "bad request"})
                return
            headers: Dict[str, str] = {}
            total = 0
            while True:
                h = await reader.readline()
                total += len(h)
                if h in (b"\r\n", b"\n", b""):
                    break
                if total > _MAX_HEADER_BYTES:
                    await self._respond(writer, 431,
                                        {"error": "headers too large"})
                    return
                k, _, v = h.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length", "0") or 0)
            if n > _MAX_BODY_BYTES:
                await self._respond(writer, 413, {"error": "body too large"})
                return
            if n:
                body = await reader.readexactly(n)

            if method == "GET" and path == "/healthz":
                await self._respond(writer, 200, {
                    "ok": True,
                    "clock": getattr(self.backend, "clock", "virtual"),
                    "draining": self._draining,
                    "connections": len(self._conns),
                    "live": self._live_count(),
                })
            elif method == "GET" and path == "/metrics":
                await self._respond(writer, 200, self.registry.to_prometheus(),
                                    ctype="text/plain; version=0.0.4")
            elif method == "POST" and path == "/v1/stream":
                await self._stream(reader, writer, body)
            else:
                await self._respond(writer, 404, {"error": "not found"})
        except (ConnectionResetError, asyncio.IncompleteReadError,
                BrokenPipeError):
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass

    async def _respond(self, writer: asyncio.StreamWriter, status: int,
                       body, ctype: str = "application/json") -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  413: "Payload Too Large", 431: "Headers Too Large",
                  503: "Service Unavailable"}.get(status, "Error")
        if not isinstance(body, (bytes, str)):
            body = json.dumps(body)
        if isinstance(body, str):
            body = body.encode("utf-8")
        writer.write((f"HTTP/1.1 {status} {reason}\r\n"
                      f"Content-Type: {ctype}\r\n"
                      f"Content-Length: {len(body)}\r\n"
                      "Connection: close\r\n\r\n").encode("latin-1") + body)
        await writer.drain()

    async def _stream(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter, body: bytes) -> None:
        t = self._now()
        if self._draining:
            self._observer_call("connection", t, -1, "reject")
            await self._respond(writer, 503, {"error": "draining"})
            return
        try:
            payload = json.loads(body.decode("utf-8")) if body else {}
            if not isinstance(payload, dict):
                raise ValueError("body must be a JSON object")
        except (ValueError, UnicodeDecodeError) as e:
            await self._respond(writer, 400, {"error": str(e)})
            return

        conn = _Conn(self._next_conn, self.config.queue_depth)
        self._next_conn += 1
        self._observer_call("connection", t, conn.conn_id, "open")
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        self._cmds.put(("submit", payload, conn))

        # EOF on the read side = client went away; an SSE client never
        # sends more bytes after the request, so any read completion
        # (data or EOF) means disconnect.
        eof_task = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                get_task = asyncio.ensure_future(conn.queue.get())
                done, _ = await asyncio.wait(
                    {get_task, eof_task},
                    return_when=asyncio.FIRST_COMPLETED)
                if get_task not in done:
                    get_task.cancel()
                    conn.dead = True
                    if conn.handle is not None:
                        self._cmds.put(("cancel", conn.handle.rid))
                    self._observer_call("connection", self._now(),
                                        conn.conn_id, "disconnect")
                    return
                batch = get_task.result()
                if batch is None:              # sentinel: stream complete
                    break
                frame = b"".join(format_sse(ev.pop("event"), ev)
                                 for ev in batch)
                writer.write(frame)
                await writer.drain()
                self._observer_call("sse_flush", self._now(), conn.conn_id,
                                    conn.handle.rid if conn.handle else -1,
                                    len(batch), len(frame))
        except (ConnectionResetError, BrokenPipeError):
            conn.dead = True
            if conn.handle is not None:
                self._cmds.put(("cancel", conn.handle.rid))
            self._observer_call("connection", self._now(), conn.conn_id,
                                "disconnect")
            return
        finally:
            if not eof_task.done():
                eof_task.cancel()
        self._observer_call("connection", self._now(), conn.conn_id, "close")

    def _offer(self, conn: _Conn, batch: Optional[List[Dict[str, Any]]]):
        """Loop-thread callback: enqueue a flush batch for one connection.

        A full queue means the consumer stopped reading while the engine
        kept emitting — evict: drop what it hasn't read, cancel its
        request, and end the stream with an `evicted` frame so the client
        knows it wasn't a clean finish."""
        if conn.dead:
            return
        if batch is None:
            try:
                conn.queue.put_nowait(None)
            except asyncio.QueueFull:
                # drop unread frames so the sentinel always fits — the
                # stream is over either way
                conn.queue.get_nowait()
                conn.queue.put_nowait(None)
            return
        try:
            conn.queue.put_nowait(batch)
        except asyncio.QueueFull:
            conn.dead = True
            while True:
                try:
                    conn.queue.get_nowait()
                except asyncio.QueueEmpty:
                    break
            t = self._now()
            conn.queue.put_nowait([{"event": "evicted", "t": t}])
            conn.queue.put_nowait(None)
            if conn.handle is not None:
                self._cmds.put(("cancel", conn.handle.rid))
            self._observer_call("connection", t, conn.conn_id, "evict")

    # ------------------------------------------------------------ pump side
    def _pump(self) -> None:
        while True:
            try:
                while True:
                    if self._apply(self._cmds.get_nowait()):
                        return
            except queue.Empty:
                pass
            progressed = self.client.step()
            self._flush_all()
            if not progressed:
                try:
                    cmd = self._cmds.get(timeout=0.02)
                except queue.Empty:
                    continue
                if self._apply(cmd):
                    return
                # apply newly submitted work before sleeping again
                self.client.step()
                self._flush_all()

    def _apply(self, cmd) -> bool:
        """Execute one command on the pump thread. True = stop."""
        kind = cmd[0]
        if kind == "stop":
            # anything still connected gets a terminal frame so its
            # handler coroutine wakes up and closes
            for conn in list(self._conns.values()):
                self._post(conn, [{"event": "shutdown", "t": self._now()}],
                           final=True)
                self._conns.pop(conn.conn_id, None)
            return True
        if kind == "cancel":
            self.client.cancel(cmd[1])
            return False
        if kind == "submit":
            _, payload, conn = cmd
            try:
                self._submit(payload, conn)
            except Exception as e:
                self._post(conn, [{"event": "error", "message": str(e)}],
                           final=True)
            return False
        return False

    def _submit(self, payload: Dict[str, Any], conn: _Conn) -> None:
        spec = self.config.default_spec
        spec = QoESpec(ttft=float(payload.get("ttft", spec.ttft)),
                       tds=float(payload.get("tds", spec.tds)))
        toks = payload.get("prompt_tokens")
        if toks is not None:
            prompt = np.asarray(toks, np.int32)
        else:
            plen = int(payload.get("prompt_len", 8))
            vocab = (self.model_cfg.vocab_size
                     if self.model_cfg is not None else 32_000)
            # deterministic per-connection prompt so differential runs
            # can reproduce it
            prompt = np.random.default_rng(
                (1234, conn.conn_id)).integers(0, vocab, plen)
        # explicit arrival: ServingClient's default reads backend.now,
        # which on a wall engine is the *paced* clock (stale while the
        # pump is between steps) — stamp the real reading instead
        opts = SubmitOptions(
            spec=spec,
            max_tokens=int(payload.get("max_tokens", 16)),
            tenant=int(payload.get("tenant", 0)),
            priority=int(payload.get("priority", 0)),
            arrival=self._now(),
        )
        if payload.get("rid") is not None:
            # trace replays pin rids for differential pairing
            req = Request(rid=int(payload["rid"]), arrival=opts.arrival,
                          prompt_len=int(prompt.size),
                          output_len=opts.max_tokens, spec=spec,
                          prompt_tokens=prompt, tenant=opts.tenant,
                          priority=opts.priority)
            handle = self.client.submit_request(req)
        else:
            handle = self.client.submit(prompt, opts)
        conn.handle = handle
        net = payload.get("network")
        conn.buf = TokenBuffer(spec.tds,
                               network=make_network(net) if net else None)
        handle.on_preempt = lambda h, t: conn.marks.append(
            {"event": "preempt", "t": t})
        self._conns[conn.conn_id] = conn
        self._observer_call("connection", self._now(), conn.conn_id,
                            "request", {"rid": handle.rid})
        self._post(conn, [{"event": "accepted", "rid": handle.rid,
                           "arrival": handle.request.arrival}])

    def _post(self, conn: _Conn, batch: Optional[List[Dict[str, Any]]],
              final: bool = False) -> None:
        """Hand a batch to the loop thread (pump side)."""
        if conn.dead or self._loop is None:
            return
        self._loop.call_soon_threadsafe(self._offer, conn, batch)
        if final:
            conn.final_sent = True
            self._loop.call_soon_threadsafe(self._offer, conn, None)

    def _flush_all(self) -> None:
        for conn in list(self._conns.values()):
            if conn.dead:
                self._conns.pop(conn.conn_id, None)
                continue
            self._flush(conn)
            if conn.final_sent:
                self._conns.pop(conn.conn_id, None)

    def _flush(self, conn: _Conn) -> None:
        h = conn.handle
        if h is None:
            return
        r = h.request
        batch: List[Dict[str, Any]] = conn.marks
        conn.marks = []
        while conn.cursor < len(r.emit_times):
            i = conn.cursor
            conn.cursor += 1
            e = float(r.emit_times[i])
            tok = (int(r.output_tokens[i]) if i < len(r.output_tokens)
                   else None)
            batch.append({"event": "token", "index": i, "token": tok,
                          "t": e, "visible": conn.buf.push(e)})
        final = False
        if h.shed:
            batch.append({"event": "shed", "t": self._now()})
            final = True
        elif h.cancelled:
            batch.append({"event": "cancel", "t": self._now(),
                          "n_tokens": int(r.generated)})
            final = True
        elif h.finished:
            tds = r.final_tds()
            batch.append({"event": "finish", "t": float(r.finish_time),
                          "n_tokens": int(r.generated),
                          "ttft": r.final_ttft(),
                          "tds": tds if math.isfinite(tds) else None,
                          "qoe": r.final_qoe()})
            final = True
        if batch:
            self._post(conn, batch, final=final)
        elif final:
            self._post(conn, None)
            conn.final_sent = True


__all__ = ["ServerConfig", "ServingServer", "build_engine"]
