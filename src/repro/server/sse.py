"""Server-sent events (SSE) wire format: framing and incremental parsing.

The streaming surface of the HTTP frontend maps the `StreamHandle` /
`TokenEvent` lifecycle 1:1 onto SSE frames (WHATWG HTML §9.2 subset):

    event: token
    data: {"index": 0, "token": 1234, "t": 0.183, "visible": 1.0}

One frame per lifecycle event, `data` always a single JSON line. The
parser is the strict inverse and is incremental — feed it arbitrary byte
chunks as they come off the socket (frames routinely straddle TCP reads)
and it yields complete events in order. Both directions are exercised
against each other and against a live server in tests/test_server.py.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple


def format_sse(event: str, data: Any, event_id: Optional[int] = None) -> bytes:
    """Render one SSE frame. `data` is JSON-encoded (single line)."""
    lines = [f"event: {event}"]
    if event_id is not None:
        lines.append(f"id: {event_id}")
    lines.append("data: " + json.dumps(data, separators=(",", ":")))
    return ("\n".join(lines) + "\n\n").encode("utf-8")


class SSEParser:
    """Incremental SSE decoder: bytes in, (event, data) tuples out.

    Handles frames split across chunk boundaries, multi-line `data:`
    fields (joined with \\n per spec), `id:` fields, comment lines
    (leading ':'), and both \\n and \\r\\n line endings. Unknown field
    names are ignored, as the spec requires.
    """

    def __init__(self):
        self._buf = b""
        self._event = ""
        self._data: List[str] = []
        self.last_id: Optional[str] = None

    def feed(self, chunk: bytes) -> List[Tuple[str, Dict[str, Any]]]:
        """Consume a chunk; return every event completed by it."""
        self._buf += chunk
        out: List[Tuple[str, Dict[str, Any]]] = []
        while True:
            nl = self._buf.find(b"\n")
            if nl < 0:
                break
            line = self._buf[:nl].rstrip(b"\r")
            self._buf = self._buf[nl + 1:]
            ev = self._line(line.decode("utf-8"))
            if ev is not None:
                out.append(ev)
        return out

    def _line(self, line: str) -> Optional[Tuple[str, Dict[str, Any]]]:
        if line == "":                       # blank line: dispatch the frame
            if not self._event and not self._data:
                return None                  # stray keep-alive blank
            event = self._event or "message"
            raw = "\n".join(self._data)
            self._event, self._data = "", []
            try:
                data = json.loads(raw) if raw else {}
            except json.JSONDecodeError:
                data = {"raw": raw}
            return (event, data)
        if line.startswith(":"):             # comment / keep-alive
            return None
        field, _, value = line.partition(":")
        value = value[1:] if value.startswith(" ") else value
        if field == "event":
            self._event = value
        elif field == "data":
            self._data.append(value)
        elif field == "id":
            self.last_id = value
        return None


__all__ = ["format_sse", "SSEParser"]
