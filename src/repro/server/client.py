"""Minimal HTTP/SSE client helpers for the repro.server frontend.

Stdlib-only (raw sockets + SSEParser), deliberately independent of the
server's asyncio internals so tests exercise the wire format the way an
external consumer would: bytes on a TCP socket, chunk boundaries
wherever the kernel puts them. `stream()` is the blocking form used by
tests/examples; `astream()` is the asyncio form used when a test needs
many concurrent connections in one loop.
"""
from __future__ import annotations

import asyncio
import json
import socket
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.server.sse import SSEParser

Event = Tuple[str, Dict[str, Any]]


def _request_bytes(method: str, path: str, host: str,
                   body: Optional[bytes] = None,
                   ctype: str = "application/json") -> bytes:
    head = [f"{method} {path} HTTP/1.1", f"Host: {host}",
            "Connection: close"]
    if body:
        head += [f"Content-Type: {ctype}", f"Content-Length: {len(body)}"]
    return ("\r\n".join(head) + "\r\n\r\n").encode("latin-1") + (body or b"")


def _split_head(data: bytes) -> Tuple[int, Dict[str, str], bytes]:
    head, _, rest = data.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split()[1])
    headers: Dict[str, str] = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers, rest


def fetch(host: str, port: int, path: str,
          timeout: float = 10.0) -> Tuple[int, str]:
    """Blocking GET; returns (status, body_text). For /metrics, /healthz."""
    with socket.create_connection((host, port), timeout=timeout) as s:
        s.sendall(_request_bytes("GET", path, host))
        data = b""
        while True:
            chunk = s.recv(65536)
            if not chunk:
                break
            data += chunk
    status, _, body = _split_head(data)
    return status, body.decode("utf-8")


def stream(host: str, port: int, payload: Dict[str, Any],
           timeout: float = 60.0,
           max_events: Optional[int] = None) -> Iterator[Event]:
    """Open one POST /v1/stream and yield (event, data) tuples as they
    arrive. Closing the generator early closes the socket — the server
    sees the disconnect and cancels the request (what a browser tab
    closing does). `max_events` stops reading after that many events
    WITHOUT closing cleanly first, for disconnect tests."""
    body = json.dumps(payload).encode("utf-8")
    s = socket.create_connection((host, port), timeout=timeout)
    try:
        s.sendall(_request_bytes("POST", "/v1/stream", host, body))
        parser = SSEParser()
        buf = b""
        # read past the HTTP response head first
        while b"\r\n\r\n" not in buf:
            chunk = s.recv(65536)
            if not chunk:
                return
            buf += chunk
        status, _, rest = _split_head(buf)
        if status != 200:
            yield ("http_error", {"status": status,
                                  "body": rest.decode("utf-8", "replace")})
            return
        n = 0
        for ev in parser.feed(rest):
            yield ev
            n += 1
            if max_events is not None and n >= max_events:
                return
        while True:
            chunk = s.recv(65536)
            if not chunk:
                return
            for ev in parser.feed(chunk):
                yield ev
                n += 1
                if max_events is not None and n >= max_events:
                    return
    finally:
        try:
            s.close()
        except OSError:
            pass


def collect(host: str, port: int, payload: Dict[str, Any],
            timeout: float = 60.0) -> List[Event]:
    """stream() drained to a list (one whole response)."""
    return list(stream(host, port, payload, timeout=timeout))


async def astream(host: str, port: int, payload: Dict[str, Any]) -> List[Event]:
    """Asyncio variant of collect() — lets a test hold N concurrent
    streams open in one event loop."""
    body = json.dumps(payload).encode("utf-8")
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(_request_bytes("POST", "/v1/stream", host, body))
        await writer.drain()
        buf = b""
        while b"\r\n\r\n" not in buf:
            chunk = await reader.read(65536)
            if not chunk:
                return []
            buf += chunk
        status, _, rest = _split_head(buf)
        if status != 200:
            return [("http_error", {"status": status,
                                    "body": rest.decode("utf-8", "replace")})]
        parser = SSEParser()
        events = list(parser.feed(rest))
        while True:
            chunk = await reader.read(65536)
            if not chunk:
                return events
            events.extend(parser.feed(chunk))
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


__all__ = ["fetch", "stream", "collect", "astream"]
