"""Token data pipeline: synthetic corpus + packing (offline container).

Provides an infinite iterator of packed {tokens, labels} batches for the
training driver and the train_4k smoke tests. The synthetic corpus is a
Zipf-distributed token stream with injected n-gram structure so the loss
actually decreases (pure uniform noise would not train).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticCorpus:
    """Zipfian unigram stream with Markov bigram structure."""

    def __init__(self, vocab_size: int, seed: int = 0, alpha: float = 1.2,
                 bigram_strength: float = 0.7, state_size: int = 64):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.unigram = ranks ** -alpha
        self.unigram /= self.unigram.sum()
        # each token deterministically prefers a successor
        self.succ = self.rng.integers(0, vocab_size, vocab_size)
        self.p_bigram = bigram_strength

    def sample(self, n: int) -> np.ndarray:
        toks = np.empty(n, np.int64)
        toks[0] = self.rng.choice(self.vocab, p=self.unigram)
        follow = self.rng.random(n) < self.p_bigram
        indep = self.rng.choice(self.vocab, size=n, p=self.unigram)
        for i in range(1, n):
            toks[i] = self.succ[toks[i - 1]] if follow[i] else indep[i]
        return toks


def packed_batches(
    vocab_size: int,
    batch: int,
    seq_len: int,
    *,
    seed: int = 0,
    pad_id: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite {tokens, labels} iterator with next-token labels."""
    corpus = SyntheticCorpus(vocab_size, seed)
    while True:
        stream = corpus.sample(batch * (seq_len + 1))
        arr = stream.reshape(batch, seq_len + 1)
        yield {
            "tokens": arr[:, :-1].astype(np.int32),
            "labels": arr[:, 1:].astype(np.int32),
        }
