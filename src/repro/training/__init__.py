from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.data import SyntheticCorpus, packed_batches
from repro.training.optimizer import (
    OptimizerConfig,
    OptState,
    adamw_update,
    init_opt_state,
    lr_schedule,
)
from repro.training.train import build_train_step, init_train_state

__all__ = [
    "OptimizerConfig", "OptState", "adamw_update", "init_opt_state",
    "lr_schedule", "build_train_step", "init_train_state",
    "SyntheticCorpus", "packed_batches",
    "save_checkpoint", "restore_checkpoint",
]
