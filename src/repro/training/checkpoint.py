"""Checkpointing: flatten pytrees to npz with path-encoded keys."""
from __future__ import annotations

import os
from typing import Tuple

import jax
import numpy as np

SEP = "|"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, opt_state=None, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"params{SEP}{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update(
            {f"opt{SEP}{k}": v for k, v in _flatten(opt_state).items()}
        )
    payload["__step__"] = np.asarray(step)
    np.savez(path, **payload)


def restore_checkpoint(path: str, params_template, opt_template=None):
    """Restores into the given pytree templates (shape/dtype preserved)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    step = int(data["__step__"])

    def rebuild(template, prefix):
        flat_t = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for pth, leaf in flat_t[0]:
            key = prefix + SEP + SEP.join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in pth
            )
            arr = data[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(flat_t[1], leaves)

    params = rebuild(params_template, "params")
    if opt_template is None:
        return params, step
    return params, rebuild(opt_template, "opt"), step
