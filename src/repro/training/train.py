"""Train-step builders: loss + grad + AdamW update, with remat policy."""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state


def build_train_step(
    model: Model,
    opt_cfg: OptimizerConfig,
    *,
    remat: bool = True,
    microbatches: int = 1,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With remat=True each layer of the scan is rematerialized
    (nothing_saveable): only the residual-stream carry is kept per layer —
    the standard memory/compute trade that lets train_4k lower with sane
    activation memory at 400B scale (EXPERIMENTS.md §Dry-run).
    """
    if remat:
        model.remat = True      # per-layer remat inside the scan (see
                                # transformer._maybe_remat); whole-loss
                                # checkpointing saves far too much at 400B.
    loss_fn = model.loss

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            loss, grads = grads_of(params, batch)
        else:
            # gradient accumulation: scan over microbatches so only one
            # microbatch's activations are live at a time
            micro = jax.tree.map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:]), batch
            )

            def acc(carry, mb):
                loss_sum, g_acc = carry
                loss, g = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (loss_sum + loss, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, grads), _ = jax.lax.scan(
                acc, (jnp.zeros(()), g0), micro
            )
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def init_train_state(model: Model, rng, dtype=jnp.float32):
    params = model.init(rng, dtype)
    return params, init_opt_state(params)
