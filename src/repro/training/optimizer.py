"""AdamW + schedules + gradient clipping, pure JAX (no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: object      # first moment pytree
    nu: object      # second moment pytree


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptimizerConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1, b2 = cfg.betas
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = lr_schedule(cfg, step)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
