"""JAX version compatibility for Pallas TPU symbols.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
across the 0.4.x → 0.5+ drift (and older wheels only ship one of the
two names). Resolve whichever the installed version provides once, so
every kernel call site works on both sides of the rename.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
