"""Paged flash-decode: single-token attention over a physical page pool.

The physically paged counterpart of ``decode_attention.py``: the KV cache
is no longer one contiguous row per batch slot but a shared pool of
fixed-size pages, ``k_pool``/``v_pool`` of shape (P, page, KV, hd), and
each request's context is scattered across the pages its **block table**
names (ordered: table entry ``i`` holds absolute positions
``[i*page, (i+1)*page)``). This is what makes token-granular preemption
cheap — ``evict_tail`` frees real HBM rows, and admission capacity is the
physical pool — at the price of one indirection on the decode hot path.

That indirection is exactly one extra scalar-prefetch input. The grid and
the online-softmax body are identical to the contiguous kernel (which is
reused verbatim); the only change is the k/v BlockSpec index map, which
reads the block table from SMEM and DMAs tile ``ki`` of request ``b``
from pool page ``block_tables[b, ki]`` instead of from row offset
``ki * block_k``. Scalar prefetch puts the table in SMEM *before* the
grid runs, so the gather is resolved at DMA-issue time — no gather op in
the dataflow, just data-dependent tile addressing.

Sentinel entries (ids >= P, marking pages past a request's allocation)
are clamped in the index map; tiles wholly past ``length`` are dead
(``k_start < length`` fails, same skip as the contiguous kernel) so a
clamped DMA's payload is never read. ``block_k`` IS the page size here —
pages are the DMA granularity by construction. For production TPU shapes
the page size should be a multiple of the dtype's sublane tile (8 for
f32, 16 for bf16); tests run tiny pages in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import _decode_kernel
from repro.kernels.pallas_compat import CompilerParams


def _paged_decode_kernel(
    lengths_ref,                 # SMEM (B,) int32 — scalar prefetch
    bt_ref,                      # SMEM (B, max_pages) int32 — scalar prefetch
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    sm_scale: float,
    window: Optional[int],
    page_size: int,
    num_pages: int,
):
    # the block table is consumed entirely by the k/v index maps; the
    # compute body is the contiguous online-softmax kernel unchanged
    # (k_start = ki * page_size lines up because tables are ordered)
    del bt_ref
    _decode_kernel(
        lengths_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
        sm_scale=sm_scale, window=window, block_k=page_size,
        num_k_blocks=num_pages,
    )


@functools.partial(
    jax.jit,
    static_argnames=("window", "sm_scale", "interpret"),
)
def paged_decode_attention(
    q: jax.Array,             # (B, H, hd) — one new token per request
    k_pool: jax.Array,        # (P, page, KV, hd) physical page pool
    v_pool: jax.Array,        # (P, page, KV, hd)
    block_tables: jax.Array,  # (B, max_pages) int32; entries >= P = sentinel
    lengths: jax.Array,       # (B,) int32 — valid context incl. current tok
    *,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    b, h, hd = q.shape
    p_total, page, kv, _ = k_pool.shape
    assert h % kv == 0
    group = h // kv
    max_pages = block_tables.shape[1]
    scale = sm_scale if sm_scale is not None else hd ** -0.5

    # (B, H, hd) -> (B, KV, G, hd); (P, page, KV, hd) -> (P, KV, page, hd)
    qg = q.reshape(b, kv, group, hd)
    kt = k_pool.transpose(0, 2, 1, 3)
    vt = v_pool.transpose(0, 2, 1, 3)

    grid = (b, kv, max_pages)
    kernel = functools.partial(
        _paged_decode_kernel,
        sm_scale=scale,
        window=window,
        page_size=page,
        num_pages=max_pages,
    )

    def kv_map(b_, kv_, ki, len_ref, bt_ref):
        del len_ref
        # data-dependent tile address: the ki-th page of request b_.
        # Clamp sentinels (>= P) — those tiles are dead (k_start >= length)
        # so the aliased payload is never read, but the DMA must be legal.
        return (jnp.minimum(bt_ref[b_, ki], p_total - 1), kv_, 0, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, group, hd), lambda b_, kv_, ki, *_: (b_, kv_, 0, 0)
                ),
                pl.BlockSpec((1, 1, page, hd), kv_map),
                pl.BlockSpec((1, 1, page, hd), kv_map),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, group, hd), lambda b_, kv_, ki, *_: (b_, kv_, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((group, hd), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, group, hd), q.dtype),
        compiler_params=CompilerParams(
            # pages of one request chain through the online softmax, so the
            # page axis is sequential; batch and kv heads stay parallel
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        lengths.astype(jnp.int32),
        block_tables.astype(jnp.int32),
        qg,
        kt,
        vt,
    )

    return out.reshape(b, h, hd)
