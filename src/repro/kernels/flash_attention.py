"""Flash attention (prefill) as a Pallas TPU kernel.

TPU-native design notes (vs. the CUDA flash-attention the paper's baselines
use): the grid is (batch, q_heads, q_blocks, k_blocks) with the k-block axis
innermost and *sequential* ("arbitrary" dimension semantics); the online
softmax accumulator, row max and row sum live in VMEM scratch and persist
across the k-block axis. Block shapes default to (128, 128) so the
q·kᵀ and p·v contractions are MXU-shaped (128-aligned), and all tiles are
explicitly staged HBM→VMEM by BlockSpecs. GQA is handled in the k/v
index_map (query head h reads kv head h // group) so KV tiles are fetched
once per group, not repeated in HBM.

Causal + sliding-window masking is positional (iota within the tile);
fully-masked tiles are skipped with ``pl.when`` so the sequential k-axis
does no work above the diagonal.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    sm_scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    kv_len: int,
    num_k_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # A tile is live unless (a) causal and fully above the diagonal, or
    # (b) sliding window and fully left of every query's window.
    live = k_start < kv_len
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32)        # (block_q, hd)
        k = k_ref[0, 0, :, :].astype(jnp.float32)        # (block_k, hd)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                                     # (block_q, block_k)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (block_q, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)   # fully-masked rows -> zeros
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "sm_scale", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,   # (B, Sq, H, hd)
    k: jax.Array,   # (B, Sk, KV, hd)
    v: jax.Array,   # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0
    group = h // kv
    scale = sm_scale if sm_scale is not None else hd ** -0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k

    # (B, S, H, hd) -> (B, H, S, hd) so tiles are (seq, hd) planes
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    num_q_blocks = sq_p // block_q
    num_k_blocks = sk_p // block_k
    grid = (b, h, num_q_blocks, num_k_blocks)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        kv_len=sk,
        num_k_blocks=num_k_blocks,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)

    out = out.transpose(0, 2, 1, 3)
    if pad_q:
        out = out[:, :sq]
    return out
