"""Dispatch wrappers over the Pallas kernels and their XLA references.

Model code calls these entry points with an ``impl`` string:

- ``"ref"``     — pure-jnp oracle (XLA-lowered). Used on CPU, in the multi-pod
                  dry-run (cost_analysis sees native HLO), and as ground truth.
- ``"pallas"``  — the Pallas TPU kernel. On a CPU backend it runs in
                  interpret mode automatically (correctness path for tests).
- ``"chunked"`` — (scans only) chunked associative-scan in pure XLA: the
                  compile-friendly parallel form used for training/prefill at
                  scale; validated against the sequential oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _paged
from repro.kernels import selective_scan as _ss

DEFAULT_IMPL = "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention(
    q, k, v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    lengths=None,
    q_offset=None,
    sm_scale: Optional[float] = None,
    impl: str = DEFAULT_IMPL,
):
    """Prefill/train attention. q (B,Sq,H,hd), k/v (B,Sk,KV,hd)."""
    if impl == "pallas" and lengths is None and q_offset is None:
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            interpret=_interpret(),
        )
    return _ref.attention_ref(
        q, k, v, causal=causal, window=window, lengths=lengths,
        q_offset=q_offset, sm_scale=sm_scale,
    )


def decode_attention(
    q, k, v, lengths,
    *,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    impl: str = DEFAULT_IMPL,
):
    """Single-token decode attention. q (B,H,hd), k/v (B,S,KV,hd)."""
    if impl == "pallas":
        return _dec.decode_attention(
            q, k, v, lengths, window=window, sm_scale=sm_scale,
            interpret=_interpret(),
        )
    return _ref.decode_attention_ref(
        q, k, v, lengths, window=window, sm_scale=sm_scale
    )


def paged_decode_attention(
    q, k_pool, v_pool, block_tables, lengths,
    *,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    impl: str = DEFAULT_IMPL,
):
    """Single-token decode attention over a physical KV page pool.

    q (B,H,hd); k_pool/v_pool (P,page,KV,hd); block_tables (B,max_pages)
    int32 (entries >= P are sentinels past a request's allocation)."""
    if impl == "pallas":
        return _paged.paged_decode_attention(
            q, k_pool, v_pool, block_tables, lengths,
            window=window, sm_scale=sm_scale, interpret=_interpret(),
        )
    return _ref.paged_decode_attention_ref(
        q, k_pool, v_pool, block_tables, lengths,
        window=window, sm_scale=sm_scale,
    )


# ---------------------------------------------------------------------------
# Mamba-1 selective scan
# ---------------------------------------------------------------------------

def selective_scan(x, dt, A, B, C, D, *, impl: str = DEFAULT_IMPL, chunk: int = 128):
    if impl == "pallas":
        bd = 512
        d = x.shape[-1]
        while d % bd:
            bd //= 2
        ch = chunk
        while x.shape[1] % ch:
            ch //= 2
        return _ss.selective_scan(
            x, dt, A, B, C, D, chunk=ch, block_d=bd, interpret=_interpret()
        )
    if impl == "chunked":
        return _selective_scan_chunked(x, dt, A, B, C, D, chunk=chunk)
    return _ref.selective_scan_ref(x, dt, A, B, C, D)


def _selective_scan_chunked(x, dt, A, B, C, D, *, chunk: int = 128):
    """Chunked associative formulation in pure XLA.

    Within a chunk the linear recurrence h_t = a_t h_{t-1} + b_t is solved
    with `lax.associative_scan` (log-depth, vectorizes on the VPU); chunks
    are chained with a `lax.scan` carrying only the (B, D, N) boundary state.
    Peak intermediate is (B, chunk, D, N) instead of (B, S, D, N).
    """
    bsz, s, d = x.shape
    n = A.shape[1]
    while s % chunk:
        chunk //= 2
    nchunks = s // chunk

    def to_chunks(t):  # (B, S, ...) -> (nchunks, B, chunk, ...)
        return jnp.moveaxis(
            t.reshape(bsz, nchunks, chunk, *t.shape[2:]), 1, 0
        )

    xc, dtc, bc, cc = map(to_chunks, (x, dt, B, C))

    def chunk_step(h0, inputs):
        xk, dtk, bk, ck = inputs                       # (B, chunk, ...)
        dtk = dtk.astype(jnp.float32)
        da = jnp.exp(dtk[..., None] * A[None, None])   # (B, chunk, D, N)
        dbx = (dtk * xk.astype(jnp.float32))[..., None] * bk[:, :, None, :]
        # prepend carry as step 0 with a == 1? fold via first element:
        dbx = dbx.at[:, 0].add(da[:, 0] * h0)
        aa, bb = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], l[1] * r[0] + r[1]),
            (da, dbx), axis=1,
        )
        h_last = bb[:, -1]
        yk = jnp.einsum("bcdn,bcn->bcd", bb, ck.astype(jnp.float32))
        return h_last, yk

    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, d)
    y = y + x.astype(jnp.float32) * D[None, None]
    return y.astype(x.dtype)


def selective_scan_step(h, x, dt, A, B, C, D):
    """Decode-step recurrence (always XLA; it is a handful of elementwise ops)."""
    return _ref.selective_scan_step_ref(h, x, dt, A, B, C, D)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

def ssd(x, dt, A, B, C, D, *, impl: str = DEFAULT_IMPL, chunk: int = 128):
    """Mamba-2 scan. x (B,S,NH,HD), dt (B,S,NH), A (NH,), B/C (B,S,N), D (NH,)."""
    if impl in ("chunked", "pallas"):
        # The SSD chunked form is already matmul-dominant; on TPU it lowers to
        # MXU einsums directly, so the XLA chunked form *is* the TPU-native
        # kernelization (no Pallas needed — noted in DESIGN.md).
        return _ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    return _ref.ssd_ref(x, dt, A, B, C, D)


def _ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 128):
    """Chunked state-space-dual algorithm (Mamba-2), pure XLA.

    Intra-chunk: quadratic attention-like masked einsum (MXU-friendly).
    Inter-chunk: scan over chunk boundary states (B, NH, HD, N).
    """
    bsz, s, nh, hd = x.shape
    n = B.shape[-1]
    while s % chunk:
        chunk //= 2
    nchunks = s // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = B.astype(jnp.float32)
    cf = C.astype(jnp.float32)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(bsz, nchunks, chunk, *t.shape[2:]), 1, 0)

    xc, dtc, bc, cc = map(to_chunks, (xf, dtf, bf, cf))

    def chunk_step(h0, inputs):
        xk, dtk, bk, ck = inputs
        # log decay within chunk: la[t] = sum_{u<=t} dt_u * A   (B, chunk, NH)
        da = dtk * A[None, None]                       # (B, chunk, NH) (<=0)
        la = jnp.cumsum(da, axis=1)
        # intra-chunk "attention" scores: decay from u to t (u<=t)
        # L[t,u] = exp(la_t - la_u) for u<=t else 0
        diff = la[:, :, None, :] - la[:, None, :, :]   # (B, t, u, NH)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        l_mat = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("btn,bun->btu", ck, bk)        # (B, t, u)
        w = cb[..., None] * l_mat * dtk[:, None, :, :]  # (B, t, u, NH)
        y_intra = jnp.einsum("btuh,buhd->bthd", w, xk)
        # contribution of the carried state
        decay0 = jnp.exp(la)                            # (B, t, NH)
        y_carry = jnp.einsum(
            "btn,bhdn,bth->bthd", ck, h0, decay0
        )
        # new boundary state
        decay_to_end = jnp.exp(la[:, -1:, :] - la)      # (B, u, NH)
        h_upd = jnp.einsum(
            "bun,buhd,buh->bhdn", bk, xk * dtk[..., None], decay_to_end
        )
        h_next = jnp.exp(la[:, -1])[..., None, None] * h0 + h_upd
        return h_next, y_intra + y_carry

    h0 = jnp.zeros((bsz, nh, hd, n), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, nh, hd)
    y = y + xf * D[None, None, :, None]
    return y.astype(x.dtype)


def ssd_step(h, x, dt, A, B, C, D):
    return _ref.ssd_step_ref(h, x, dt, A, B, C, D)
