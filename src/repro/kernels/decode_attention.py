"""Flash-decode: single-token attention against a static-slot KV cache.

This is the serving hot loop the Andes scheduler drives — every decode
iteration of every running request lands here. TPU-native shape: queries for
one request are reshaped to (KV, G, hd) where G = q_heads / kv_heads, so the
per-tile contraction is (G, hd) x (hd, block_k) — the GQA group becomes the
MXU's M dimension rather than a HBM-side KV replication. The KV sequence is
the innermost, *sequential* grid axis; online-softmax state (acc, row max,
row sum) persists in VMEM scratch.

Per-request cache lengths arrive via scalar prefetch (SMEM) so tiles wholly
past a request's length are skipped before their DMA result is used —
continuous batching means lengths are ragged across the batch, and this is
where the "token-granular accounting" of the scheduler meets the kernel.

Sliding window (``window``) implements the long-context decode variant:
only the last `window` cache positions are attended, making decode cost
O(window) instead of O(context) — the sub-quadratic path used by the
``long_500k`` shape for attention archs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(
    lengths_ref,                 # SMEM (B,) int32 — scalar prefetch
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    sm_scale: float,
    window: Optional[int],
    block_k: int,
    num_k_blocks: int,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    k_start = ki * block_k
    live = k_start < length
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k > length - window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32)        # (G, hd)
        k = k_ref[0, 0, :, :].astype(jnp.float32)        # (block_k, hd)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                                     # (G, block_k)

        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < length
        if window is not None:
            mask &= k_pos > length - 1 - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "sm_scale", "block_k", "interpret"),
)
def decode_attention(
    q: jax.Array,          # (B, H, hd) — one new token per request
    k: jax.Array,          # (B, S, KV, hd)
    v: jax.Array,          # (B, S, KV, hd)
    lengths: jax.Array,    # (B,) int32 — valid cache length incl. current tok
    *,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, hd = q.shape
    _, s, kv, _ = k.shape
    assert h % kv == 0
    group = h // kv
    scale = sm_scale if sm_scale is not None else hd ** -0.5

    block_k = min(block_k, s)
    pad_k = (-s) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    s_p = s + pad_k
    num_k_blocks = s_p // block_k

    # (B, H, hd) -> (B, KV, G, hd); (B, S, KV, hd) -> (B, KV, S, hd)
    qg = q.reshape(b, kv, group, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, kv, num_k_blocks)
    kernel = functools.partial(
        _decode_kernel,
        sm_scale=scale,
        window=window,
        block_k=block_k,
        num_k_blocks=num_k_blocks,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, group, hd), lambda b_, kv_, ki, *_: (b_, kv_, 0, 0)
                ),
                pl.BlockSpec(
                    (1, 1, block_k, hd), lambda b_, kv_, ki, *_: (b_, kv_, ki, 0)
                ),
                pl.BlockSpec(
                    (1, 1, block_k, hd), lambda b_, kv_, ki, *_: (b_, kv_, ki, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, group, hd), lambda b_, kv_, ki, *_: (b_, kv_, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((group, hd), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, group, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)

    return out.reshape(b, h, hd)
