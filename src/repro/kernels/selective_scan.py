"""Mamba-1 selective scan as a Pallas TPU kernel.

The GPU Mamba kernel is a fused warp-level scan; the TPU analogue is a
*chunked* scan: the grid is (batch, d_inner blocks, seq chunks) with the
chunk axis innermost and sequential, and the recurrent state h
(block_d, N) lives in VMEM scratch, persisting across chunks. Each chunk's
inputs (x, dt, B, C tiles) are staged HBM→VMEM by BlockSpecs; within the
chunk the recurrence runs as a `fori_loop` over time steps on the VPU
(elementwise exp/mul/add) with the (block_d, N) state resident in VMEM —
there is no HBM traffic for h at all, which is the entire point of the
paper-adjacent Mamba "hardware-aware" scan, re-expressed for the TPU memory
hierarchy instead of CUDA shared memory.

block_d defaults to 512 lanes so the (block_d, N=16) state tile is
(512, 16) fp32 = 32 KiB — comfortably VMEM-resident alongside the chunk
tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _scan_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref,
    h_ref,
    *,
    chunk: int,
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                    # (block_d, N)
    d_skip = d_ref[...].astype(jnp.float32)               # (1, block_d)

    def step(t, h):
        x_t = x_ref[0, t, :].astype(jnp.float32)          # (block_d,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)        # (block_d,)
        b_t = b_ref[0, t, :].astype(jnp.float32)          # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)          # (N,)
        da = jnp.exp(dt_t[:, None] * a)                   # (block_d, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1) + d_skip[0] * x_t
        o_ref[0, t, :] = y_t.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_d", "interpret")
)
def selective_scan(
    x: jax.Array,    # (B, S, D)
    dt: jax.Array,   # (B, S, D)
    A: jax.Array,    # (D, N)
    B: jax.Array,    # (B, S, N)
    C: jax.Array,    # (B, S, N)
    D: jax.Array,    # (D,)
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bsz, s, d = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    block_d = min(block_d, d)
    assert s % chunk == 0, (s, chunk)
    assert d % block_d == 0, (d, block_d)

    grid = (bsz, d // block_d, s // chunk)
    kernel = functools.partial(_scan_kernel, chunk=chunk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, di, si: (b_, si, di)),
            pl.BlockSpec((1, chunk, block_d), lambda b_, di, si: (b_, si, di)),
            pl.BlockSpec((block_d, n), lambda b_, di, si: (di, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, di, si: (b_, si, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, di, si: (b_, si, 0)),
            pl.BlockSpec((1, block_d), lambda b_, di, si: (0, di)),
        ],
        out_specs=pl.BlockSpec(
            (1, chunk, block_d), lambda b_, di, si: (b_, si, di)
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, B, C, D.reshape(1, d))

    return out
