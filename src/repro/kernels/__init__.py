"""Pallas TPU kernels for the serving hot-spots + pure-jnp oracles.

- flash_attention.py — prefill attention (BlockSpec-tiled, causal/GQA/window)
- decode_attention.py — flash-decode (scalar-prefetch ragged lengths)
- selective_scan.py — chunked Mamba-1 scan (VMEM-resident state)
- ops.py — jit'd dispatch wrappers (impl="ref" | "pallas" | "chunked")
- ref.py — the oracles every kernel is validated against (interpret mode)
"""
