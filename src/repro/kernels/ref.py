"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each Pallas kernel's test sweeps
shapes/dtypes and asserts allclose against the function here. They are also
the implementation used on non-TPU backends and for the multi-pod dry-run
(XLA lowers them natively, which is what ``cost_analysis`` should see).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating KV heads (GQA)."""
    b, s, kv, hd = k.shape
    if kv == num_q_heads:
        return k
    assert num_q_heads % kv == 0, (num_q_heads, kv)
    return jnp.repeat(k, num_q_heads // kv, axis=2)


def attention_ref(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,            # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    q_offset: Optional[jax.Array] = None,   # (B,) absolute pos of q[0]
    lengths: Optional[jax.Array] = None,    # (B,) valid kv length
    window: Optional[int] = None,           # sliding window size
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Reference multi-head attention with GQA, causality, per-request
    lengths and an optional sliding window. Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = sm_scale if sm_scale is not None else (hd ** -0.5)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale

    kv_pos = jnp.arange(sk)[None, None, None, :]                 # (1,1,1,Sk)
    if q_offset is None:
        q_pos = jnp.arange(sq)
        q_pos = q_pos[None, None, :, None] + jnp.zeros((b, 1, 1, 1), q_pos.dtype)
    else:
        q_pos = q_offset[:, None, None, None] + jnp.arange(sq)[None, None, :, None]
    mask = jnp.ones(logits.shape, dtype=bool)
    if causal:
        mask &= kv_pos <= q_pos
    if lengths is not None:
        mask &= kv_pos < lengths[:, None, None, None]
    if window is not None:
        mask &= kv_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def decode_attention_ref(
    q: jax.Array,            # (B, H, hd) — single new token per request
    k: jax.Array,            # (B, S, KV, hd) KV cache
    v: jax.Array,            # (B, S, KV, hd)
    lengths: jax.Array,      # (B,) tokens already in cache (incl. current)
    *,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Single-step decode attention against a static-slot KV cache."""
    out = attention_ref(
        q[:, None],
        k,
        v,
        causal=False,
        lengths=lengths,
        q_offset=lengths - 1,
        window=window,
        sm_scale=sm_scale,
    )
    return out[:, 0]


def paged_decode_attention_ref(
    q: jax.Array,             # (B, H, hd) — single new token per request
    k_pool: jax.Array,        # (P, page, KV, hd) physical page pool
    v_pool: jax.Array,        # (P, page, KV, hd)
    block_tables: jax.Array,  # (B, max_pages) int32 page ids; >= P = sentinel
    lengths: jax.Array,       # (B,) tokens in cache (incl. current)
    *,
    window: Optional[jax.Array] = None,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Decode attention over a physically paged KV pool, jnp oracle.

    Gathers each request's pages back into a contiguous (B, S', KV, hd)
    view (S' = max_pages * page) and defers to `decode_attention_ref`.
    Sentinel table entries are clamped before the gather; whatever rows
    they alias are masked out by `lengths` (a request's block table always
    covers ceil(length / page) real pages, so every attended position maps
    to a page the request owns). When S' equals the contiguous cache depth
    the result is bit-identical to `decode_attention_ref` on the
    equivalent contiguous cache: masked positions contribute exact zeros
    (exp(NEG_INF - m) underflows to 0.0) and the reduction shapes match —
    the degenerate-oracle engine differentials rely on this.
    """
    b = q.shape[0]
    p_total, page = k_pool.shape[0], k_pool.shape[1]
    bt = jnp.minimum(block_tables, p_total - 1)
    n_pages = bt.shape[1]
    k = k_pool[bt].reshape(b, n_pages * page, *k_pool.shape[2:])
    v = v_pool[bt].reshape(b, n_pages * page, *v_pool.shape[2:])
    return decode_attention_ref(
        q, k, v, lengths, window=window, sm_scale=sm_scale
    )


def selective_scan_ref(
    x: jax.Array,      # (B, S, D)   — D = d_inner
    dt: jax.Array,     # (B, S, D)   — softplus'd timestep
    A: jax.Array,      # (D, N)      — negative (continuous-time)
    B: jax.Array,      # (B, S, N)
    C: jax.Array,      # (B, S, N)
    D: jax.Array,      # (D,)
) -> jax.Array:
    """Mamba-1 selective scan, sequential oracle.

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t;   y_t = C_t . h_t + D*x_t
    Returns (B, S, D).
    """
    bsz, s, d = x.shape
    n = A.shape[1]

    def step(h, inputs):
        x_t, dt_t, b_t, c_t = inputs            # (B,D) (B,D) (B,N) (B,N)
        dA = jnp.exp(dt_t[..., None] * A[None])             # (B, D, N)
        dBx = dt_t[..., None] * b_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(B, 1, 0).astype(jnp.float32),
        jnp.moveaxis(C, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D[None, None]
    return y.astype(x.dtype)


def selective_scan_step_ref(
    h: jax.Array,      # (B, D, N) carried state
    x: jax.Array,      # (B, D)
    dt: jax.Array,     # (B, D)
    A: jax.Array,      # (D, N)
    B: jax.Array,      # (B, N)
    C: jax.Array,      # (B, N)
    D: jax.Array,      # (D,)
):
    """One decode step of the Mamba-1 recurrence. Returns (h', y)."""
    dA = jnp.exp(dt[..., None] * A[None])
    h = dA * h + dt[..., None] * B[:, None, :] * x[..., None]
    y = jnp.einsum("bdn,bn->bd", h, C) + x * D[None]
    return h, y


def ssd_ref(
    x: jax.Array,      # (B, S, NH, HD)
    dt: jax.Array,     # (B, S, NH)  — softplus'd
    A: jax.Array,      # (NH,)       — negative scalar per head
    B: jax.Array,      # (B, S, N)
    C: jax.Array,      # (B, S, N)
    D: jax.Array,      # (NH,)
) -> jax.Array:
    """Mamba-2 state-space-dual recurrence, sequential oracle.

    Per head: h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T,
    y_t = h_t C_t + D x_t.   Returns (B, S, NH, HD).
    """
    bsz, s, nh, hd = x.shape
    n = B.shape[-1]

    def step(h, inputs):
        x_t, dt_t, b_t, c_t = inputs   # (B,NH,HD) (B,NH) (B,N) (B,N)
        da = jnp.exp(dt_t * A[None])                        # (B, NH)
        dbx = dt_t[..., None, None] * x_t[..., None] * b_t[:, None, None, :]
        h = da[..., None, None] * h + dbx                   # (B,NH,HD,N)
        y = jnp.einsum("bhdn,bn->bhd", h, c_t)
        return h, y

    h0 = jnp.zeros((bsz, nh, hd, n), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(B, 1, 0).astype(jnp.float32),
        jnp.moveaxis(C, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype)


def ssd_step_ref(h, x, dt, A, B, C, D):
    """One decode step of the Mamba-2 recurrence.

    h (B,NH,HD,N), x (B,NH,HD), dt (B,NH), A (NH,), B/C (B,N), D (NH,).
    Returns (h', y) with y (B,NH,HD)."""
    da = jnp.exp(dt * A[None])
    h = da[..., None, None] * h + dt[..., None, None] * x[..., None] * B[:, None, None, :]
    y = jnp.einsum("bhdn,bn->bhd", h, C) + x * D[None, :, None]
    return h, y
