"""Falcon-Mamba 7B — attention-free Mamba-1. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    kind="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
    source="arXiv:2410.05355",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-smoke",
        kind="ssm",
        num_layers=2,
        d_model=256,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
        source="arXiv:2410.05355",
    )
