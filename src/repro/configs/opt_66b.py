"""OPT-66B — the paper's own evaluation model family. [arXiv:2205.01068]

Used by the faithful-reproduction benchmarks (latency-model calibration in
the simulator mirrors Table 3's 4xA100 deployment, mapped to TPU v5e chips).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-66b",
    kind="dense",
    num_layers=64,
    d_model=9216,
    num_heads=72,
    num_kv_heads=72,
    d_ff=36864,
    vocab_size=50272,
    gated_mlp=False,
    source="arXiv:2205.01068",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="opt-66b-smoke",
        kind="dense",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        source="arXiv:2205.01068",
    )
