"""Qwen-1.5 MoE A2.7B — 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    kind="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    moe=MoEConfig(num_experts=60, num_shared_experts=4, top_k=4, d_expert=1408),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke",
        kind="moe",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2, d_expert=128),
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )
