"""SeamlessM4T-medium — enc-dec multimodal (audio) backbone. [arXiv:2308.11596]

12 encoder + 12 decoder layers, d_model=1024, 16 heads, d_ff=4096,
vocab 256206. Audio frontend (mel + conv) is a stub: ``input_specs`` feeds
precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    kind="audio",
    num_layers=12,
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    source="arXiv:2308.11596",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        kind="audio",
        num_layers=2,
        num_encoder_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        frontend="audio",
        source="arXiv:2308.11596",
    )
