"""Zamba2 2.7B — Mamba-2 backbone + shared attention blocks. [arXiv:2411.15242]

54 Mamba2 layers with a shared (weight-tied) attention+MLP block applied
every 6 layers. MHA kv=32. ssm_state=64.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    kind="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, version=2, headdim=64),
    hybrid_attn_every=6,
    hybrid_shared_attn=True,
    source="arXiv:2411.15242",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        kind="hybrid",
        num_layers=4,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=2, headdim=64),
        hybrid_attn_every=2,
        hybrid_shared_attn=True,
        source="arXiv:2411.15242",
    )
