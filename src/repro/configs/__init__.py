from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    get_config,
    get_shape,
    get_smoke_config,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeConfig",
    "get_config",
    "get_shape",
    "get_smoke_config",
]
