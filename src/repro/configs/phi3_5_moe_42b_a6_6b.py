"""Phi-3.5-MoE 42B (A6.6B) — 16 experts top-2, GQA.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    kind="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(num_experts=16, num_shared_experts=0, top_k=2, d_expert=6400),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke",
        kind="moe",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, num_shared_experts=0, top_k=2, d_expert=128),
        source="hf:microsoft/Phi-3.5-MoE-instruct",
    )
