"""Pixtral-12B — ViT frontend (stub) + Mistral-NeMo-style dense decoder.
[hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    kind="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1000000.0,
    frontend="vision",
    source="hf:mistralai/Pixtral-12B-2409",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke",
        kind="vlm",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        frontend="vision",
        source="hf:mistralai/Pixtral-12B-2409",
    )
