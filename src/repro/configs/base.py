"""Configuration system: model architecture configs, input shapes, registry.

Every assigned architecture gets a module in this package defining a
``CONFIG`` (full production scale, exercised only via the dry-run) and a
``smoke_config()`` (reduced variant of the same family for CPU tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

ARCH_KINDS = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0     # always-on experts
    top_k: int = 0
    d_expert: int = 0               # per-expert FFN hidden size
    router_aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                 # d_inner = expand * d_model
    version: int = 1                # 1 = Mamba (selective scan), 2 = Mamba2 (SSD)
    headdim: int = 64               # Mamba2 head dim
    chunk: int = 256                # Mamba2 chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering all assigned families."""

    name: str
    kind: str                       # one of ARCH_KINDS
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    gated_mlp: bool = True          # SwiGLU (3 mats) vs GeLU (2 mats)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    max_position: int = 1 << 20
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: indices of layers that are attention blocks (shared weights if
    # hybrid_shared_attn); everything else is an SSM block.
    hybrid_attn_every: int = 0      # 0 = not hybrid
    hybrid_shared_attn: bool = False
    # enc-dec
    num_encoder_layers: int = 0
    # sliding-window used by long-context serve variant (and zamba2 long mode)
    sliding_window: int = 8192
    # modality frontend stub (audio frames / vision patches)
    frontend: Optional[str] = None  # None | "audio" | "vision"
    source: str = ""                # citation

    def __post_init__(self):
        assert self.kind in ARCH_KINDS, self.kind
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.kind == "ssm"

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    def attn_layer_ids(self) -> Tuple[int, ...]:
        """Layers that carry attention (for hybrid archs)."""
        if self.kind == "ssm":
            return ()
        if self.hybrid_attn_every:
            return tuple(
                i for i in range(self.num_layers)
                if (i + 1) % self.hybrid_attn_every == 0
            )
        return tuple(range(self.num_layers))

    def ssm_layer_ids(self) -> Tuple[int, ...]:
        if self.kind == "ssm":
            return tuple(range(self.num_layers))
        if self.hybrid_attn_every:
            attn = set(self.attn_layer_ids())
            return tuple(i for i in range(self.num_layers) if i not in attn)
        return ()

    # ---- parameter counting (used by roofline + latency model) -------------
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes appended per generated/context token (per request)."""
        n_attn = len(self.attn_layer_ids())
        if self.kind == "encdec":
            n_attn = self.num_layers  # decoder self-attn layers
        kv_heads = max(self.num_kv_heads, 1)
        return 2 * n_attn * kv_heads * self.head_dim * dtype_bytes

    def ssm_state_bytes(self, dtype_bytes: int = 4) -> int:
        """Constant per-request recurrent state (Mamba layers)."""
        n_ssm = len(self.ssm_layer_ids())
        if not n_ssm or not self.ssm:
            return 0
        conv = self.d_inner * self.ssm.d_conv
        if self.ssm.version == 2:
            nheads = self.d_inner // self.ssm.headdim
            scan = nheads * self.ssm.headdim * self.ssm.d_state
            conv = (self.d_inner + 2 * self.ssm.d_state) * self.ssm.d_conv
        else:
            scan = self.d_inner * self.ssm.d_state
        return n_ssm * (scan + conv) * dtype_bytes


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    embed = cfg.vocab_size * d
    lm_head = 0 if cfg.tie_embeddings else cfg.vocab_size * d
    total = embed + lm_head + d  # final norm

    def attn_params() -> int:
        hd = cfg.head_dim
        q = d * cfg.num_heads * hd
        kv = 2 * d * cfg.num_kv_heads * hd
        o = cfg.num_heads * hd * d
        bias = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd if cfg.qkv_bias else 0
        return q + kv + o + bias + 2 * d  # 2 norms per block

    def mlp_params(d_ff: int) -> int:
        return (3 if cfg.gated_mlp else 2) * d * d_ff  # SwiGLU vs GeLU

    def moe_params() -> int:
        m = cfg.moe
        router = d * m.num_experts
        shared = m.num_shared_experts * mlp_params(m.d_expert)
        if active_only:
            routed = m.top_k * mlp_params(m.d_expert)
        else:
            routed = m.num_experts * mlp_params(m.d_expert)
        return router + shared + routed

    def ssm_params() -> int:
        s = cfg.ssm
        di = cfg.d_inner
        if s.version == 2:
            nheads = di // s.headdim
            in_proj = d * (2 * di + 2 * s.d_state + nheads)
            conv = (di + 2 * s.d_state) * s.d_conv
            extra = nheads * 2 + di  # A_log, D(per head), norm-ish
        else:
            in_proj = d * 2 * di
            conv = di * s.d_conv
            dt_rank = max(d // 16, 1)
            extra = di * (s.d_state * 2 + dt_rank) + dt_rank * di + di * 2
        out_proj = di * d
        return in_proj + conv + extra + out_proj + d  # + norm

    n_attn = len(cfg.attn_layer_ids())
    n_ssm = len(cfg.ssm_layer_ids())
    if cfg.kind == "moe":
        total += n_attn * (attn_params() + moe_params())
    elif cfg.kind == "ssm":
        total += n_ssm * ssm_params()
    elif cfg.kind == "hybrid":
        total += n_ssm * ssm_params()
        attn_blocks = 2 if cfg.hybrid_shared_attn else n_attn
        total += attn_blocks * (attn_params() + mlp_params(cfg.d_ff))
    elif cfg.kind in ("encdec", "audio"):
        # encoder layers: self-attn + mlp; decoder: self + cross + mlp
        enc = cfg.num_encoder_layers * (attn_params() + mlp_params(cfg.d_ff))
        dec = cfg.num_layers * (2 * attn_params() + mlp_params(cfg.d_ff))
        total += enc + dec
    else:  # dense, vlm
        total += n_attn * (attn_params() + mlp_params(cfg.d_ff))
    return int(total)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    phase: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "seamless-m4t-medium",
    "falcon-mamba-7b",
    "qwen2-moe-a2.7b",
    "llama3-405b",
    "granite-3-2b",
    "qwen1.5-4b",
    "llama3-8b",
    "pixtral-12b",
    "phi3.5-moe-42b-a6.6b",
    "zamba2-2.7b",
    # the paper's own evaluation family
    "opt-66b",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MOD:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MOD)}")
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.smoke_config()


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]
