"""QoE pricing: ONE implementation of the paper's marginal-gain math.

Before this module existed, the Eq. 2 vocabulary — "what is the QoE value
of serving request i (at rate r, after delay d), against letting it wait?"
— was re-derived in four places: the scheduler knapsack (§4, per-batch
gains), the fleet router (per-placement gains), admission control
(gain-vs-threshold), and the autoscaler (SLO-attainment signal). Each
carried its own copy of the response-length estimator l̂ and the
serve-delay model, which is exactly how the copies drift apart.

Now every consumer prices through this module:

  * `QoEPricer` — bound to one scheduler (its LatencyModel, KV capacity
    M, and running l̂ estimate). The scheduler's knapsack calls
    `batch_pricing`/`serve_gains`; the router and admission controller
    call `placement_gain` for the fleet-level marginal gain of placing a
    request on a replica. Speculative replicas need no special-casing:
    the pricer asks the scheduler's LatencyModel for every pacing
    quantity, and a `SpeculativeLatencyModel` answers with expected
    1..k+1-token bursts folded in.
  * `SLOContract` — a per-tenant service contract (TTFT/TDS targets, the
    QoE floor that counts as "attained", and an attainment weight).
    Replaces the uniform admission threshold: admission prices the
    newcomer's QoE at `weight ×` its fleet value, and the autoscaler's
    attainment signal weighs each finished request by its contract.
    A request without a contract prices exactly as before (weight 1.0,
    fleet-default floor) — the PR 1 uniform-threshold behavior is the
    `DEFAULT_CONTRACT` special case, bit-for-bit (tests/test_api.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.qoe import predict_request_qoe
from repro.core.request import Request, ReqState


# ---------------------------------------------------------------------------
# Per-tenant SLO contracts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLOContract:
    """A tenant's service contract, priced fleet-wide.

    ttft_target / tds_target: hard attainment targets layered on top of
    the QoE floor (None = only the floor counts). qoe_floor: per-request
    QoE at or above which the request counts as attained (None = the
    fleet default, e.g. AutoscalerConfig.slo_threshold). weight: how much
    this tenant's QoE is worth in fleet pricing — admission admits a
    weight-w request iff  w·Q̂_new − Σ degradation > min_gain, and the
    autoscaler's attainment signal is the weight-w mean. weight=1.0 with
    no targets reproduces the pre-contract uniform behavior exactly.
    """
    ttft_target: Optional[float] = None   # seconds (None = not contracted)
    tds_target: Optional[float] = None    # tokens/s (None = not contracted)
    qoe_floor: Optional[float] = None     # None = fleet default threshold
    weight: float = 1.0                   # attainment / pricing weight


DEFAULT_CONTRACT = SLOContract()


def request_weight(req: Request) -> float:
    """Pricing weight of a request: its contract weight scaled by the
    priority class (class p counts (1+p)×; the default class 0 is the
    exact identity, so uncontracted traffic prices as before)."""
    w = req.contract.weight if req.contract is not None else 1.0
    return w * (1 + req.priority)


def request_weights(reqs: Sequence[Request]) -> np.ndarray:
    return np.array([request_weight(r) for r in reqs], np.float64)


def slo_attained(req: Request, default_floor: float) -> bool:
    """Did a finished request meet its contract (or the fleet default)?"""
    c = req.contract
    floor = default_floor if c is None or c.qoe_floor is None else c.qoe_floor
    ok = req.final_qoe() >= floor
    if c is not None and c.ttft_target is not None:
        ok = ok and req.final_ttft() <= c.ttft_target
    if c is not None and c.tds_target is not None:
        ok = ok and req.final_tds() >= c.tds_target
    return bool(ok)


def weighted_attainment(reqs: Sequence[Request], default_floor: float) -> float:
    """Contract-weighted SLO attainment (the autoscaler's feedback signal,
    §6.1 fleet-wide). With no contracts every weight is 1.0 and this is
    the plain fraction of requests at or above `default_floor`."""
    if not reqs:
        return 1.0
    w = request_weights(reqs)
    att = np.array([slo_attained(r, default_floor) for r in reqs], np.float64)
    return float((w * att).sum() / w.sum())


# ---------------------------------------------------------------------------
# Shared estimators (the formulas that used to be copy-pasted)
# ---------------------------------------------------------------------------

def expected_len(emitted: np.ndarray, mean_out: float,
                 min_remaining: float) -> np.ndarray:
    """l̂ per live request: emitted + max(E[len] − emitted, floor).
    (Eq. 1 caps the expected curve at l; the true l is unknown online.)"""
    return emitted + np.maximum(mean_out - emitted, min_remaining)


def expected_new_len(mean_out: float, min_remaining: float) -> float:
    """Scalar l̂ for a request that has not emitted anything yet."""
    return max(mean_out, min_remaining)


def shared_token_rate(
    lat,
    n_live: int,
    total_ctx: float,
    kv_capacity: int,
    state_equiv_tokens: int = 0,
) -> float:
    """Memory-capped, time-shared per-request decode rate (tokens/s).

    A replica with more live requests than fit in KV memory cannot decode
    them concurrently — the scheduler time-shares. The sustainable batch is
    capped by memory (b_mem = M / avg KV weight); the aggregate token rate
    at that batch is then split across *all* live requests. This is what
    makes the marginal cost of one more request real on a saturated
    replica (naive rate(b) vs rate(b+1) is near-zero at large b, which
    would admit forever — the tragedy of the commons the admission
    controller exists to prevent).
    """
    if n_live <= 0:
        return 0.0
    avg_ctx = total_ctx / n_live
    avg_w = state_equiv_tokens if state_equiv_tokens else avg_ctx
    b_mem = max(int(kv_capacity / max(avg_w, 1.0)), 1)
    b_eff = min(n_live, b_mem)
    agg = b_eff / lat.iter_latency(b_eff, int(b_eff * avg_ctx))
    return agg / n_live


# ---------------------------------------------------------------------------
# Fleet-level placement pricing (router + admission)
# ---------------------------------------------------------------------------

def placement_components(
    replica,
    req: Request,
    now: float,
    *,
    horizon: float,
    min_remaining_est: float,
) -> Tuple[float, float]:
    """(Q̂_new, degradation) of placing `req` on `replica` now.

    Q̂_new is the newcomer's predicted fluid QoE over the horizon and the
    degradation is Σ_live w_i·(Q̂_without − Q̂_with) across the replica's
    live requests — each victim's loss priced at its own contract weight
    (the same fleet objective serve_gains and weighted_attainment use;
    all-default weights multiply by exactly 1.0). Two harm channels are
    priced:

      * rate sharing — one more mouth shares the memory-capped token
        supply (shared_token_rate). Thanks to the paper's central slack
        (generation speed ≫ digest speed) this alone rarely hurts;
      * queueing — the newcomer's KV footprint pushes back the start time
        of every *waiting* request. Per-request the extra delay is tiny,
        but summed over a deep queue it outweighs the newcomer's own
        achievable QoE. This is the term that turns the gain negative
        under surge and makes admission control bite.
    """
    lat = replica.lat
    live = replica.live
    committed = replica.committed()      # live + routed-but-not-yet-admitted
    b = len(committed)
    ctx = sum(r.context_len for r in committed)
    t = max(now, replica.clock)
    dt = horizon
    mean_out = replica.backend.sched.mean_output_len
    st = replica.backend.sched.cfg.state_equiv_tokens
    M = replica.kv_capacity

    exp_new = expected_new_len(mean_out, min_remaining_est)
    demand = replica.kv_demand()
    footprint = req.kv_tokens(st) + (0 if st else int(exp_new))

    rate1 = shared_token_rate(lat, b + 1, ctx + req.prompt_len, M, st)
    # KV-overcommit queueing delay before a waiting request starts: excess
    # demand has to drain (at the aggregate token rate) before its KV fits
    wait1 = max(demand + footprint - M, 0) / max(rate1 * (b + 1), 1e-9)
    # prefill serialization: every committed-but-unprefilled request blocks
    # the engine for its prefill before the newcomer's can run (§2.2; on a
    # chunked-prefill engine the blocking is per chunk rather than per
    # prompt, but the total backlog drained ahead of the newcomer is the
    # same order — the monolithic sum stays the routing-level estimate).
    # During a burst this is the *leading* congestion
    # signal — KV and rate terms only move once damage is already done —
    # and it is hardware-aware (slow chips prefill slower).
    prefill_backlog = sum(
        lat.prefill_latency(r.context_len)
        for r in committed if not r.prefilled
    )

    # -- degradation of the replica's live requests -------------------------
    # (pending requests contribute to load above but have no fluid slot yet,
    # so only live requests enter the degradation sum)
    degradation = 0.0
    if live:
        rate0 = shared_token_rate(lat, b, ctx, M, st)
        wait0 = max(demand - M, 0) / max(rate0 * b, 1e-9)
        # compact copy of just the live slots (slots are grow-only; cloning
        # the full state would make routing O(total requests) per query)
        idx = np.array([r.fluid_idx for r in live])
        fluid = replica.fluid.clone_slots(idx)
        waiting = np.array([r.state != ReqState.RUNNING for r in live])
        e_len = expected_len(fluid.emitted, mean_out, min_remaining_est)
        d0 = np.where(waiting, wait0, 0.0)
        d1 = np.where(waiting, wait1, 0.0)
        q0 = fluid.predict_qoe(t, dt, rate0, delay=d0, exp_len=e_len)
        q1 = fluid.predict_qoe(t, dt, rate1, delay=d1, exp_len=e_len)
        degradation = float(np.sum(request_weights(live) * (q0 - q1)))

    # -- the newcomer's own predicted QoE -----------------------------------
    # The request's QoE clock runs from its *arrival* (Eq. 1), not from
    # this routing instant: a deferred request re-enters with dead time on
    # the clock, which must lower its achievable QoE here — otherwise every
    # retry would be re-scored as fresh and over-admitted. Shifting both
    # the delay and the horizon by `age` evaluates the same Eq. 1 window
    # [arrival, arrival + age + Δt] with delivery starting at age + delay.
    age = max(t - req.arrival, 0.0)
    delay = wait1 + prefill_backlog + lat.prefill_latency(req.prompt_len)
    q_new = predict_request_qoe(req.spec, age + delay, rate1, age + dt,
                                exp_new)
    return q_new, degradation


def placement_gain(
    replica,
    req: Request,
    now: float,
    *,
    horizon: float,
    min_remaining_est: float,
    weight: float = 1.0,
) -> float:
    """Predicted fleet QoE change of placing `req` on `replica` now:

      gain = weight · Q̂_new  −  Σ_live w_i · degradation_i

    On an idle replica gain ≈ weight (full QoE, nobody hurt); on a
    saturated one it goes negative — the admission controller's shed
    signal. `weight` is the request's contract/priority pricing weight
    (request_weight); 1.0 — the no-contract default — reproduces the
    uniform PR 1 gain exactly.
    """
    q_new, degradation = placement_components(
        replica, req, now, horizon=horizon,
        min_remaining_est=min_remaining_est,
    )
    return weight * q_new - degradation


# ---------------------------------------------------------------------------
# Batch pricing (the scheduler knapsack's face of the pricer)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchPricing:
    """Per-iteration pricing state shared across candidate batch sizes."""
    idx: np.ndarray          # live -> fluid slot indices
    exp_len: np.ndarray      # l̂ per fluid slot
    q_wait: np.ndarray       # Q_wait per live request (rate 0)
    q_now: np.ndarray        # current fluid QoE per live request
    delays_slot: np.ndarray  # serve delay per fluid slot
    weights: np.ndarray      # contract/priority pricing weight per live req
    mean_ctx: float          # mean context length across live requests

    def summary(self) -> dict:
        """Compact JSON-able view of the iteration's pricing inputs — the
        payload the scheduler attaches to its `schedule` observability
        events (repro.obs), so a trace records *why* a knapsack decision
        was taken without carrying full per-request arrays."""
        return {
            "q_wait_mean": float(self.q_wait.mean()) if self.q_wait.size
            else 0.0,
            "q_now_mean": float(self.q_now.mean()) if self.q_now.size
            else 0.0,
            "mean_ctx": float(self.mean_ctx),
            "total_weight": float(self.weights.sum()),
        }


class QoEPricer:
    """The one QoE-pricing surface, bound to a scheduler.

    Reads the scheduler's LatencyModel, KV capacity and running l̂
    estimate *through* the scheduler (live references — backend factories
    legitimately re-point `sched.lat`/`sched.M` after construction, e.g.
    `speculative_backend` installs a SpeculativeLatencyModel; the pricer
    must follow). Consumers:

      scheduler  — batch_pricing() once per iteration + serve_gains()
                   per candidate batch size B (the knapsack item values)
      router     — placement_gain() per (replica, request) placement
      admission  — the same placement_gain(), contract-weighted
      autoscaler — weighted_attainment() over finished requests
    """

    def __init__(self, sched):
        self.sched = sched

    # live views through the owning scheduler
    @property
    def lat(self):
        return self.sched.lat

    @property
    def kv_capacity(self) -> int:
        return self.sched.M

    @property
    def mean_output_len(self) -> float:
        return self.sched.mean_output_len

    def serve_delay(self, r: Request) -> float:
        """Time before tokens start flowing if we serve this request.
        On a chunked-prefill backend (cfg.prefill_chunk) a partially
        prefilled resident is priced like any other request: by the
        chunks it still owes before its first token can flow — the
        knapsack sees an honest TTFT, not the RUNNING-state zero."""
        chunk = self.sched.cfg.prefill_chunk
        if r.state == ReqState.RUNNING:
            if chunk and r.prefill_cursor:
                return self.lat.chunked_prefill_latency(
                    r.context_len, chunk, start=r.prefill_cursor)
            return 0.0
        if r.state == ReqState.SWAPPED:
            d = self.lat.swap_latency(r.context_len)
            if chunk and r.prefill_cursor:
                d += self.lat.chunked_prefill_latency(
                    r.context_len, chunk, start=r.prefill_cursor)
            return d
        if chunk:
            return self.lat.chunked_prefill_latency(r.context_len, chunk)
        return self.lat.prefill_latency(r.prompt_len)

    def batch_pricing(self, now: float, live: List[Request],
                      fluid) -> BatchPricing:
        """Everything the knapsack needs that does not depend on B."""
        cfg = self.sched.cfg
        idx = np.array([r.fluid_idx for r in live])
        e_len = expected_len(fluid.emitted, self.mean_output_len,
                             cfg.min_remaining_est)
        q_wait = fluid.predict_qoe(now, cfg.delta_t, 0.0, exp_len=e_len)[idx]
        q_now = fluid.qoe_now(now, exp_len=e_len)[idx]
        delays_slot = np.zeros(fluid.arrival.size)
        delays_slot[idx] = [self.serve_delay(r) for r in live]
        return BatchPricing(
            idx=idx, exp_len=e_len, q_wait=q_wait, q_now=q_now,
            delays_slot=delays_slot, weights=request_weights(live),
            mean_ctx=float(np.mean([r.context_len for r in live])),
        )

    def serve_gains(self, now: float, fluid, bp: BatchPricing, b: int,
                    gain_fn) -> np.ndarray:
        """Knapsack item values at candidate batch size B: the objective
        over (Q_serve(B), Q_wait, Q_now), contract/priority-weighted
        (all-default weights are exactly 1.0 — bit-identical to the
        unweighted gains)."""
        return self.serve_gains_grid(now, fluid, bp, [int(b)], gain_fn)[0]

    def serve_gains_grid(self, now: float, fluid, bp: BatchPricing,
                         bs, gain_fn) -> np.ndarray:
        """Knapsack item values for a whole grid of candidate batch sizes
        in ONE vectorized pricing pass — the §4.2 #2/#3 hot path.

        The per-request terms (fluid state, serve delays, l̂, Q_wait,
        Q_now, contract weights) do not depend on B; only the hypothetical
        serving rate does. Pricing each of the ~12 candidates separately
        re-derived all of them per candidate; here the B axis is one numpy
        broadcast through `FluidQoE.predict_qoe_grid` (elementwise ⇒ each
        row bit-identical to the old per-B call, so the chosen batch — and
        every downstream emit timestamp — is unchanged). Returns
        (len(bs), n_live); gain_fn is applied per row because some
        objectives (max_min_qoe) reduce over the live axis internally."""
        cfg = self.sched.cfg
        rates = np.array([self.lat.token_rate(int(b), int(b * bp.mean_ctx))
                          for b in bs], np.float64)
        q_serve = fluid.predict_qoe_grid(
            now, cfg.delta_t, rates, bp.delays_slot, bp.exp_len
        )[:, bp.idx]
        return np.stack([
            gain_fn(q_serve[i], bp.q_wait, bp.q_now) * bp.weights
            for i in range(len(bs))
        ])


__all__ = [
    "SLOContract", "DEFAULT_CONTRACT",
    "request_weight", "request_weights",
    "slo_attained", "weighted_attainment",
    "expected_len", "expected_new_len", "shared_token_rate",
    "placement_components", "placement_gain",
    "BatchPricing", "QoEPricer",
]
