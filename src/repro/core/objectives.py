"""Scheduling objectives (paper §4.1 Eq. 2 and Appendix A Eqs. 6–7).

Each objective maps (Q_serve, Q_wait, Q_now) vectors to per-request *gains*
(the knapsack item values). The scheduler maximizes the total gain of the
served set.
"""
from __future__ import annotations

import numpy as np

PERFECT_TOL = 1e-3


def avg_qoe(q_serve: np.ndarray, q_wait: np.ndarray, q_now: np.ndarray) -> np.ndarray:
    """Eq. 2 — maximize average QoE: gain_i = Q_serve,i − Q_wait,i."""
    return q_serve - q_wait


# Eqs. 6/7 produce zero gain for most requests most of the time (only the
# floor request / currently-perfect requests earn value). A pure
# implementation therefore loses all discrimination among the zero-gain
# majority and degrades into churn — especially once one unsalvageable
# request anchors Q_min ~ 0. We blend in an epsilon of the Eq. 2 gain as a
# tiebreak so the secondary ordering stays QoE-aware (implementation choice
# documented in DESIGN.md; the primary term still dominates decisions).
EPS_TIEBREAK = 0.01


def max_min_qoe(q_serve: np.ndarray, q_wait: np.ndarray, q_now: np.ndarray) -> np.ndarray:
    """Eq. 6 — lift the QoE floor: gain_i = max(Q_min − Q_wait,i, 0)."""
    if q_now.size == 0:
        return np.zeros(0)
    q_min = float(np.min(q_now))
    return (np.maximum(q_min - q_wait, 0.0)
            + EPS_TIEBREAK * (q_serve - q_wait))


def perfect_count(q_serve: np.ndarray, q_wait: np.ndarray, q_now: np.ndarray) -> np.ndarray:
    """Eq. 7 — maximize requests that keep QoE = 1."""
    s1 = (q_serve >= 1.0 - PERFECT_TOL).astype(np.float64)
    w1 = (q_wait >= 1.0 - PERFECT_TOL).astype(np.float64)
    n1 = (q_now >= 1.0 - PERFECT_TOL).astype(np.float64)
    return (s1 - w1) * n1 + EPS_TIEBREAK * (q_serve - q_wait)


OBJECTIVES = {
    "avg_qoe": avg_qoe,
    "max_min_qoe": max_min_qoe,
    "perfect_count": perfect_count,
}


# ---------------------------------------------------------------------------
# Fleet-level aggregation (cluster layer, paper §6.4 extended)
#
# The single-engine objectives above value a *batch* choice inside one
# replica; the cluster router/admission/autoscaler (repro.cluster) need the
# same vocabulary one level up: how good is the fleet, given each replica's
# per-request QoE vector? Shed requests enter as zeros — degrading
# gracefully under surge means accounting for who we turned away.
# ---------------------------------------------------------------------------

def fleet_qoes(per_replica: "list[np.ndarray]", n_shed: int = 0) -> np.ndarray:
    """Concatenate per-replica QoE vectors, appending a zero per shed
    request."""
    parts = [np.asarray(q, np.float64) for q in per_replica if len(q)]
    if n_shed:
        parts.append(np.zeros(n_shed))
    return np.concatenate(parts) if parts else np.zeros(0)


def fleet_avg_qoe(per_replica: "list[np.ndarray]", n_shed: int = 0) -> float:
    q = fleet_qoes(per_replica, n_shed)
    return float(q.mean()) if q.size else 1.0


def fleet_min_qoe(per_replica: "list[np.ndarray]", n_shed: int = 0) -> float:
    q = fleet_qoes(per_replica, n_shed)
    return float(q.min()) if q.size else 1.0


def fleet_slo_attainment(
    per_replica: "list[np.ndarray]",
    threshold: float = 0.9,
    n_shed: int = 0,
) -> float:
    """Fraction of requests meeting the QoE SLO (§6.1 capacity metric,
    fleet-wide). This is the autoscaler's feedback signal."""
    q = fleet_qoes(per_replica, n_shed)
    return float((q >= threshold).mean()) if q.size else 1.0


FLEET_OBJECTIVES = {
    "fleet_avg_qoe": fleet_avg_qoe,
    "fleet_min_qoe": fleet_min_qoe,
    "fleet_slo_attainment": fleet_slo_attainment,
}
