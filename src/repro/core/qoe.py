"""Quality-of-Experience for text streaming (paper §3.1, Eq. 1).

Two layers:

1. **Exact, discrete** QoE — used for *reporting*: given the server's token
   emission timestamps, `pace_delivery` applies the client-side token buffer
   (§5: release at the user's expected TDS, first token immediately) and
   `qoe_exact` evaluates Eq. 1 on the resulting delivery curve.

2. **Fluid, vectorized** QoE state — used by the *scheduler*: a
   struct-of-arrays over all live requests, advanced in O(1) per event under
   a fluid (continuous-token) approximation, with closed-form
   `predict_qoe(Δt, rate)` for Q_serve(B) / Q_wait (paper Eq. 2, Fig. 7).
   The fluid model is what makes per-iteration scheduling cheap; the exact
   model is what the benchmarks report.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class QoESpec:
    """Expected token delivery timeline of a request."""
    ttft: float       # expected time-to-first-token (s)
    tds: float        # expected token delivery speed (tokens/s)


# ---------------------------------------------------------------------------
# Exact (reporting) path
# ---------------------------------------------------------------------------

def pace_delivery(emit_times: np.ndarray, tds: float,
                  network=None) -> np.ndarray:
    """Client-side token buffer (paper §5, Fig. 8).

    Token i becomes *visible* at d_i = max(e_i, d_{i-1} + 1/tds): the buffer
    withholds tokens arriving faster than the user's digest speed and
    releases them at exactly the expected TDS; the first token is shown as
    soon as it arrives.

    `network` (a repro.core.network.NetworkModel, optional) transits the
    server emission timeline through a delay/jitter/loss link first, so the
    buffer paces what actually *arrives* at the client.
    """
    e = np.asarray(emit_times, dtype=np.float64)
    if e.size == 0:
        return e
    if network is not None:
        e = network.arrivals(e)
    gap = 1.0 / tds
    d = np.empty_like(e)
    d[0] = e[0]
    for i in range(1, e.size):
        d[i] = max(e[i], d[i - 1] + gap)
    return d


def expected_area(t: float, spec: QoESpec, cap: Optional[float] = None) -> float:
    """∫₀ᵗ min(T(τ), cap) dτ with T(τ) = tds·(τ − ttft)⁺  (Eq. 1 denominator)."""
    if t <= spec.ttft:
        return 0.0
    if cap is None or cap <= 0:
        ramp_end = t
    else:
        ramp_end = min(t, spec.ttft + cap / spec.tds)
    area = 0.5 * spec.tds * (ramp_end - spec.ttft) ** 2
    if cap is not None and cap > 0 and t > ramp_end:
        area += cap * (t - ramp_end)
    return area


def actual_area(delivery_times: np.ndarray, t: float) -> float:
    """∫₀ᵗ A(τ) dτ where A is the delivered-token staircase."""
    d = np.asarray(delivery_times, dtype=np.float64)
    return float(np.sum(np.maximum(t - d[d <= t], 0.0)))


def qoe_exact(
    emit_times: np.ndarray,
    arrival: float,
    spec: QoESpec,
    *,
    response_len: Optional[int] = None,
) -> float:
    """Eq. 1: QoE = S_actual / S_expected over [arrival, TTLT], both curves
    measured on the *user-visible* (buffer-paced) delivery timeline."""
    e = np.asarray(emit_times, dtype=np.float64) - arrival
    if e.size == 0:
        return 0.0
    d = pace_delivery(e, spec.tds)
    ttlt = float(d[-1])
    l = response_len if response_len is not None else e.size
    s_exp = expected_area(ttlt, spec, cap=l)
    if s_exp <= 0.0:
        return 1.0
    s_act = actual_area(d, ttlt)
    return float(np.clip(s_act / s_exp, 0.0, 1.0))


def ttft_actual(emit_times: np.ndarray, arrival: float) -> float:
    e = np.asarray(emit_times, dtype=np.float64)
    return float(e[0] - arrival) if e.size else float("inf")


def tds_actual(emit_times: np.ndarray) -> float:
    """Average observed delivery speed excluding TTFT (paper Table 4)."""
    e = np.asarray(emit_times, dtype=np.float64)
    if e.size < 2 or e[-1] <= e[0]:
        return float("inf")
    return (e.size - 1) / (e[-1] - e[0])


def predict_request_qoe(
    spec: QoESpec,
    delay: float,
    rate: float,
    dt: float,
    exp_len: float,
) -> float:
    """Fluid QoE of a *fresh* (not yet admitted) request after horizon dt,
    if its first token appears after `delay` seconds and tokens then flow at
    `rate` tokens/s until the estimated length `exp_len` is generated.

    Scalar companion of `FluidQoE.predict_qoe` for requests that have no
    fluid slot yet — the cluster router and admission controller (paper
    §6.4 surge handling, extended fleet-wide in repro.cluster) score
    hypothetical placements with it. The client buffer caps the visible
    delivery speed at the user's expected TDS, so the visible curve ramps
    at min(rate, tds).
    """
    if dt <= 0:
        return 1.0
    delay = min(max(delay, 0.0), dt)
    s_act = 0.0
    if rate > 0 and delay < dt:
        vis_rate = min(rate, spec.tds)
        # visible ramp lasts until exp_len tokens are shown (or horizon)
        t_ramp = min(dt - delay, exp_len / max(vis_rate, 1e-12))
        s_act += 0.5 * vis_rate * t_ramp * t_ramp
        t_flat = (dt - delay) - t_ramp
        s_act += vis_rate * t_ramp * t_flat
    s_exp = expected_area(dt, spec, cap=exp_len)
    if s_exp <= 0.0:
        return 1.0
    return float(np.clip(s_act / s_exp, 0.0, 1.0))


# ---------------------------------------------------------------------------
# Fluid (scheduling) path — struct-of-arrays over live requests
# ---------------------------------------------------------------------------

class FluidQoE:
    """Vectorized fluid QoE state for N live requests.

    Fields (np.float64 arrays, index = request slot):
      arrival   absolute arrival time
      ttft_e / tds_e   the request's QoESpec
      n_vis     tokens visible to the user (fluid)
      buf       tokens in the client buffer
      s_act     accumulated ∫A dτ (relative to arrival)
      t_last    absolute time of last update
      emitted   tokens emitted by the server so far
    """

    FIELDS = ("arrival", "ttft_e", "tds_e", "n_vis", "buf", "s_act",
              "t_last", "emitted")

    def __init__(self, capacity: int = 0):
        for f in self.FIELDS:
            setattr(self, f, np.zeros(capacity, np.float64))

    def clone_slots(self, idx) -> "FluidQoE":
        """Compact deep copy of only the given slots (positional reindex).

        The cluster router (repro.cluster.router) evaluates hypothetical
        placements with `predict_qoe`, whose internal `advance(t)` moves
        `t_last` forward; querying a copy keeps the replica's own fluid
        state byte-identical to an unrouted run (the 1-replica invariance
        guarantee). Slots are grow-only (finished requests keep theirs),
        so the copy is restricted to the slots the caller cares about —
        cloning the full state per routing decision would make fleet
        routing O(total requests) per query."""
        out = FluidQoE()
        for f in self.FIELDS:
            setattr(out, f, getattr(self, f)[idx].copy())
        return out

    def add(self, arrival: float, spec: QoESpec) -> int:
        """Append a request; returns its slot index."""
        for f in self.FIELDS:
            arr = getattr(self, f)
            setattr(self, f, np.append(arr, 0.0))
        i = self.arrival.size - 1
        self.arrival[i] = arrival
        self.ttft_e[i] = spec.ttft
        self.tds_e[i] = spec.tds
        self.t_last[i] = arrival
        return i

    # -- fluid dynamics ------------------------------------------------------

    def advance(self, t: float, idx=None) -> None:
        """Drain client buffers up to absolute time t (no new emissions)."""
        sl = slice(None) if idx is None else idx
        dt = np.maximum(t - self.t_last[sl], 0.0)
        tds = self.tds_e[sl]
        g = np.minimum(self.buf[sl], tds * dt)
        # visible rises at tds for g/tds, then flat
        self.s_act[sl] += self.n_vis[sl] * dt + g * dt - g * g / (2.0 * tds)
        self.n_vis[sl] += g
        self.buf[sl] -= g
        self.t_last[sl] = t

    def emit(self, idx, t: float, k: float = 1.0) -> None:
        """Server emitted k tokens for request(s) idx at time t."""
        self.advance(t, idx)
        first = self.emitted[idx] == 0
        # the buffer releases the first token immediately
        self.n_vis[idx] = np.where(
            first, self.n_vis[idx] + 1.0, self.n_vis[idx]
        )
        self.buf[idx] += np.where(first, k - 1.0, float(k))
        self.emitted[idx] += k

    # -- QoE queries ---------------------------------------------------------

    def _expected_area_vec(self, t_rel, cap=None):
        ttft, tds = self.ttft_e, self.tds_e
        if cap is None:
            ramp_end = np.maximum(t_rel, ttft)
        else:
            ramp_end = np.minimum(np.maximum(t_rel, ttft), ttft + cap / tds)
        area = 0.5 * tds * (ramp_end - ttft) ** 2
        if cap is not None:
            area += np.maximum(cap, 0.0) * np.maximum(t_rel - ramp_end, 0.0)
        return area

    def qoe_now(self, t: float, exp_len: np.ndarray = None) -> np.ndarray:
        """Current fluid QoE of every request."""
        self.advance(t)
        if exp_len is not None:
            exp_len = np.maximum(exp_len, np.maximum(self.emitted, 1.0))
        s_exp = self._expected_area_vec(t - self.arrival, cap=exp_len)
        out = np.ones_like(s_exp)
        nz = s_exp > 0
        out[nz] = np.clip(self.s_act[nz] / s_exp[nz], 0.0, 1.0)
        return out

    def predict_qoe(
        self,
        t: float,
        dt: float,
        rate: np.ndarray,
        delay: np.ndarray = None,
        exp_len: np.ndarray = None,
    ) -> np.ndarray:
        """QoE after horizon dt if request i receives tokens at rate[i]
        (tokens/s) starting after delay[i] seconds (prefill time; 0 = already
        decoding). rate=0 gives Q_wait. Paper Eq. 2 / Fig. 7.

        exp_len: estimated final response length l̂ (Eq. 1 caps the expected
        curve at l). This is what distinguishes "already sufficiently served"
        (delivered area ≈ capped expected area ⇒ Q_wait high ⇒ safe to
        preempt) from "starving" (Q_wait collapsing ⇒ urgent). Generation
        also stops once emitted reaches l̂.

        Pure function: does NOT mutate state (operates on copies).
        """
        n = self.arrival.size
        rate = np.broadcast_to(np.asarray(rate, np.float64), (n,))
        return self.predict_qoe_grid(t, dt, rate[None, :], delay, exp_len)[0]

    def predict_qoe_grid(
        self,
        t: float,
        dt: float,
        rates: np.ndarray,
        delay: np.ndarray = None,
        exp_len: np.ndarray = None,
    ) -> np.ndarray:
        """`predict_qoe` evaluated for a whole grid of serving rates in one
        vectorized pass: rates (nB,) — one hypothetical rate per candidate
        batch size — or (nB, n) per (candidate, request). Returns (nB, n).

        This is the scheduler-knapsack hot path: the per-request fluid
        state, delays, and l̂ do not depend on the candidate B, so pricing
        all 12 candidates is one broadcast over the rate axis instead of 12
        re-derivations (QoEPricer.serve_gains_grid). Every operation is
        elementwise, so each row is bit-identical to a scalar-rate
        `predict_qoe` call — the greedy knapsack sees the exact same gains.
        """
        n = self.arrival.size
        rates = np.asarray(rates, np.float64)
        if rates.ndim == 1:
            rates = rates[:, None]
        rate = np.broadcast_to(rates, (rates.shape[0], n))
        delay = (np.zeros(n) if delay is None
                 else np.broadcast_to(np.asarray(delay, np.float64), (n,)).copy())
        delay = np.minimum(delay, dt)
        if exp_len is not None:
            exp_len = np.maximum(
                np.broadcast_to(np.asarray(exp_len, np.float64), (n,)),
                np.maximum(self.emitted, 1.0),
            )

        # local copies of fluid state, advanced to t first
        self.advance(t)
        n_vis = self.n_vis.copy()
        buf = self.buf.copy()
        s_act = self.s_act.copy()
        tds = self.tds_e

        def seg(seg_len, inflow, n_vis, buf, s_act):
            """Advance fluid state by seg_len with server inflow rate."""
            # phase A: buffer (plus inflow) sustains drain at tds
            net = tds - inflow                      # buffer depletion rate
            with np.errstate(divide="ignore", invalid="ignore"):
                tau = np.where(net > 0, buf / np.where(net > 0, net, 1.0), np.inf)
            ta = np.minimum(seg_len, tau)           # time visible grows at tds
            s_act = s_act + n_vis * ta + 0.5 * tds * ta * ta
            n_vis = n_vis + tds * ta
            buf = np.maximum(buf - net * ta, 0.0)
            # phase B: buffer empty, visible grows at inflow
            tb = seg_len - ta
            grow = np.minimum(inflow, tds)
            s_act = s_act + n_vis * tb + 0.5 * grow * tb * tb
            n_vis = n_vis + grow * tb
            return n_vis, buf, s_act

        # segment 1: [0, delay) — no inflow (rate-independent, stays (n,))
        n_vis, buf, s_act = seg(delay, np.zeros(n), n_vis, buf, s_act)
        # segment 2: [delay, delay+t_gen) — inflow at `rate` until l̂ reached
        seg2 = dt - delay
        if exp_len is not None:
            remaining = np.maximum(exp_len - self.emitted, 0.0)
            with np.errstate(divide="ignore", invalid="ignore"):
                t_gen = np.where(rate > 0, remaining / np.where(rate > 0, rate, 1.0), 0.0)
            t_gen = np.minimum(seg2, t_gen)
        else:
            t_gen = np.where(rate > 0, seg2, 0.0)
        n_vis, buf, s_act = seg(t_gen, rate, n_vis, buf, s_act)
        # segment 3: rest — generation finished / not served, buffer drains
        n_vis, buf, s_act = seg(seg2 - t_gen, np.zeros(n)[None, :], n_vis,
                                buf, s_act)

        t_rel = (t + dt) - self.arrival
        s_exp = self._expected_area_vec(t_rel, cap=exp_len)
        s_act = np.broadcast_to(s_act, rate.shape)
        out = np.ones(rate.shape)
        nz = np.broadcast_to(s_exp > 0, rate.shape)
        out[nz] = np.clip(s_act[nz] / np.broadcast_to(s_exp, rate.shape)[nz],
                          0.0, 1.0)
        return out
