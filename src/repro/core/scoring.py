"""Goodput + fairness scoring for the policy arena.

`core/objectives.py` answers "which batch should the scheduler pick";
this module answers "who actually won" after a run finishes — the
counter-metrics a QoE-maximizing policy must also report against
(ISSUE: the fairness-vs-avg-QoE tension made measurable):

  slo_goodput        SLO-attained work per second ("Revisiting SLOs"
                     family, PAPERS.md): only requests that met their
                     contract count, weighted by their delivered tokens
                     (or counted per-request with unit=\"requests\")
  jains_index        Jain's fairness index over per-tenant normalized
                     service, (Σx)²/(n·Σx²) ∈ (0, 1]; 1.0 = exact
                     weighted fair shares
  per_tenant_service per-tenant delivered tokens, weight-normalized
  max_min_service    min over tenants of normalized service — the
                     max-min yardstick VTC/WSC optimize
  fairness_report    one dict with all of the above + mean/min QoE,
                     the row `benchmarks/policy_arena.py` puts on the
                     scoreboard

Service is normalized by the tenant's contract weight (weight-2 tenants
are *entitled* to twice the tokens, so fair shares mean equal
service/weight), which makes the same metrics correct for both the
unweighted (VTC) and weighted (WSC/FAIRSERVE) notions of fairness.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.pricing import slo_attained
from repro.core.request import Request


def jains_index(x: Sequence[float]) -> float:
    """Jain's fairness index (Σx)²/(n·Σx²); 1.0 when all equal."""
    v = np.asarray(list(x), np.float64)
    v = v[v >= 0]
    if v.size == 0 or not np.any(v > 0):
        return 1.0
    return float(v.sum() ** 2 / (v.size * np.square(v).sum()))


def _tenant_weight(reqs: Sequence[Request], tenant: int) -> float:
    for r in reqs:
        if r.tenant == tenant and r.contract is not None:
            return max(r.contract.weight, 1e-9)
    return 1.0


def per_tenant_service(reqs: Sequence[Request],
                       normalize: bool = True,
                       until: float = None) -> Dict[int, float]:
    """Delivered tokens per tenant, divided by the tenant's contract
    weight when `normalize` (equal values == weighted fair shares).

    `until` counts only tokens emitted at or before that absolute time.
    This matters for run-to-completion experiments: every policy
    eventually delivers every token, so *lifetime* service is
    policy-independent — fairness differentiates inside the contention
    window. Pass the last arrival time (what `fairness_report` does) to
    measure who got served while tenants were actually competing."""
    service: Dict[int, float] = {}
    for r in reqs:
        if until is None:
            tok = float(r.generated)
        else:
            tok = float(sum(1 for e in r.emit_times if e <= until))
        service[r.tenant] = service.get(r.tenant, 0.0) + tok
    if normalize:
        for t in service:
            service[t] /= _tenant_weight(reqs, t)
    return service


def max_min_service(reqs: Sequence[Request],
                    until: float = None) -> float:
    """Smallest weight-normalized per-tenant service (max-min yardstick)."""
    service = per_tenant_service(reqs, until=until)
    return min(service.values()) if service else 0.0


def slo_goodput(reqs: Sequence[Request], duration: float,
                default_floor: float = 0.9,
                unit: str = "tokens") -> float:
    """SLO goodput: work from requests that met their contract, per
    second. `unit=\"tokens\"` counts delivered tokens (throughput-style);
    `unit=\"requests\"` counts attained requests (capacity-style)."""
    if duration <= 0:
        return 0.0
    good = 0.0
    for r in reqs:
        if r.emit_times and slo_attained(r, default_floor):
            good += float(r.generated) if unit == "tokens" else 1.0
    return good / duration


def fairness_report(reqs: Sequence[Request], duration: float,
                    default_floor: float = 0.9) -> Dict[str, float]:
    """Everything the arena scoreboard reports for one (policy, trace,
    load) cell. QoE columns average over finished requests (unfinished
    ones never got their Eq. 1 curve completed). Fairness columns count
    service inside the contention window (up to the last arrival) —
    see `per_tenant_service`."""
    finished: List[Request] = [r for r in reqs if r.emit_times]
    qoes = np.array([r.final_qoe() for r in finished], np.float64)
    window = max((r.arrival for r in reqs), default=None)
    service = per_tenant_service(reqs, until=window)
    return {
        "n_requests": len(reqs),
        "n_finished": len(finished),
        "avg_qoe": float(qoes.mean()) if qoes.size else 0.0,
        "min_qoe": float(qoes.min()) if qoes.size else 0.0,
        "slo_attainment": (float(np.mean(
            [slo_attained(r, default_floor) for r in finished]))
            if finished else 0.0),
        "goodput_tok_s": slo_goodput(reqs, duration, default_floor),
        "goodput_req_s": slo_goodput(reqs, duration, default_floor,
                                     unit="requests"),
        "jains_index": jains_index(service.values()),
        "max_min_service": max_min_service(reqs, until=window),
        "n_tenants": len(service),
    }
