"""The scheduling-policy arena.

One registry, many policies, every backend: any name in `SCHEDULERS`
can drive the simulator, the engine (incl. speculative), and cluster
replicas unchanged — they all consume the `SchedulingPolicy` protocol.

    fcfs         vLLM-style first-come-first-served (baselines.py)
    round_robin  fair-share rotation, paper §6.1 (baselines.py)
    andes        the paper's QoE knapsack, Algorithm 1 (andes.py)
    andes_dp     optimal 3-D DP, Algorithm 2 (andes.py)
    vtc          virtual-token-counter per-tenant fairness (fair.py)
    wsc          FAIRSERVE-style weighted service counter (fair.py)
    burst        TokenFlow-style burst-preemptive buffer slack (burst.py)

`benchmarks/policy_arena.py` referees them on adversarial multi-tenant
traces; `tests/test_policy_conformance.py` is the shared contract every
entry must pass.
"""
from __future__ import annotations

from typing import Optional

from repro.core.latency_model import LatencyModel
from repro.core.policies.andes import AndesDPScheduler, AndesScheduler
from repro.core.policies.base import (Scheduler, SchedulerConfig,
                                      SchedulingPolicy)
from repro.core.policies.baselines import FCFSScheduler, RoundRobinScheduler
from repro.core.policies.burst import BurstPreemptiveScheduler
from repro.core.policies.fair import VTCScheduler, WSCScheduler

SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "round_robin": RoundRobinScheduler,
    "andes": AndesScheduler,
    "andes_dp": AndesDPScheduler,
    "vtc": VTCScheduler,
    "wsc": WSCScheduler,
    "burst": BurstPreemptiveScheduler,
}


def make_scheduler(name: str, kv_capacity: int, lat: LatencyModel,
                   cfg: Optional[SchedulerConfig] = None, **kw) -> Scheduler:
    return SCHEDULERS[name](kv_capacity, lat, cfg, **kw)


__all__ = [
    "AndesDPScheduler",
    "AndesScheduler",
    "BurstPreemptiveScheduler",
    "FCFSScheduler",
    "RoundRobinScheduler",
    "SCHEDULERS",
    "Scheduler",
    "SchedulerConfig",
    "SchedulingPolicy",
    "VTCScheduler",
    "WSCScheduler",
    "make_scheduler",
]
