"""Per-tenant fairness policies: VTC and FAIRSERVE-style weighted WSC.

**VTC (virtual token counter).** Each tenant carries a counter of the
service it has received — `w_p` per prompt token at first admission,
`w_q` per decode token served. Every iteration the scheduler serves the
tenants with the *smallest* counters first, so a tenant that monopolized
the engine accumulates counter and yields to starved tenants; a newly
active tenant's counter is lifted to the minimum of the active counters
so idling can't bank credit. For continuously backlogged tenants the
counter gap stays bounded by one max-cost request — the fairness
invariant the property tests pin.

**WSC (weighted service counter).** The FAIRSERVE generalization: each
tenant is entitled to a *share* proportional to its contract weight
(`SLOContract.weight`, the same weight fleet pricing uses), and the
counter accumulates `cost / weight`. Under saturating load the served
token shares converge to the contract weights.

Both policies run greedy lowest-counter packing: running state earns no
priority, so an over-served tenant's requests are preempted for a
starved tenant's queue whenever memory is short — fairness is bought
with preemption churn, and the arena scoreboard prices that trade.
Service accounting is observational: the scheduler charges
`Request.generated` deltas between its own calls (plus the prompt at
first admission), which works identically on the simulator and the real
engine with no extra backend hooks.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.policies.base import Scheduler
from repro.core.request import Request


class VTCScheduler(Scheduler):
    """Virtual-token-counter fair scheduler (per-tenant)."""

    name = "vtc"

    def __init__(self, kv_capacity, lat, cfg=None, *,
                 w_p: float = 1.0, w_q: float = 1.0,
                 counter_lift: bool = True):
        self.w_p = w_p
        self.w_q = w_q
        self.counter_lift = counter_lift
        super().__init__(kv_capacity, lat, cfg)

    def reset(self):
        super().reset()
        self.counters: Dict[int, float] = {}
        self._seen_tokens: Dict[int, int] = {}   # rid -> charged decode tokens
        self._prefill_charged: set = set()       # rids charged w_p * prompt

    # -- service accounting --------------------------------------------------
    def _tenant_weight(self, req: Request) -> float:
        """Service entitlement of the request's tenant (1.0 for VTC; WSC
        overrides with the contract weight)."""
        return 1.0

    def _charge(self, req: Request, cost: float) -> None:
        t = req.tenant
        self.counters[t] = self.counters.get(t, 0.0) \
            + cost / self._tenant_weight(req)

    def _settle(self, live: List[Request]) -> None:
        """Charge decode tokens served since the last call (observational:
        `generated` grew between schedule() calls / before finish)."""
        for r in live:
            seen = self._seen_tokens.get(r.rid, 0)
            if r.generated > seen:
                self._charge(r, self.w_q * (r.generated - seen))
                self._seen_tokens[r.rid] = r.generated

    def on_request_arrival(self, req: Request) -> None:
        super().on_request_arrival(req)
        if self.counter_lift and self.counters:
            floor = min(self.counters.values())
            self.counters[req.tenant] = max(
                self.counters.get(req.tenant, 0.0), floor)
        else:
            self.counters.setdefault(req.tenant, 0.0)

    def on_request_finish(self, req: Request) -> None:
        super().on_request_finish(req)
        # the final token is emitted after our last schedule() sighting
        seen = self._seen_tokens.pop(req.rid, 0)
        if req.generated > seen:
            self._charge(req, self.w_q * (req.generated - seen))
        self._prefill_charged.discard(req.rid)

    # -- the decision --------------------------------------------------------
    def schedule(self, now, live, fluid):
        """Greedy lowest-counter packing (the VTC discipline).

        Repeatedly admit the head-of-line request of the tenant with the
        smallest *live* counter until memory is full; prefill charges
        land the moment a request is admitted, so the very next pick
        already sees them. That mid-call visibility is what keeps the
        backlogged-tenant counter gap bounded by ONE max-cost request
        (the property test's invariant) — batching all of a tenant's
        admissions at one stale counter value would let the gap grow by
        several prompts per iteration. Running state earns no priority:
        an over-served tenant's running requests sort behind a starved
        tenant's queue and get preempted when memory is short — the
        fairness-vs-churn trade the arena measures."""
        self.iteration += 1
        self._settle(live)
        st = self.cfg.state_equiv_tokens
        heads: dict = {}                 # tenant -> FIFO of live requests
        for r in sorted(live, key=lambda q: (q.arrival, q.rid)):
            heads.setdefault(r.tenant, []).append(r)
        used = 0
        keep: List[Request] = []
        while heads:
            t = min(heads, key=lambda k: (self.counters.get(k, 0.0),
                                          heads[k][0].arrival,
                                          heads[k][0].rid))
            r = heads[t].pop(0)
            if not heads[t]:
                del heads[t]
            w = r.kv_tokens(st)
            if used + w > self.M:
                continue                 # skip; tenant's next may still fit
            keep.append(r)
            used += w
            if not r.prefilled and r.rid not in self._prefill_charged:
                self._charge(r, self.w_p * r.prompt_len)
                self._prefill_charged.add(r.rid)
        if self.obs is not None:
            active = [self.counters[t] for t in
                      sorted({r.tenant for r in live})
                      if t in self.counters]
            self._record_decision(now, live, keep, {
                "counter_min": min(active) if active else 0.0,
                "counter_max": max(active) if active else 0.0,
                "counter_gap": (max(active) - min(active)) if active else 0.0,
                "n_tenants": len(active),
            })
        return keep


class WSCScheduler(VTCScheduler):
    """FAIRSERVE-style weighted-service-counter scheduler.

    Identical machinery to VTC, but service is normalized by each
    tenant's contract weight: a weight-3 tenant's counter grows 3x slower
    per served token, so under saturation it receives ~3x the service of
    a weight-1 tenant — the max-min weighted fair share the SLO contracts
    promise. Tenant weights are learned from the requests themselves
    (first contract seen per tenant; default 1.0)."""

    name = "wsc"

    def reset(self):
        super().reset()
        self.tenant_weights: Dict[int, float] = {}

    def on_request_arrival(self, req: Request) -> None:
        w = req.contract.weight if req.contract is not None else 1.0
        self.tenant_weights.setdefault(req.tenant, max(w, 1e-9))
        super().on_request_arrival(req)

    def _tenant_weight(self, req: Request) -> float:
        return self.tenant_weights.get(req.tenant, 1.0)
