"""The scheduling-policy arena's shared foundation.

`SchedulingPolicy` is the formal protocol extracted from what the
backends (simulator / engine / speculative engine / cluster replicas)
actually consume of `AndesScheduler`: candidate set in (`schedule(now,
live, fluid)`), batch out — the victim set is implicit as "running
requests not in the returned batch" — with all QoE math priced through
the policy's bound `QoEPricer`. Any object satisfying the protocol can
drive every backend unchanged; `Scheduler` below is the concrete base
class all in-repo policies share (bookkeeping, pricing surface,
observability, the §4.2 #4 preemption-cap enforcement, and `reset()`
for rerun reproducibility).

The concrete policies live beside this module:

  baselines.py   FCFS (vLLM-style) and Round-Robin (paper §6.1)
  andes.py       the paper's QoE knapsack (greedy Algorithm 1 + DP)
  fair.py        VTC virtual-token-counter and FAIRSERVE-style weighted-
                 service-counter per-tenant fairness
  burst.py       TokenFlow-style burst-preemptive buffer-slack policy
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.pricing import QoEPricer
from repro.core.qoe import FluidQoE
from repro.core.request import Request, ReqState


@dataclasses.dataclass
class SchedulerConfig:
    delta_t: float = 50.0            # prediction horizon Δt (s) (§6.5: insensitive >50)
    preemption_cap: float = 1.0      # P: avg preemptions per request (§4.2 #4)
    memory_watermark: float = 0.9    # high-memory trigger (§4.2 #1)
    objective: str = "avg_qoe"
    num_batch_candidates: int = 12   # B grid size within [B_min, B_max]
    state_equiv_tokens: int = 0      # SSM archs: constant weight per request
    page_size: int = 0               # paged KV: knapsack weights / capacity
                                     # views round up to page multiples so
                                     # the memory trigger and packing see
                                     # what admission will actually charge
                                     # (0 = token-granular, the legacy view)
    prefill_chunk: int = 0           # chunked prefill: serve-delay pricing
                                     # charges per-chunk costs instead of one
                                     # monolithic prefill (0 = unchunked)
    min_remaining_est: float = 64.0  # floor on l̂ − emitted (length estimator)
    stickiness: float = 0.02         # priority bonus for running requests
                                     # (hysteresis: suppresses preemption churn
                                     # when gains are near-tied)


@runtime_checkable
class SchedulingPolicy(Protocol):
    """What a backend requires of a scheduler — the arena contract.

    Every member below is consumed by at least one backend: `schedule`
    each iteration (the decision), `idle_steps`/`skip_iterations` by the
    engine's multi-step fast path, the `on_*`/`record_preemptions` hooks
    by the serving loops, `pricer`/`lat`/`M`/`cfg`/`mean_output_len` by
    the fleet router/admission/autoscaler, `obs` by the observability
    rewiring, and `reset()` by backend `reset()` (rerun reproducibility).
    """

    name: str
    M: int
    lat: LatencyModel
    cfg: SchedulerConfig
    pricer: QoEPricer
    obs: Optional[object]
    iteration: int
    total_preemptions: int
    total_requests: int

    def schedule(self, now: float, live: List[Request],
                 fluid: FluidQoE) -> List[Request]: ...

    def idle_steps(self, live: List[Request], max_steps: int) -> int: ...

    def skip_iterations(self, k: int) -> None: ...

    def on_request_arrival(self, req: Request) -> None: ...

    def on_request_finish(self, req: Request) -> None: ...

    def record_preemptions(self, n: int) -> None: ...

    def reset(self) -> None: ...

    @property
    def mean_output_len(self) -> float: ...


class Scheduler:
    """Base: subclasses return the set of requests that should run next."""

    name = "base"
    # True when the policy bounds avg preemptions/request by
    # cfg.preemption_cap via `_apply_preemption_cap` (§4.2 #4). Counter/
    # rotation policies preempt by design (VTC reorders on every counter
    # crossing, round-robin on every rotation) and do not take the cap;
    # the conformance suite reads this flag to know what to pin.
    enforces_preemption_cap = False

    def __init__(self, kv_capacity: int, lat: LatencyModel,
                 cfg: Optional[SchedulerConfig] = None):
        self.M = kv_capacity
        self.lat = lat
        self.cfg = cfg or SchedulerConfig()
        # the single QoE-pricing surface (core.pricing): the knapsack,
        # the fleet router, admission control, and the autoscaler all price
        # marginal QoE through this object. Bound to the scheduler so later
        # re-pointing of self.lat / self.M (backend factories do both) is
        # seen by every consumer.
        self.pricer = QoEPricer(self)
        # observability (repro.obs): wired by the owning backend's
        # `_rewire_obs`; None = off. Decision events are emitted through
        # `_record_decision` so the payload is only built when observed.
        self.obs = None
        self.reset()

    def reset(self) -> None:
        """Return to the just-constructed state (policy state included —
        subclasses clear their own counters/queues and call super()).
        Backends call this from their own `reset()` so a rerun on the
        same backend reproduces the first run bit-for-bit."""
        self.iteration = 0
        self.total_preemptions = 0
        self.total_requests = 0
        # running estimate of the response length l̂ (Eq. 1 cap; the true l
        # is unknown online — paper §2.3(a))
        self._len_sum = 0.0
        self._len_n = 0

    def on_request_finish(self, req: Request) -> None:
        self._len_sum += req.generated
        self._len_n += 1

    @property
    def mean_output_len(self) -> float:
        return (self._len_sum / self._len_n) if self._len_n >= 10 else 256.0

    # -- bookkeeping helpers -------------------------------------------------
    def _kv_weight(self, r: Request) -> int:
        """One request's KV footprint as the capacity view prices it:
        token-granular by default; rounded up to whole pages when the
        backend's KV manager is paged (cfg.page_size), so the knapsack /
        memory trigger charge what allocation will actually take from the
        pool. page_size=0 reproduces the legacy integers bit-for-bit."""
        w = r.kv_tokens(self.cfg.state_equiv_tokens)
        p = self.cfg.page_size
        if p > 1:
            return -(-w // p) * p
        return w

    def _weights(self, reqs: Sequence[Request]) -> np.ndarray:
        return np.array([self._kv_weight(r) for r in reqs], np.int64)

    def on_request_arrival(self, req: Request) -> None:
        self.total_requests += 1

    def record_preemptions(self, n: int) -> None:
        self.total_preemptions += n

    def _record_decision(self, now: float, live: Sequence[Request],
                         chosen: Sequence[Request],
                         info: Optional[dict] = None) -> None:
        """Emit one `schedule` observability event (no-op when
        unobserved): which requests were chosen, which running requests
        became victims, plus any policy-specific pricing payload."""
        obs = self.obs
        if obs is None:
            return
        chosen_ids = {id(r) for r in chosen}
        victims = [r.rid for r in live
                   if r.state == ReqState.RUNNING
                   and id(r) not in chosen_ids]
        payload = {
            "policy": self.name,
            "iteration": int(self.iteration),
            "n_live": len(live),
            "n_chosen": len(chosen),
            "chosen": [r.rid for r in chosen],
            "victims": victims,
        }
        if info:
            payload.update(info)
        obs.schedule(now, payload)

    def schedule(self, now: float, live: List[Request], fluid: FluidQoE
                 ) -> List[Request]:
        raise NotImplementedError

    def idle_steps(self, live: List[Request], max_steps: int) -> int:
        """How many consecutive future iterations this scheduler GUARANTEES
        it would be a pure pass-through — i.e. schedule() would return the
        full live set with no decision (no knapsack, no preemption, no
        rotation) — assuming every live request is RUNNING, none finishes,
        and no arrival lands in the window (the engine checks those).

        This is the legality certificate for the engine's multi-step decode
        fast path (§4.2 #1 turned into a skip): the engine may fuse up to
        idle_steps()+1 decode iterations into one device dispatch and
        replay the skipped schedule() calls as `iteration += k` bookkeeping.
        The base scheduler (and any stateful policy like round-robin or the
        fairness counters) answers 0: never skip me.

        Certificate contract (PR 10 — the device-resident persistent
        loop spends it in three ways, all of which a policy's answer
        must stay sound for):

        * **Unquantized:** `decode_persistent` takes the fused length as
          loop DATA, so the certificate is consumed at full resolution —
          a policy must not assume the engine rounds it down.
        * **Token-denominated under speculation:** a speculative verify
          round commits 1..k+1 tokens per slot, so the engine asks for
          `max_steps = s·(k+1) - 1` single-token iterations and runs
          `s` rounds — the projection must therefore be sound per TOKEN
          of growth, not per scheduler invocation. (The acceptance EMA
          may drift inside the block; the engine separately re-checks
          any EMA-dependent trigger at its worst case, so `idle_steps`
          itself may price the current EMA.)
        * **Page pre-reservation bound:** physically paged engines
          reserve every page the block can write BEFORE dispatch, so a
          paged projection (see AndesScheduler) must count the rounded
          page demand of `+max_steps` tokens per running request — an
          over-grant here is not a soft miss but a pool overdraft the
          engine refuses to serve."""
        return 0

    def skip_iterations(self, k: int) -> None:
        """Replay `k` skipped pass-through schedule() calls (multi-step
        decode committed k+1 iterations off one schedule decision)."""
        self.iteration += k

    # -- shared packing / cap enforcement ------------------------------------
    def _pack_in_order(self, ordered: Sequence[Request]) -> List[Request]:
        """Greedy prefix packing under the KV budget M in the given
        priority order (skipping requests that no longer fit — arena
        policies that rank by counters/slack use this; FCFS keeps its own
        head-of-line-blocking admission verbatim)."""
        used = 0
        keep: List[Request] = []
        for r in ordered:
            w = self._kv_weight(r)
            if used + w <= self.M:
                keep.append(r)
                used += w
        return keep

    def _apply_preemption_cap(self, chosen, running, weights, live):
        """Optimization #4 (§4.2): keep average preemptions/request ≤ P by
        sparing would-be victims (cheapest-context first) when the budget
        is exhausted, then re-enforcing memory by dropping admitted
        non-running requests."""
        preempted = [r for r in running if r not in chosen]
        if not preempted:
            return chosen
        budget = self.cfg.preemption_cap * max(self.total_requests, 1) \
            - self.total_preemptions
        allowed = max(int(budget), 0)
        if len(preempted) <= allowed:
            return chosen
        # keep the lowest-context (cheapest-to-keep) would-be victims running
        preempted.sort(key=lambda r: r.context_len)
        spared = preempted[: len(preempted) - allowed]
        chosen = list(chosen) + spared
        # re-enforce memory by dropping admitted (non-running) requests
        used = 0
        final: List[Request] = []
        # running first (sparing them is the point), then the rest
        for r in sorted(chosen, key=lambda r: r.state != ReqState.RUNNING):
            w = self._kv_weight(r)
            if used + w <= self.M:
                final.append(r)
                used += w
        return final
