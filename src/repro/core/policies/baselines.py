"""Baseline policies: FCFS (vLLM default) and Round-Robin (paper §6.1)."""
from __future__ import annotations

from typing import List

from repro.core.policies.base import Scheduler
from repro.core.request import Request, ReqState


class FCFSScheduler(Scheduler):
    """vLLM-style: running requests keep running; waiting requests admitted
    in arrival order while KV memory allows; preemption only on OOM
    (most-recent-arrival victim first)."""

    name = "fcfs"

    def schedule(self, now, live, fluid):
        self.iteration += 1
        running = [r for r in live if r.state == ReqState.RUNNING]
        queued = sorted(
            (r for r in live if r.state in (ReqState.WAITING, ReqState.SWAPPED)),
            key=lambda r: r.arrival,
        )
        st = self.cfg.state_equiv_tokens
        # OOM handling: victimize most recent arrivals (vLLM recompute policy)
        running.sort(key=lambda r: r.arrival)
        used = 0
        keep: List[Request] = []
        for r in running:
            w = r.kv_tokens(st)
            if used + w <= self.M:
                keep.append(r)
                used += w
        # admit in arrival order (reserve the full prompt)
        for r in queued:
            w = r.kv_tokens(st)
            if used + w <= self.M:
                keep.append(r)
                used += w
            else:
                break
        self._record_decision(now, live, keep,
                              {"kv_used": int(used)}
                              if self.obs is not None else None)
        return keep


class RoundRobinScheduler(Scheduler):
    """Fair-share baseline (paper §6.1): every `interval` iterations the
    running set is rotated to the back of a cyclic queue."""

    name = "round_robin"

    def __init__(self, kv_capacity, lat, cfg=None, interval: int = 50):
        super().__init__(kv_capacity, lat, cfg)
        self.interval = interval
        self._order: List[int] = []      # rids, cyclic service order

    def reset(self):
        super().reset()
        self._order = []

    def schedule(self, now, live, fluid):
        self.iteration += 1
        by_rid = {r.rid: r for r in live}
        # maintain cyclic order: append newcomers, drop finished
        known = set(self._order)
        for r in sorted(live, key=lambda q: q.arrival):
            if r.rid not in known:
                self._order.append(r.rid)
        self._order = [rid for rid in self._order if rid in by_rid]

        rotate = self.iteration % self.interval == 0
        if rotate:
            running_rids = [rid for rid in self._order
                            if by_rid[rid].state == ReqState.RUNNING]
            self._order = [rid for rid in self._order
                           if rid not in running_rids] + running_rids

        st = self.cfg.state_equiv_tokens
        used = 0
        keep: List[Request] = []
        for rid in self._order:
            r = by_rid[rid]
            w = r.kv_tokens(st)
            if used + w <= self.M:
                keep.append(r)
                used += w
        self._record_decision(now, live, keep,
                              {"rotated": bool(rotate), "kv_used": int(used)}
                              if self.obs is not None else None)
        return keep
