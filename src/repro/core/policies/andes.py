"""The paper's QoE-aware knapsack scheduler (§4).

Andes: at every continuous-batching iteration, choose the set of
requests to run next by solving the Exact-K-item knapsack

    max Σ gain_i(B) · x_i   s.t.  Σ x_i = B,  Σ l_i x_i ≤ M

over candidate batch sizes B ∈ [B_min, B_max], where
gain_i(B) = Q_serve,i(B) − Q_wait,i (Eq. 2; alternatives in objectives.py)
and l_i is the request's KV footprint in tokens. The production solver is
the greedy packing of Algorithm 1 (priority = gain_i / l_i); the optimal
3-D DP of Algorithm 2 is provided for comparison (fig18 benchmark).

Optimizations from §4.2 implemented here:
  #1 selective triggering   — solve only under memory or latency pressure
  #2 batch-size pruning     — B ∈ [B_min, B_max]
  #3 greedy packing         — O(N log N)
  #4 preemption cap         — average preemptions/request ≤ P

Speculative replicas: a decode step there costs draft(k)+verify(k) and
yields 1..k+1 tokens, so every pacing quantity the solver consumes —
token_rate for Q_serve(B), per_token_latency for the latency trigger,
max_batch_from_latency for B_min, prefill/swap delays for _serve_delay —
is asked of the LatencyModel, and a SpeculativeLatencyModel answers with
the expected-accepted-length already folded in (EMA of observed
acceptance). The scheduler code itself stays regime-agnostic.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import objectives as obj_lib
from repro.core.policies.base import Scheduler
from repro.core.request import Request, ReqState


class AndesScheduler(Scheduler):
    """The paper's QoE-aware scheduler (greedy packing, Algorithm 1)."""

    name = "andes"
    solver = "greedy"
    enforces_preemption_cap = True

    def schedule(self, now, live, fluid):
        self.iteration += 1
        if not live:
            return []
        running = [r for r in live if r.state == ReqState.RUNNING]
        weights = self._weights(live)

        # ---- Optimization #1: selective triggering -----------------------
        if not self._triggered(live, running, weights):
            chosen = self._admit_all(live, weights)
            self._record_decision(now, live, chosen,
                                  {"triggered": False}
                                  if self.obs is not None else None)
            return chosen

        # ---- Optimization #2: batch size pruning --------------------------
        b_min, b_max = self._batch_bounds(live, weights)
        candidates = np.unique(
            np.linspace(b_min, b_max, self.cfg.num_batch_candidates)
            .round().astype(int)
        )

        # ---- evaluate objective over the candidate-B grid -----------------
        # all Eq. 2 math lives in the pricer (core.pricing) — the same
        # implementation the router/admission/autoscaler consume. The
        # per-request terms are invariant across candidates, so the whole
        # grid is priced in ONE vectorized pass (serve_gains_grid; rows are
        # bit-identical to per-B serve_gains calls) and only the knapsack
        # solve itself remains per candidate.
        bp = self.pricer.batch_pricing(now, live, fluid)
        gain_fn = obj_lib.OBJECTIVES[self.cfg.objective]
        is_running = np.array([r.state == ReqState.RUNNING for r in live])

        gains_grid = self.pricer.serve_gains_grid(
            now, fluid, bp, candidates, gain_fn
        ) + self.cfg.stickiness * is_running
        best = (-np.inf, None, None, 0)
        for gains, b in zip(gains_grid, candidates):
            sel, value = self._solve(gains, weights, int(b))
            if value > best[0]:
                best = (value, sel, gains, int(b))

        sel = best[1]
        chosen = [live[i] for i in np.nonzero(sel)[0]]

        # ---- Optimization #4: preemption cap -------------------------------
        chosen = self._apply_preemption_cap(chosen, running, weights, live)
        if self.obs is not None:
            # pricing inputs behind the decision (QoEPricer gains, the
            # candidate grid, the winning knapsack) — trace-only payload
            info = {
                "triggered": True,
                "b_candidates": [int(b) for b in candidates],
                "b_chosen": best[3],
                "knapsack_value": float(best[0]),
                **bp.summary(),
            }
            if len(live) <= 64:       # full gain vector only when small
                info["gains"] = {str(r.rid): float(g)
                                 for r, g in zip(live, best[2])}
            self._record_decision(now, live, chosen, info)
        return chosen

    # ------------------------------------------------------------------ parts
    def idle_steps(self, live, max_steps):
        """Andes is a pass-through iteration exactly when the §4.2 #1
        trigger is off: schedule() then returns `_admit_all`, which admits
        every live request (untriggered ⇒ total demand ≤ watermark·M < M ⇒
        all fit). Project the trigger forward: the latency term is
        invariant within the window (len(live) and the stiffest TDS don't
        change while nobody finishes/arrives), and the memory term grows
        deterministically — every running request's KV weight grows by one
        token per iteration (or not at all under state_equiv_tokens). The
        s-th skipped call sees demand + s·grow; return the largest s kept
        under the watermark."""
        if not live:
            return 0
        if any(r.state != ReqState.RUNNING for r in live):
            return 0
        stiffest = max((r.spec.tds for r in live), default=0.0)
        if stiffest > 0 and \
                self.lat.per_token_latency(len(live)) > 1.0 / stiffest:
            return 0                         # latency trigger is on
        st = self.cfg.state_equiv_tokens
        demand = int(self._weights(live).sum())
        cap = self.cfg.memory_watermark * self.M
        if demand > cap:
            return 0                         # memory trigger is on
        grow = 0 if st else len(live)
        if grow == 0:
            return int(max_steps)
        p = self.cfg.page_size
        if p > 1:
            # paged capacity view: a request's page weight is flat until
            # its context crosses a page boundary, then jumps by a whole
            # page — project the page-rounded demand exactly rather than
            # the +1-token-per-request linear form
            toks = np.array([r.kv_tokens(st) for r in live], np.int64)
            s = 0
            while s < max_steps and \
                    int((-(-(toks + s + 1) // p) * p).sum()) <= cap:
                s += 1
            return s
        # largest s with demand + s*grow <= cap (float comparison matches
        # _triggered's `total_demand > watermark * M` exactly)
        s = 0
        while s < max_steps and demand + (s + 1) * grow <= cap:
            s += 1
        return s

    def _triggered(self, live, running, weights) -> bool:
        used = sum(self._kv_weight(r) for r in running)
        total_demand = int(weights.sum())
        mem_pressure = total_demand > self.cfg.memory_watermark * self.M \
            or used > self.cfg.memory_watermark * self.M
        if mem_pressure:
            return True
        # latency pressure: per-token latency at "everyone runs" batch size
        # would violate the most stringent TDS in the system. Per *token*,
        # not per iteration: a speculative step costs verify(k) but yields
        # E[accepted+1] tokens (SpeculativeLatencyModel folds that in; for
        # the baseline model per_token_latency IS iter_latency, bit-for-bit).
        stiffest = max((r.spec.tds for r in live), default=0.0)
        if stiffest <= 0:
            return False
        lat_all = self.lat.per_token_latency(len(live))
        return lat_all > 1.0 / stiffest

    def _admit_all(self, live, weights) -> List[Request]:
        order = sorted(range(len(live)), key=lambda i: live[i].arrival)
        used, keep = 0, []
        for i in order:
            if used + weights[i] <= self.M:
                keep.append(live[i])
                used += int(weights[i])
        return keep

    def _batch_bounds(self, live, weights) -> Tuple[int, int]:
        # B_max: most requests that fit in memory (shortest-first)
        w_sorted = np.sort(weights)
        fits = np.cumsum(w_sorted) <= self.M
        b_max = max(int(fits.sum()), 1)
        # B_min: largest B still faster than the stiffest TDS requirement
        stiffest = max((r.spec.tds for r in live), default=1.0)
        b_min = self.lat.max_batch_from_latency(1.0 / max(stiffest, 1e-9))
        b_min = max(1, min(b_min, b_max))
        return b_min, b_max

    def _serve_delay(self, r: Request) -> float:
        return self.pricer.serve_delay(r)

    def _solve(self, gains, weights, b) -> Tuple[np.ndarray, float]:
        """Algorithm 1: greedy packing by priority = gain / weight."""
        pri = gains / np.maximum(weights, 1)
        order = np.argsort(-pri)
        sel = np.zeros(len(gains), bool)
        used = used_n = 0
        value = 0.0
        for i in order:
            if used_n + 1 > b:
                break
            if used + weights[i] <= self.M:
                sel[i] = True
                used += int(weights[i])
                used_n += 1
                value += float(gains[i])
        return sel, value


class AndesDPScheduler(AndesScheduler):
    """Andes with the optimal 3-D dynamic program (Algorithm 2).

    Pseudo-polynomial O(M·N·B); memory is bucketed into `granularity`-token
    units to keep M tractable (the paper runs the DP at full granularity and
    finds it *slower end-to-end* than greedy — fig18 reproduces that)."""

    name = "andes_dp"
    solver = "dp"

    def __init__(self, *args, granularity: int = 64, **kw):
        super().__init__(*args, **kw)
        self.granularity = granularity

    def _solve(self, gains, weights, b):
        g = self.granularity
        w = np.maximum((weights + g - 1) // g, 1).astype(np.int64)
        m = self.M // g
        n = len(gains)
        b = min(b, n)
        NEG = -1e18
        # dp[j, c] = best value with j items and c memory units
        dp = np.full((b + 1, m + 1), NEG)
        dp[0, 0] = 0.0
        choice = np.zeros((n, b + 1, m + 1), np.bool_)
        for i in range(n):
            wi, gi = int(w[i]), float(gains[i])
            if wi > m:
                continue
            new = dp.copy()
            cand = dp[: b, : m + 1 - wi] + gi
            better = cand > new[1:, wi:]
            new[1:, wi:] = np.where(better, cand, new[1:, wi:])
            choice[i, 1:, wi:] = better
            dp = new
        # best exactly-B solution (paper formulation); fall back to best ≤ B
        flat = dp[b] if np.any(dp[b] > NEG / 2) else dp.max(axis=0)
        c = int(np.argmax(flat))
        j = b if np.any(dp[b] > NEG / 2) else int(np.argmax(dp[:, c]))
        value = float(dp[j, c]) if dp[j, c] > NEG / 2 else 0.0
        # backtrack
        sel = np.zeros(n, bool)
        for i in range(n - 1, -1, -1):
            if j > 0 and choice[i, j, c]:
                sel[i] = True
                j -= 1
                c -= int(w[i])
        if value <= 0.0 and not sel.any():
            return super()._solve(gains, weights, b)
        return sel, float(np.sum(gains[sel]))
