"""TokenFlow-style burst-preemptive policy (PAPERS.md).

TokenFlow's observation: the client-side token buffer (qoe.pace_delivery,
§5 of the paper) makes instantaneous server throughput per request
irrelevant — what matters is that no user's buffer runs dry. A request
whose buffer holds 5s of tokens can be paused for 4s with zero visible
impact, freeing the engine to absorb a burst of fresh arrivals whose
TTFT clocks are ticking.

The policy ranks every live request by *buffer slack* — the time until
its user starves:

    emitted requests      slack = buf / tds          (buffer drain time)
    never-emitted         slack = (arrival + ttft) − now   (TTFT countdown)

and serves smallest-slack-first under the KV budget, preempting
big-buffer requests to admit burst arrivals early. Preempted requests
bank no QoE damage while their buffer drains; they are re-admitted when
their slack decays below the frontier. The §4.2 #4 preemption cap is
enforced so pathological traces can't thrash.

Unlike Andes this needs no knapsack and no Δt prediction — it is the
purely reactive competitor: cheap, burst-robust, but blind to the
delivery *future* (it re-serves a starved request even when serving it
can no longer save its QoE, where Andes would cut the loss).
"""
from __future__ import annotations

from typing import List

from repro.core.policies.base import Scheduler
from repro.core.request import ReqState


class BurstPreemptiveScheduler(Scheduler):
    """Serve minimum-buffer-slack first; preempt big buffers for bursts."""

    name = "burst"
    enforces_preemption_cap = True

    def __init__(self, kv_capacity, lat, cfg=None, *,
                 slack_floor: float = 0.0):
        # slack_floor: treat slack below this as "already starving" —
        # such requests are mutually FCFS-ordered to avoid churn.
        self.slack_floor = slack_floor
        super().__init__(kv_capacity, lat, cfg)

    def _slack(self, now, r, fluid) -> float:
        i = r.fluid_idx
        if i is not None and i >= 0 and fluid.emitted[i] > 0:
            tds = max(float(fluid.tds_e[i]), 1e-9)
            return float(fluid.buf[i]) / tds
        return (r.arrival + r.spec.ttft) - now

    def schedule(self, now, live, fluid):
        self.iteration += 1
        if not live:
            return []
        # drain client buffers to `now` so buf reflects the present
        # (idempotent: backends have already advanced to now)
        fluid.advance(now)
        slacks = {r.rid: max(self._slack(now, r, fluid), self.slack_floor)
                  for r in live}
        ordered = sorted(live, key=lambda r: (slacks[r.rid],
                                              r.arrival, r.rid))
        keep = self._pack_in_order(ordered)
        running = [r for r in live if r.state == ReqState.RUNNING]
        weights = self._weights(live)
        keep = self._apply_preemption_cap(keep, running, weights, live)
        if self.obs is not None:
            vals = list(slacks.values())
            self._record_decision(now, live, keep, {
                "slack_min": float(min(vals)),
                "slack_max": float(max(vals)),
                "n_starving": sum(1 for s in vals if s <= self.slack_floor),
            })
        return keep
