"""The paper's primary contribution: QoE definition + QoE-aware scheduling."""
from repro.core.latency_model import (
    A40_4X,
    A100_4X,
    TPU_V5E,
    TPU_V5E_POD,
    HardwareSpec,
    LatencyModel,
    SpeculativeLatencyModel,
)
from repro.core.objectives import (
    FLEET_OBJECTIVES,
    fleet_avg_qoe,
    fleet_min_qoe,
    fleet_slo_attainment,
)
from repro.core.pricing import (
    QoEPricer,
    SLOContract,
    placement_gain,
    request_weight,
    shared_token_rate,
    slo_attained,
    weighted_attainment,
)
from repro.core.qoe import (
    FluidQoE,
    QoESpec,
    pace_delivery,
    predict_request_qoe,
    qoe_exact,
)
from repro.core.scheduler import (
    SCHEDULERS,
    AndesDPScheduler,
    AndesScheduler,
    FCFSScheduler,
    RoundRobinScheduler,
    Scheduler,
    SchedulerConfig,
    make_scheduler,
)
from repro.core.token_buffer import TokenBuffer

__all__ = [
    "QoESpec", "FluidQoE", "pace_delivery", "qoe_exact", "predict_request_qoe",
    "FLEET_OBJECTIVES", "fleet_avg_qoe", "fleet_min_qoe", "fleet_slo_attainment",
    "HardwareSpec", "LatencyModel", "SpeculativeLatencyModel",
    "TPU_V5E", "TPU_V5E_POD", "A100_4X", "A40_4X",
    "Scheduler", "SchedulerConfig", "FCFSScheduler", "RoundRobinScheduler",
    "AndesScheduler", "AndesDPScheduler", "SCHEDULERS", "make_scheduler",
    "TokenBuffer",
    "QoEPricer", "SLOContract", "placement_gain", "request_weight",
    "shared_token_rate", "slo_attained", "weighted_attainment",
]
