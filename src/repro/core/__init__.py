"""The paper's primary contribution: QoE definition + QoE-aware scheduling."""
from repro.core.latency_model import (
    A40_4X,
    A100_4X,
    TPU_V5E,
    TPU_V5E_POD,
    HardwareSpec,
    LatencyModel,
    SpeculativeLatencyModel,
)
from repro.core.objectives import (
    FLEET_OBJECTIVES,
    fleet_avg_qoe,
    fleet_min_qoe,
    fleet_slo_attainment,
)
from repro.core.pricing import (
    QoEPricer,
    SLOContract,
    placement_gain,
    request_weight,
    shared_token_rate,
    slo_attained,
    weighted_attainment,
)
from repro.core.qoe import (
    FluidQoE,
    QoESpec,
    pace_delivery,
    predict_request_qoe,
    qoe_exact,
)
from repro.core.policies import (
    SCHEDULERS,
    AndesDPScheduler,
    AndesScheduler,
    BurstPreemptiveScheduler,
    FCFSScheduler,
    RoundRobinScheduler,
    Scheduler,
    SchedulerConfig,
    SchedulingPolicy,
    VTCScheduler,
    WSCScheduler,
    make_scheduler,
)
from repro.core.scoring import (
    fairness_report,
    jains_index,
    max_min_service,
    per_tenant_service,
    slo_goodput,
)
from repro.core.network import (
    NETWORK_SCENARIOS,
    JitterLossLink,
    NetworkModel,
    make_network,
    qoe_under_network,
)
from repro.core.token_buffer import TokenBuffer

__all__ = [
    "QoESpec", "FluidQoE", "pace_delivery", "qoe_exact", "predict_request_qoe",
    "FLEET_OBJECTIVES", "fleet_avg_qoe", "fleet_min_qoe", "fleet_slo_attainment",
    "HardwareSpec", "LatencyModel", "SpeculativeLatencyModel",
    "TPU_V5E", "TPU_V5E_POD", "A100_4X", "A40_4X",
    "Scheduler", "SchedulerConfig", "SchedulingPolicy",
    "FCFSScheduler", "RoundRobinScheduler",
    "AndesScheduler", "AndesDPScheduler",
    "VTCScheduler", "WSCScheduler", "BurstPreemptiveScheduler",
    "SCHEDULERS", "make_scheduler",
    "jains_index", "slo_goodput", "per_tenant_service", "max_min_service",
    "fairness_report",
    "TokenBuffer",
    "NetworkModel", "JitterLossLink", "NETWORK_SCENARIOS", "make_network",
    "qoe_under_network",
    "QoEPricer", "SLOContract", "placement_gain", "request_weight",
    "shared_token_rate", "slo_attained", "weighted_attainment",
]
