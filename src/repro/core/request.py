"""Request lifecycle for the serving runtime (engine and simulator)."""
from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, List, Optional

import numpy as np

from repro.core.qoe import QoESpec, qoe_exact, tds_actual, ttft_actual

if TYPE_CHECKING:  # pricing imports request; annotation only, no cycle
    from repro.core.pricing import SLOContract


class ReqState(enum.Enum):
    WAITING = "waiting"      # queued, never served or preempted-by-recompute
    RUNNING = "running"      # in the current decode batch
    SWAPPED = "swapped"      # preempted; KV/state parked in host RAM
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    spec: QoESpec
    # ground-truth response length (simulator) / max tokens (engine)
    output_len: int
    prompt_tokens: Optional[np.ndarray] = None       # real engine only
    tenant: int = 0              # multi-tenant traces (cluster layer)
    priority: int = 0            # priority class (0 = default; pricing
                                 # weighs class p as (1+p)x, core.pricing)
    contract: Optional["SLOContract"] = None   # per-tenant SLO contract;
                                 # None prices as the uniform PR 1 default

    state: ReqState = ReqState.WAITING
    generated: int = 0
    emit_times: List[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    prefill_cursor: int = 0      # chunked prefill: context tokens already
                                 # committed to the device cache; 0 when not
                                 # mid-prefill (engine clears it on the
                                 # final chunk / recompute preemption)
    fluid_idx: int = -1          # slot in the scheduler's FluidQoE arrays
    engine_slot: int = -1        # slot in the static KV cache (engine)
    prefilled: bool = False      # KV/state for the prompt exists somewhere
    finish_time: float = float("nan")
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    cancelled: bool = False      # aborted by the client (server disconnect /
                                 # explicit cancel) — FINISHED early, partial
                                 # output; QoE reporting should exclude these

    def clone(self) -> "Request":
        """A fresh, unserved copy: identity fields (rid/arrival/lengths/
        spec/prompt/tenant) carried over, all lifecycle state reset.
        This is what differential tests and the cluster layer use to run
        the same workload through two backends."""
        return Request(
            rid=self.rid, arrival=self.arrival, prompt_len=self.prompt_len,
            spec=self.spec, output_len=self.output_len,
            prompt_tokens=self.prompt_tokens, tenant=self.tenant,
            priority=self.priority, contract=self.contract,
        )

    # ---- knapsack weight (l_i) -------------------------------------------
    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated

    def kv_tokens(self, state_equiv_tokens: int = 0) -> int:
        """Scheduler weight: KV entries consumed (attention archs grow with
        context; SSM archs pay a constant state, see DESIGN.md §4)."""
        if state_equiv_tokens:
            return state_equiv_tokens
        return max(self.context_len, 1)

    # ---- QoE reporting ------------------------------------------------------
    def final_qoe(self) -> float:
        return qoe_exact(
            np.asarray(self.emit_times), self.arrival, self.spec,
            response_len=self.generated,
        )

    def final_ttft(self) -> float:
        return ttft_actual(np.asarray(self.emit_times), self.arrival)

    def final_tds(self) -> float:
        return tds_actual(np.asarray(self.emit_times))

    @property
    def is_live(self) -> bool:
        return self.state != ReqState.FINISHED

    def normalized_latency(self) -> float:
        """End-to-end latency / output length (paper Appendix E)."""
        if not self.emit_times or self.generated == 0:
            return float("inf")
        return (self.emit_times[-1] - self.arrival) / self.generated
