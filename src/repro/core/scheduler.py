"""Backward-compat shim — the schedulers live in `repro.core.policies`.

This module used to hold every scheduler; they were extracted into the
policy-arena package (`repro.core.policies`: base protocol + FCFS /
round-robin / Andes / VTC / WSC / burst-preemptive) so new policies can
be added and refereed without touching the Andes code. Every name that
was importable from here still is.
"""
from repro.core.policies import (  # noqa: F401
    AndesDPScheduler,
    AndesScheduler,
    BurstPreemptiveScheduler,
    FCFSScheduler,
    RoundRobinScheduler,
    SCHEDULERS,
    Scheduler,
    SchedulerConfig,
    SchedulingPolicy,
    VTCScheduler,
    WSCScheduler,
    make_scheduler,
)

__all__ = [
    "AndesDPScheduler",
    "AndesScheduler",
    "BurstPreemptiveScheduler",
    "FCFSScheduler",
    "RoundRobinScheduler",
    "SCHEDULERS",
    "Scheduler",
    "SchedulerConfig",
    "SchedulingPolicy",
    "VTCScheduler",
    "WSCScheduler",
    "make_scheduler",
]
