"""Token-generation latency model (paper Appendix B) — roofline-derived.

The paper observes decode-iteration latency is (nearly) linear in batch
size B (Pearson 0.997 between B and total context tokens lets them drop the
latter). We keep that linear form but derive its coefficients from the
architecture + hardware roofline instead of fitting to A100 traces:

  iter_latency(B) = overhead
      + max( FLOPs(B) / (chips · peak · eff),  bytes(B) / (chips · bw · eff) )

  FLOPs(B)  = 2 · N_active · B            (one token per running request)
  bytes(B)  = param_bytes + B · avg_ctx · kv_bytes_per_token + B · state_bytes

Decode is memory-bound at practical batch sizes, which is exactly why the
paper's "generation speed ≫ user digest speed" slack exists. The same model
gives prefill latency (compute-bound) and the swap cost of Appendix D.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # per chip, bf16/fp16 FLOP/s
    hbm_bw: float              # per chip, bytes/s
    link_bw: float             # per chip ICI/NVLink, bytes/s
    chips: int = 1
    host_dma_bw: float = 25e9  # device<->host for KV swap, bytes/s
    efficiency: float = 0.55   # achieved fraction of roofline
    overhead: float = 0.004    # fixed per-iteration launch/scheduling (s)


TPU_V5E = HardwareSpec("tpu-v5e", 197e12, 819e9, 50e9)
TPU_V5E_POD = dataclasses.replace(TPU_V5E, chips=256)
# Calibrated to the paper's observed OPT-66B behavior on 4xA100 with vLLM
# (Fig. 3b: ~6.6 tok/s per-request generation speed at operating batch,
# aggregate ~700 tok/s at rate 3.3; pairwise-NVLink topology makes TP
# all-reduces expensive, hence the modest achieved roofline fraction).
A100_4X = HardwareSpec("4xA100", 312e12, 2.0e12, 300e9, chips=4,
                       efficiency=0.35, overhead=0.015)
A100_1X = dataclasses.replace(A100_4X, chips=1, efficiency=0.50,
                              overhead=0.006)
A40_4X = HardwareSpec("4xA40", 150e12, 696e9, 64e9, chips=4,
                      efficiency=0.40, overhead=0.015)


class LatencyModel:
    """Analytic latency for decode / prefill / swap on a given deployment."""

    def __init__(
        self,
        cfg: ModelConfig,
        hw: HardwareSpec,
        *,
        dtype_bytes: int = 2,
        avg_ctx: int = 512,
    ):
        self.cfg = cfg
        self.hw = hw
        self.dtype_bytes = dtype_bytes
        self.avg_ctx = avg_ctx
        self.param_bytes = cfg.param_count() * dtype_bytes
        self.active_params = cfg.active_param_count()
        self.kv_tok_bytes = cfg.kv_bytes_per_token(dtype_bytes)
        self.state_bytes = cfg.ssm_state_bytes()
        self._agg_flops = hw.peak_flops * hw.chips * hw.efficiency
        self._agg_bw = hw.hbm_bw * hw.chips * hw.efficiency

    # -- decode ---------------------------------------------------------------

    def iter_latency(self, batch_size: int, total_ctx: int | None = None) -> float:
        """One continuous-batching decode iteration (s)."""
        if batch_size <= 0:
            return self.hw.overhead
        ctx = total_ctx if total_ctx is not None else batch_size * self.avg_ctx
        flops = 2.0 * self.active_params * batch_size
        bytes_ = (
            self.param_bytes
            + ctx * self.kv_tok_bytes
            + batch_size * self.state_bytes
        )
        return self.hw.overhead + max(flops / self._agg_flops,
                                      bytes_ / self._agg_bw)

    def token_rate(self, batch_size: int, total_ctx: int | None = None) -> float:
        """Per-request decode speed (tokens/s) at batch size B."""
        return 1.0 / self.iter_latency(batch_size, total_ctx)

    def iter_latency_schedule(self, batch_size: int, total_ctx: int,
                              steps: int) -> "list[float]":
        """Per-iteration latencies of `steps` consecutive decode iterations
        at a fixed batch: every iteration emits one token per request, so
        the context term grows by batch_size per step. Deterministic — this
        is what lets the engine's multi-step decode fast path reconstruct
        per-step virtual-clock emit timestamps EXACTLY (the same
        `iter_latency` calls, in the same order, the one-step loop makes)
        and what the planner uses to bound a block by the next pending
        arrival before dispatching it."""
        out = []
        ctx = total_ctx
        for _ in range(steps):
            out.append(self.iter_latency(batch_size, ctx))
            ctx += batch_size
        return out

    def per_token_latency(self, batch_size: int,
                          total_ctx: int | None = None) -> float:
        """Seconds per *emitted* token. For the one-token-per-iteration
        baseline this IS iter_latency (the scheduler's pacing checks call
        this so the speculative model can report iter/E[tokens] instead
        without perturbing baseline float behavior bit-for-bit)."""
        return self.iter_latency(batch_size, total_ctx)

    def verify_latency(self, batch_size: int, total_ctx: int | None = None,
                       k: int = 0) -> float:
        """One speculative verify pass: k+1 positions per request in a
        single forward. FLOPs scale with the window ((k+1)x decode), but
        HBM traffic is still dominated by the one weight/KV pass — that
        asymmetry (decode is memory-bound, Appendix B) is the entire
        speculative-decoding bargain: ~one iteration's wall time buys up
        to k+1 tokens."""
        if batch_size <= 0:
            return self.hw.overhead
        ctx = total_ctx if total_ctx is not None else batch_size * self.avg_ctx
        flops = 2.0 * self.active_params * batch_size * (k + 1)
        bytes_ = (
            self.param_bytes
            + (ctx + batch_size * (k + 1)) * self.kv_tok_bytes
            + batch_size * self.state_bytes
        )
        return self.hw.overhead + max(flops / self._agg_flops,
                                      bytes_ / self._agg_bw)

    # -- prefill ----------------------------------------------------------------

    def prefill_latency(self, prompt_tokens: int) -> float:
        """Prompt processing (compute-bound)."""
        flops = 2.0 * self.active_params * prompt_tokens
        bytes_ = self.param_bytes
        return self.hw.overhead + max(flops / self._agg_flops,
                                      bytes_ / self._agg_bw)

    def prefill_chunk_latency(self, chunk_tokens: int,
                              ctx_tokens: int) -> float:
        """One chunked-prefill step: process `chunk_tokens` new prompt
        tokens whose attention spans `ctx_tokens` of accumulated context.
        Every chunk pays the fixed launch overhead, a full weight pass,
        and the KV traffic of the prefix it attends over — so the summed
        chunk cost strictly dominates the monolithic `prefill_latency`
        and a chunked prompt's own TTFT under contention is honest (the
        win is the residents it stops stalling, not its own latency)."""
        flops = 2.0 * self.active_params * chunk_tokens
        bytes_ = self.param_bytes + ctx_tokens * self.kv_tok_bytes
        return self.hw.overhead + max(flops / self._agg_flops,
                                      bytes_ / self._agg_bw)

    def chunked_prefill_latency(self, total_tokens: int, chunk: int,
                                start: int = 0) -> float:
        """Total remaining prefill cost of a prompt split at `chunk`
        tokens, resuming from a cursor at `start`. Prompts that fit one
        chunk take the monolithic path (same float path as
        `prefill_latency` — the engine's degenerate-case oracle).
        `QoEPricer.serve_delay` prices a partially-prefilled resident by
        the chunks it still owes through this."""
        if chunk <= 0 or total_tokens <= chunk:
            return self.prefill_latency(total_tokens - start)
        t = 0.0
        cur = start
        while cur < total_tokens:
            step = min(chunk, total_tokens - cur)
            cur += step
            t += self.prefill_chunk_latency(step, cur)
        return t

    # -- preemption (Appendix D) --------------------------------------------------

    def swap_latency(self, ctx_tokens: int) -> float:
        """Move a request's KV/state to (or from) host RAM."""
        bytes_ = ctx_tokens * self.kv_tok_bytes + self.state_bytes
        return bytes_ / self.hw.host_dma_bw

    def recompute_latency(self, ctx_tokens: int) -> float:
        return self.prefill_latency(ctx_tokens)

    # -- capacity ----------------------------------------------------------------

    def max_batch_from_latency(self, max_iter_latency: float) -> int:
        """Largest B whose iteration latency stays under the bound
        (used for B_min pruning: tokens must flow at the stiffest TDS)."""
        lo, hi = 1, 1 << 20
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.iter_latency(mid) <= max_iter_latency:
                lo = mid
            else:
                hi = mid - 1
        return lo


class SpeculativeLatencyModel(LatencyModel):
    """Cost model for a speculative engine step: k+1 greedy draft decodes
    plus one (k+1)-position target verify, yielding 1..k+1 tokens.

    The scheduler prices QoE gains in tokens/s; with speculation that rate
    is E[accepted+1] / step_latency, where the expected accepted length is
    a deterministic EMA of the engine's observed acceptance counts
    (`observe_acceptance`, updated after every verify). All pacing entry
    points the Andes scheduler uses — `token_rate` for Q_serve(B),
    `per_token_latency` for the latency-pressure trigger,
    `max_batch_from_latency` for B_min — account for the expected burst,
    so knapsack pricing and preemption decisions see the true delivery
    speed of a speculative replica. `prefill_latency` / `swap_latency`
    include the draft's share: a speculative request prefills and parks
    *two* caches (Appendix D accounting, extended).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        hw: HardwareSpec,
        draft_cfg: ModelConfig,
        *,
        k: int,
        dtype_bytes: int = 2,
        avg_ctx: int = 512,
        accept_prior: float = 0.5,
        ema_alpha: float = 0.05,
    ):
        super().__init__(cfg, hw, dtype_bytes=dtype_bytes, avg_ctx=avg_ctx)
        if k < 1:
            raise ValueError(f"speculation needs k >= 1, got {k}")
        self.k = int(k)
        self.draft = LatencyModel(draft_cfg, hw, dtype_bytes=dtype_bytes,
                                  avg_ctx=avg_ctx)
        self._exp_accept0 = float(accept_prior) * self.k
        self._exp_accept = self._exp_accept0
        self._alpha = float(ema_alpha)

    def observe_acceptance(self, accepted: int) -> None:
        """Feed one verify outcome (0..k accepted) into the EMA."""
        self._exp_accept += self._alpha * (accepted - self._exp_accept)

    def reset(self) -> None:
        """Restore the acceptance EMA to its prior. ServingEngine.reset()
        calls this so back-to-back run() calls on one speculative engine
        price (and therefore clock) exactly like a fresh engine."""
        self._exp_accept = self._exp_accept0

    @property
    def expected_step_tokens(self) -> float:
        """E[tokens emitted per step] = E[accepted] + 1 (correction/bonus)."""
        return 1.0 + self._exp_accept

    # -- one speculative step -------------------------------------------------

    def iter_latency(self, batch_size: int, total_ctx: int | None = None) -> float:
        if batch_size <= 0:
            return self.hw.overhead
        return ((self.k + 1) * self.draft.iter_latency(batch_size, total_ctx)
                + self.verify_latency(batch_size, total_ctx, self.k))

    def token_rate(self, batch_size: int, total_ctx: int | None = None) -> float:
        return self.expected_step_tokens / self.iter_latency(batch_size, total_ctx)

    def per_token_latency(self, batch_size: int,
                          total_ctx: int | None = None) -> float:
        return self.iter_latency(batch_size, total_ctx) / self.expected_step_tokens

    # -- both caches move -----------------------------------------------------

    def prefill_latency(self, prompt_tokens: int) -> float:
        return (super().prefill_latency(prompt_tokens)
                + self.draft.prefill_latency(prompt_tokens))

    def swap_latency(self, ctx_tokens: int) -> float:
        return (super().swap_latency(ctx_tokens)
                + self.draft.swap_latency(ctx_tokens))

    def max_batch_from_latency(self, max_iter_latency: float) -> int:
        """Largest B whose *per-token* latency stays under the bound."""
        lo, hi = 1, 1 << 20
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.per_token_latency(mid) <= max_iter_latency:
                lo = mid
            else:
                hi = mid - 1
        return lo
