"""Network link models for the client-side token path (Eloquent-style).

The paper's §5 client buffer assumes tokens arrive at the user exactly
when the server emits them. Over a real wire they do not: token streams
cross links with propagation delay, jitter, and loss, and Eloquent
(PAPERS.md) shows that streaming QoE is dominated by how the transport
turns those impairments into *stalls*. This module makes the link a
pluggable scenario axis:

  * `NetworkModel` — the identity link (arrival == emission), the default
    everywhere so existing timelines are byte-identical;
  * `JitterLossLink` — one-way propagation `delay`, exponential `jitter`,
    and per-token loss with an `rto` retransmission penalty, delivered
    IN ORDER (SSE rides TCP, so a delayed token head-of-line-blocks every
    later one: arrival_i = max(arrival_{i-1}, emit_i + latency_i));
  * `NETWORK_SCENARIOS` — a named catalog (ideal/broadband/wifi/lte/
    satellite/lossy_wifi) used by tests, benchmarks, and per-tenant
    workload specs.

Determinism and monotone coupling: every per-token draw is derived from a
seeded generator *by token index*, independent of impairment knobs — the
jitter of token i is `jitter * exp_i` for a fixed exponential draw, and
its retransmission count is the largest k with `u_i <= loss^k` for a
fixed uniform draw. The same seed therefore yields latencies that are
pointwise non-decreasing in `delay`, `jitter`, `loss`, and `rto`, which
is what lets tests assert "QoE degrades monotonically with loss" as an
exact property instead of a statistical one.

The §5 buffer composes with any of these (`TokenBuffer(tds,
network=...)`, `pace_delivery(..., network=...)`): the buffer paces the
post-link arrival timeline, absorbing jitter up to its accumulated lead.
`qoe_under_network` evaluates Eq. 1 on that degraded timeline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.qoe import QoESpec, qoe_exact


class NetworkModel:
    """Identity link: tokens arrive the instant they are emitted.

    Subclasses override `latency(i)` (the one-way transit of the i-th
    token of a stream, independent of emission time) and inherit the
    in-order delivery rule. The model is *stateful per stream*: call
    `reset()` (or use a fresh instance / `clone()`) before replaying
    another stream so the head-of-line cursor and the per-index draws
    restart identically.
    """

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self._count = 0
        self._last_arrival = -np.inf

    def clone(self) -> "NetworkModel":
        """A fresh same-configuration link (for replaying a stream)."""
        return type(self)()

    # ------------------------------------------------------------- per-token
    def latency(self, i: int) -> float:
        """One-way transit latency of the i-th token (seconds)."""
        return 0.0

    def transit(self, emit_time: float) -> float:
        """Arrival time of the next token emitted at `emit_time`.

        In-order (TCP) delivery: a token can never arrive before its
        predecessor, so one slow transit head-of-line-blocks the rest.
        """
        i = self._count
        self._count += 1
        arr = max(self._last_arrival, float(emit_time) + self.latency(i))
        self._last_arrival = arr
        return arr

    def arrivals(self, emit_times) -> np.ndarray:
        """Vectorized `transit` over a whole emission timeline (resets the
        stream first, so it is a pure function of the timeline)."""
        self.reset()
        e = np.asarray(emit_times, np.float64)
        out = np.empty_like(e)
        for i in range(e.size):
            out[i] = self.transit(e[i])
        self.reset()
        return out


@dataclasses.dataclass
class JitterLossLink(NetworkModel):
    """Delay + jitter + loss link with in-order delivery (module docstring).

    delay   one-way propagation + serialization floor (s)
    jitter  scale of an exponential per-token jitter term (s)
    loss    per-transmission loss probability; each loss costs `rto`
    rto     retransmission timeout charged per lost transmission (s)
    seed    per-stream draw seed (same seed => coupled, monotone draws)
    """
    delay: float = 0.0
    jitter: float = 0.0
    loss: float = 0.0
    rto: float = 0.2
    seed: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.loss < 1.0:
            raise ValueError(f"loss must be in [0, 1), got {self.loss}")
        self._exp_draws: List[float] = []   # fixed per-index draws,
        self._uni_draws: List[float] = []   #   independent of the knobs
        super().__init__()

    def clone(self) -> "JitterLossLink":
        return JitterLossLink(delay=self.delay, jitter=self.jitter,
                              loss=self.loss, rto=self.rto, seed=self.seed)

    def _draws(self, i: int) -> tuple:
        """(exponential, uniform) draws for token index i — extended
        lazily in index order from one seeded generator, so they depend
        only on (seed, i), never on the impairment parameters."""
        if i >= len(self._exp_draws):
            while len(self._exp_draws) <= i:
                rng = np.random.default_rng((self.seed,
                                             len(self._exp_draws)))
                self._exp_draws.append(float(rng.exponential()))
                u = float(rng.random())
                # guard the open interval so log(u) is finite
                self._uni_draws.append(min(max(u, 1e-12), 1.0 - 1e-12))
        return self._exp_draws[i], self._uni_draws[i]

    def retransmissions(self, i: int) -> int:
        """Lost transmissions before token i got through: the largest k
        with u_i <= loss^k (geometric by inversion — monotone in loss)."""
        if self.loss <= 0.0:
            return 0
        _, u = self._draws(i)
        return int(np.floor(np.log(u) / np.log(self.loss)))

    def latency(self, i: int) -> float:
        exp_draw, _ = self._draws(i)
        return (self.delay + self.jitter * exp_draw
                + self.rto * self.retransmissions(i))


def qoe_under_network(emit_times, arrival: float, spec: QoESpec,
                      network: Optional[NetworkModel] = None) -> float:
    """Eq. 1 QoE of a served request as experienced *behind* a link:
    the server emission timeline is pushed through the network model and
    the client buffer paces what actually arrives."""
    e = np.asarray(emit_times, np.float64)
    if network is not None:
        e = network.arrivals(e)
    return qoe_exact(e, arrival, spec, response_len=e.size)


# ---------------------------------------------------------------------------
# Scenario catalog
# ---------------------------------------------------------------------------

#: Named link conditions (rough consumer-access characterizations — the
#: point is a shared ordinal axis from clean to hostile, not calibration).
NETWORK_SCENARIOS: Dict[str, dict] = {
    "ideal":      dict(delay=0.0,   jitter=0.0,   loss=0.0),
    "broadband":  dict(delay=0.02,  jitter=0.005, loss=0.0),
    "wifi":       dict(delay=0.03,  jitter=0.02,  loss=0.005, rto=0.15),
    "lte":        dict(delay=0.06,  jitter=0.04,  loss=0.01,  rto=0.2),
    "satellite":  dict(delay=0.3,   jitter=0.05,  loss=0.01,  rto=0.6),
    "lossy_wifi": dict(delay=0.03,  jitter=0.03,  loss=0.08,  rto=0.25),
}


def make_network(name: str, seed: int = 0) -> NetworkModel:
    """Instantiate a scenario by name (see NETWORK_SCENARIOS)."""
    try:
        kw = NETWORK_SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown network scenario {name!r}; "
            f"known: {sorted(NETWORK_SCENARIOS)}") from None
    if name == "ideal":
        return NetworkModel()
    return JitterLossLink(seed=seed, **kw)


__all__ = [
    "NetworkModel", "JitterLossLink", "qoe_under_network",
    "NETWORK_SCENARIOS", "make_network",
]
