"""Client-side token buffer (paper §5, Fig. 8) — incremental form.

The server streams tokens as fast as it generates them; the buffer shows
them to the user at the expected TDS, absorbing generation burstiness and
network jitter. The first token is displayed on arrival.

An optional `network` model (repro.core.network) sits between the server
emission and the buffer: `push(emit_time)` is then the *server-side*
timestamp, transited through the link (delay/jitter/loss, in-order) before
the buffer paces it. The default (None) keeps arrival == emission, so all
existing timelines are unchanged.
"""
from __future__ import annotations

from typing import List, Optional, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (network -> qoe)
    from repro.core.network import NetworkModel


class TokenBuffer:
    def __init__(self, tds: float, network: "Optional[NetworkModel]" = None):
        self.gap = 1.0 / tds
        self.network = network
        self.deliveries: List[float] = []
        self.arrivals: List[float] = []
        self._last: Optional[float] = None

    def push(self, emit_time: float) -> float:
        """Register a server emission; returns the user-visible display time."""
        if self.network is not None:
            emit_time = self.network.transit(emit_time)
        self.arrivals.append(emit_time)
        d = emit_time if self._last is None else max(emit_time, self._last + self.gap)
        self._last = d
        self.deliveries.append(d)
        return d

    def buffered_at(self, t: float) -> int:
        """Tokens received but not yet displayed at time t."""
        return sum(1 for d in self.deliveries if d > t)

    def __len__(self) -> int:
        return len(self.deliveries)
