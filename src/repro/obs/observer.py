"""The Observer protocol: one hook surface for every layer of the stack.

Backends (ServingSimulator / ServingEngine / ClusterSimulator), the
scheduler, and the cluster control plane all report through a single
`Observer` object. The base class is the *null* implementation — every
hook is a no-op — and the default everywhere is `None`, so instrumentation
sites cost exactly one `is not None` test when observability is off.

Hook taxonomy (all timestamps are virtual-clock seconds):

  request lifecycle   submit admit prefill emit preempt swap_in finish
                      shed defer cancel
  scheduler           schedule (decision payload: pricing inputs, victim
                      set), multi_step (idle_steps certificate j)
  fleet               route admission scale
  hot path            sync dispatch jit_compile spec
  wire / server       connection sse_flush drain (repro.server; `t` is the
                      serving clock — wall seconds for a wall engine)

Every hook takes a keyword-only ``replica`` (default -1 = "not a cluster
replica" / fleet-level). `ScopedObserver` stamps it so one observer
attached at the cluster level sees which replica each event came from.

Composition:

  MultiObserver      fan out one event stream to several observers
  ScopedObserver     tag events with a replica id
  EventSinkAdapter   adapt an Observer stream back onto PR 4's legacy
                     ``sink(kind, req, t, k)`` callable (deprecated)
  compose(*obs)      None-tolerant combinator returning None / the single
                     observer / a flattened MultiObserver
"""
from __future__ import annotations

from typing import Callable, Optional


class Observer:
    """Null observer: subclass and override only the hooks you need.

    Contract notes:
      * hooks must not mutate the request or any engine state — the
        differential oracle (tests/test_obs.py) asserts instrumented runs
        are bit-for-bit identical to uninstrumented ones;
      * ``t`` is the virtual clock of the emitting backend;
      * ``replica`` is keyword-only and already stamped when the event
        crossed a cluster boundary (-1 means single-node / fleet-level).
    """

    # ---- request lifecycle -------------------------------------------------
    def submit(self, req, t, *, replica=-1):
        """Request entered the system (arrival)."""

    def admit(self, req, t, *, replica=-1):
        """Request became visible to the scheduler (joined the live set)."""

    def prefill(self, req, t, n_tokens, *, replica=-1):
        """Prompt (or recompute) prefill of `n_tokens` charged at `t`."""

    def prefill_chunk(self, req, t, cursor, total, *, replica=-1):
        """One chunk of a chunked prefill committed: `cursor` of `total`
        context tokens are now resident on-device (the `prefill` hook
        still fires once when the final chunk lands)."""

    def emit(self, req, t, k=1, *, replica=-1):
        """`k` tokens delivered to the client at `t`."""

    def preempt(self, req, t, mode="swap", *, replica=-1):
        """Request evicted from the batch (`mode`: "swap"|"recompute")."""

    def swap_in(self, req, t, *, replica=-1):
        """Swapped-out request restored to the device."""

    def finish(self, req, t, *, replica=-1):
        """Request completed its full response."""

    def shed(self, req, t, *, replica=-1):
        """Admission control rejected the request outright."""

    def defer(self, req, t, *, replica=-1):
        """Admission control pushed the request back into the queue."""

    def cancel(self, req, t, *, replica=-1):
        """Request aborted by the client (disconnect / explicit cancel)
        before completing; `req.generated` tokens had been emitted."""

    # ---- scheduler ---------------------------------------------------------
    def schedule(self, t, info, *, replica=-1):
        """One scheduler decision. `info` is a JSON-able dict: policy,
        n_live, chosen rids, and (for QoE-aware policies) the pricing
        inputs — candidate batch sizes, chosen B, knapsack value, gains,
        victim set."""

    def multi_step(self, t, j, committed, *, replica=-1):
        """Engine ran a fused block of `j` decode iterations under a
        scheduler `idle_steps` certificate, committing `committed` tokens."""

    def persistent_loop(self, t, j, steps, *, replica=-1):
        """The fused block ran as a device-resident while_loop: planned
        `j` iterations, the device executed `steps` (< j only when every
        active row emitted its EOS early). Fires IN ADDITION to
        `multi_step` — the persistent path is a strict specialization."""

    # ---- fleet -------------------------------------------------------------
    def route(self, req, t, replica_id, gain, scores, *, replica=-1):
        """Router picked `replica_id`; `scores` maps replica id -> marginal
        QoE gain (None for score-free policies)."""

    def admission(self, req, t, action, gain, *, replica=-1):
        """Admission verdict: action in {"admit","shed","defer"}."""

    def scale(self, t, action, replica_id, signal=None, *, replica=-1):
        """Autoscaler event: action in {"scale_up","scale_down","reap",
        "provision_ready"}; `signal` is the attainment/pressure snapshot
        that triggered it (when available)."""

    # ---- hot path ----------------------------------------------------------
    def sync(self, t, n=1, *, replica=-1):
        """`n` host<->device synchronizations (device_get / blocking read)."""

    def dispatch(self, t, kind, n=1, *, replica=-1):
        """`n` device computation dispatches of `kind` (prefill / write /
        decode / decode_multi / spec_fused / propose / verify / read)."""

    def jit_compile(self, t, key, *, replica=-1):
        """A new jit shape signature `key` entered the compile cache."""

    def spec(self, t, proposed, accepted, *, replica=-1):
        """One speculative iteration: drafted `proposed`, accepted
        `accepted` tokens (acceptance rate = accepted/proposed)."""

    # ---- wire / server (repro.server) --------------------------------------
    # `t` on these hooks is the *serving* clock (wall seconds since server
    # start for a wall-clock engine) and `conn_id` a server-unique integer
    # per accepted TCP connection.
    def connection(self, t, conn_id, event, info=None, *, replica=-1):
        """Connection lifecycle: event in {"open","request","close",
        "disconnect","reject"}; `info` is a small JSON-able dict (peer,
        path, rid, ...) when available."""

    def sse_flush(self, t, conn_id, rid, n_events, n_bytes, *, replica=-1):
        """`n_events` server-sent events (`n_bytes` on the wire) flushed
        to connection `conn_id` for request `rid`."""

    def drain(self, t, phase, conns, live, *, replica=-1):
        """Graceful-shutdown progress: phase in {"begin","waiting",
        "done","timeout"} with `conns` open connections and `live`
        unfinished requests remaining."""


#: Every hook name, in canonical order. MultiObserver / ScopedObserver
#: forwarders are generated from this list so new hooks only need a
#: definition on Observer plus an entry here.
HOOK_NAMES = (
    "submit", "admit", "prefill", "prefill_chunk", "emit", "preempt",
    "swap_in", "finish",
    "shed", "defer", "cancel",
    "schedule", "multi_step", "persistent_loop",
    "route", "admission", "scale",
    "sync", "dispatch", "jit_compile", "spec",
    "connection", "sse_flush", "drain",
)


def _is_null_hook(bound: Callable, name: str) -> bool:
    """True when `bound` is the inherited no-op from the Observer base
    (works for both class methods and instance-attribute closures)."""
    return getattr(bound, "__func__", None) is getattr(Observer, name)


class MultiObserver(Observer):
    """Fan a single event stream out to several observers, in order.

    Forwarders are pre-bound per hook at construction (the children tuple
    is immutable): a hook no child overrides inherits the Observer no-op,
    a single-consumer hook IS that child's bound method (no wrapper), and
    only genuinely shared hooks pay a fan-out loop. This keeps a full
    trace+metrics+profiling stack inside the engine benchmark's ~2%
    overhead budget on per-token events."""

    def __init__(self, *children: Observer):
        self.children = tuple(c for c in children if c is not None)
        for name in HOOK_NAMES:
            targets = tuple(getattr(c, name) for c in self.children
                            if not _is_null_hook(getattr(c, name), name))
            if not targets:
                continue                      # inherit the class no-op
            if len(targets) == 1:
                setattr(self, name, targets[0])
            else:
                setattr(self, name, _fanout(targets))


def _fanout(targets: tuple) -> Callable:
    if len(targets) == 2:           # the common full-stack case, loop-free
        f1, f2 = targets

        def hook(*args, **kwargs):
            f1(*args, **kwargs)
            f2(*args, **kwargs)
        return hook

    def hook(*args, **kwargs):
        for f in targets:
            f(*args, **kwargs)
    return hook


class ScopedObserver(Observer):
    """Stamp every forwarded event with a replica id.

    The cluster installs one of these on each replica backend so a single
    observer attached at the cluster level can attribute events. An
    already-stamped event (replica != -1) passes through untouched.
    Forwarders are pre-bound like MultiObserver's: hooks the inner
    observer does not consume stay the inherited no-op."""

    def __init__(self, inner: Observer, replica: int):
        self.inner = inner
        self.replica = replica
        for name in HOOK_NAMES:
            bound = getattr(inner, name)
            if not _is_null_hook(bound, name):
                setattr(self, name, _scoped(bound, replica))


def _scoped(bound: Callable, stamp: int) -> Callable:
    def hook(*args, replica=-1, **kwargs):
        bound(*args, replica=stamp if replica == -1 else replica, **kwargs)
    return hook


class EventSinkAdapter(Observer):
    """Adapter from the Observer stream to PR 4's legacy ``event_sink``.

    .. deprecated::
        ``backend.event_sink = fn`` (a ``fn(kind, req, t, k)`` callable
        receiving kinds emit/preempt/finish/shed/defer) predates the
        Observer protocol. It keeps working — backends wrap an assigned
        sink in this adapter and compose it with any installed observer —
        but new code should subclass :class:`Observer`, which also sees
        scheduler, fleet, and hot-path events the sink never carried.
    """

    def __init__(self, sink: Callable):
        self.sink = sink

    def emit(self, req, t, k=1, *, replica=-1):
        self.sink("emit", req, t, k)

    def preempt(self, req, t, mode="swap", *, replica=-1):
        self.sink("preempt", req, t, 0)

    def finish(self, req, t, *, replica=-1):
        self.sink("finish", req, t, 0)

    def shed(self, req, t, *, replica=-1):
        self.sink("shed", req, t, 0)

    def defer(self, req, t, *, replica=-1):
        self.sink("defer", req, t, 0)


def compose(*observers: Optional[Observer]) -> Optional[Observer]:
    """Combine observers, tolerating None: returns None when empty, the
    lone observer when singular, otherwise a flattened MultiObserver."""
    flat = []
    for obs in observers:
        if obs is None:
            continue
        if isinstance(obs, MultiObserver):
            flat.extend(obs.children)
        else:
            flat.append(obs)
    if not flat:
        return None
    if len(flat) == 1:
        return flat[0]
    return MultiObserver(*flat)
