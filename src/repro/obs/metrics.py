"""Metrics registry: counters / gauges / histograms with Prometheus-text
and JSON export, virtual-clock snapshots, and a `MetricsObserver` that
derives the Andes QoE metric family from the Observer event stream.

Everything is plain Python and allocation-light: a metric series is a
dict entry keyed by its label values. Gauges may be *bound* to a callable
(`set_fn`) so exports read live state — e.g. KV slot occupancy straight
off `engine.kv` — without per-step bookkeeping; bindings survive
`engine.reset()` because `KVSlotManager.reset()` clears in place.

Export / ingest:

  to_prometheus()     Prometheus text exposition (HELP/TYPE, labels,
                      histogram _bucket/_sum/_count with cumulative
                      counts and a +Inf bucket)
  parse_prometheus()  inverse of the above (for round-trip testing and
                      scraping our own output); label values must not
                      contain '",' or newlines
  to_json/from_json   lossless structural round-trip
  snapshot(t)         append a timestamped sample set (driven by the
                      virtual clock via MetricsObserver.snapshot_every)
"""
from __future__ import annotations

import json
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.pricing import request_weight, slo_attained
from repro.core.qoe import tds_actual, ttft_actual
from repro.obs.observer import Observer

_INF = float("inf")


def _fmt(v: float) -> str:
    """Exact float formatting (repr round-trips doubles)."""
    if v == _INF:
        return "+Inf"
    if v == -_INF:
        return "-Inf"
    if isinstance(v, float) and v.is_integer() and abs(v) < 1e15:
        return repr(v)
    return repr(float(v))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: Dict[Tuple, object] = {}

    def _key(self, labels: Dict[str, str]) -> Tuple:
        # fast paths: unlabeled metrics dominate the hot emit/sync/dispatch
        # stream, and the overhead gate in benchmarks/engine_hotpath.py
        # budgets the whole observer stack at ~2% of engine wall clock —
        # so no set() construction on the labeled path either
        if not labels and not self.labelnames:
            return ()
        try:
            key = tuple(str(labels[n]) for n in self.labelnames)
        except KeyError:
            key = None
        if key is None or len(labels) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(labels)}")
        return key

    def _labels_dict(self, key: Tuple) -> Dict[str, str]:
        return dict(zip(self.labelnames, key))


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        cur = self._series.get(key, 0.0)
        if callable(cur):
            raise TypeError(f"{self.name}: cannot inc a bound counter")
        self._series[key] = cur + amount

    def set_fn(self, fn: Callable[[], float], **labels) -> None:
        """Bind this series to a callable read at export/snapshot time.

        Hot observers (MetricsObserver, ProfilingObserver) count in plain
        instance attributes and bind the counter to a reader, so the
        per-event cost is one `+=` instead of a metric lookup — the same
        pattern Gauge.set_fn uses for live state."""
        self._series[self._key(labels)] = fn

    def value(self, **labels) -> float:
        v = self._series.get(self._key(labels), 0.0)
        return float(v()) if callable(v) else float(v)

    def samples(self):
        for key, v in self._series.items():
            yield self.name, self._labels_dict(key), \
                float(v()) if callable(v) else float(v)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = float(value)

    def set_fn(self, fn: Callable[[], float], **labels) -> None:
        """Bind this series to a callable read at export/snapshot time."""
        self._series[self._key(labels)] = fn

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        cur = self._series.get(key, 0.0)
        if callable(cur):
            raise TypeError(f"{self.name}: cannot inc a bound gauge")
        self._series[key] = cur + amount

    def value(self, **labels) -> float:
        v = self._series.get(self._key(labels), 0.0)
        return float(v()) if callable(v) else float(v)

    def samples(self):
        for key, v in self._series.items():
            yield self.name, self._labels_dict(key), \
                float(v()) if callable(v) else float(v)


class Histogram(_Metric):
    kind = "histogram"
    DEFAULT_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
                       50.0, 100.0)

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Optional[Sequence[float]] = None):
        super().__init__(name, help, labelnames)
        bs = tuple(sorted(buckets if buckets is not None
                          else self.DEFAULT_BUCKETS))
        if not bs or bs[-1] != _INF:
            bs = bs + (_INF,)
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        st = self._series.get(key)
        if st is None:
            st = self._series[key] = {"counts": [0] * len(self.buckets),
                                      "sum": 0.0, "count": 0}
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                st["counts"][i] += 1
                break
        st["sum"] += value
        st["count"] += 1

    def count(self, **labels) -> int:
        st = self._series.get(self._key(labels))
        return int(st["count"]) if st else 0

    def sum(self, **labels) -> float:
        st = self._series.get(self._key(labels))
        return float(st["sum"]) if st else 0.0

    def samples(self):
        for key, st in self._series.items():
            labels = self._labels_dict(key)
            cum = 0
            for ub, c in zip(self.buckets, st["counts"]):
                cum += c
                yield (self.name + "_bucket",
                       {**labels, "le": _fmt(float(ub))}, float(cum))
            yield self.name + "_sum", labels, float(st["sum"])
            yield self.name + "_count", labels, float(st["count"])


class MetricsRegistry:
    """Ordered get-or-create registry of named metrics."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self.snapshots: List[Dict] = []

    # ------------------------------------------------------------- creation
    def _get_or_create(self, cls, name, help, labelnames, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(f"{name} already registered as {m.kind}")
            return m
        m = cls(name, help, labelnames, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    # --------------------------------------------------------------- access
    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge series (0.0 if unset)."""
        m = self._metrics[name]
        return m.value(**labels)

    def samples(self):
        """Yield (sample_name, labels_dict, value) over every series,
        expanding histograms into _bucket/_sum/_count."""
        for m in self._metrics.values():
            yield from m.samples()

    # -------------------------------------------------------------- exports
    def to_prometheus(self) -> str:
        lines: List[str] = []
        for m in self._metrics.values():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for name, labels, value in m.samples():
                if labels:
                    lab = ",".join(f'{k}="{v}"'
                                   for k, v in sorted(labels.items()))
                    lines.append(f"{name}{{{lab}}} {_fmt(value)}")
                else:
                    lines.append(f"{name} {_fmt(value)}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> Dict:
        metrics = []
        for m in self._metrics.values():
            entry = {"name": m.name, "kind": m.kind, "help": m.help,
                     "labelnames": list(m.labelnames)}
            if isinstance(m, Histogram):
                entry["buckets"] = [b for b in m.buckets if b != _INF]
                entry["series"] = [
                    {"labels": m._labels_dict(k),
                     "counts": list(st["counts"]), "sum": st["sum"],
                     "count": st["count"]}
                    for k, st in m._series.items()]
            else:
                entry["series"] = [
                    {"labels": m._labels_dict(k),
                     "value": float(v()) if callable(v) else float(v)}
                    for k, v in m._series.items()]
            metrics.append(entry)
        return {"metrics": metrics, "snapshots": self.snapshots}

    @staticmethod
    def from_json(d: Dict) -> "MetricsRegistry":
        reg = MetricsRegistry()
        for e in d.get("metrics", []):
            names = e.get("labelnames", [])
            if e["kind"] == "counter":
                m = reg.counter(e["name"], e.get("help", ""), names)
                for s in e["series"]:
                    m.inc(s["value"], **s["labels"])
            elif e["kind"] == "gauge":
                m = reg.gauge(e["name"], e.get("help", ""), names)
                for s in e["series"]:
                    m.set(s["value"], **s["labels"])
            elif e["kind"] == "histogram":
                m = reg.histogram(e["name"], e.get("help", ""), names,
                                  buckets=e.get("buckets"))
                for s in e["series"]:
                    key = m._key(s["labels"])
                    m._series[key] = {"counts": list(s["counts"]),
                                      "sum": s["sum"],
                                      "count": s["count"]}
        reg.snapshots = list(d.get("snapshots", []))
        return reg

    def snapshot(self, t: float) -> Dict:
        """Record a timestamped sample set (virtual-clock periodic
        snapshots; bound gauges are resolved now)."""
        snap = {"t": float(t),
                "samples": [[name, labels, value]
                            for name, labels, value in self.samples()]}
        self.snapshots.append(snap)
        return snap


_LINE_RE = re.compile(r'^([A-Za-z_:][A-Za-z0-9_:]*)'
                      r'(?:\{(.*)\})?\s+(\S+)$')


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return _INF
    if s == "-Inf":
        return -_INF
    return float(s)


def parse_prometheus(text: str) -> Dict[Tuple[str, Tuple], float]:
    """Parse Prometheus text exposition into
    {(sample_name, ((label, value), ...)): value}. Handles exactly the
    dialect `to_prometheus` emits (label values without '",' /
    newlines)."""
    out: Dict[Tuple[str, Tuple], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable metric line: {line!r}")
        name, labelstr, value = m.groups()
        labels: List[Tuple[str, str]] = []
        if labelstr:
            for part in labelstr.split('",'):
                k, _, v = part.partition('=')
                labels.append((k.strip(), v.strip('"')))
        out[(name, tuple(sorted(labels)))] = _parse_value(value)
    return out


def registry_samples_dict(reg: MetricsRegistry) -> Dict[Tuple[str, Tuple], float]:
    """Same keying as parse_prometheus, for round-trip comparison."""
    return {(name, tuple(sorted((k, str(v)) for k, v in labels.items()))):
            float(value)
            for name, labels, value in reg.samples()}


# ---------------------------------------------------------------------------
# Observer -> registry bridge
# ---------------------------------------------------------------------------

class MetricsObserver(Observer):
    """Derive the QoE metric family from the event stream.

    Counters for every lifecycle/fleet event, histograms for TTFT / TDS /
    per-tenant QoE on finish, and a running contract-weighted attainment
    gauge (same `slo_attained` the autoscaler uses). When
    `snapshot_every` is set, takes periodic registry snapshots on the
    *virtual* clock — event timestamps, not wall time.

    The unlabeled lifecycle counters are *bound* to this observer's
    internal tallies (Counter.set_fn), so attach at most one
    MetricsObserver per registry — a second would rebind the series."""

    def __init__(self, registry: Optional[MetricsRegistry] = None, *,
                 qoe_floor: float = 0.9,
                 snapshot_every: Optional[float] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.qoe_floor = qoe_floor
        self.snapshot_every = snapshot_every
        self._next_snap = snapshot_every
        r = self.registry
        # unlabeled lifecycle counters fire per event (emit is per TOKEN);
        # count in plain attributes and bind the registry series to readers
        # so the hot path pays one `+=` (the benchmark's ~2% overhead gate)
        self._submitted_n = 0
        self._admitted_n = 0
        self._finished_n = 0
        self._shed_n = 0
        self._deferred_n = 0
        self._tokens_n = 0
        self._prefill_n = 0
        self._chunks_n = 0
        self._swapins_n = 0
        self._cancelled_n = 0
        self._sse_events_n = 0
        self._sse_bytes_n = 0
        r.counter("requests_submitted_total",
                  "requests that entered the system"
                  ).set_fn(lambda: float(self._submitted_n))
        r.counter("requests_admitted_total",
                  "requests admitted to a live set"
                  ).set_fn(lambda: float(self._admitted_n))
        r.counter("requests_finished_total", "requests fully served"
                  ).set_fn(lambda: float(self._finished_n))
        r.counter("requests_shed_total",
                  "requests rejected by admission control"
                  ).set_fn(lambda: float(self._shed_n))
        r.counter("requests_deferred_total",
                  "admission deferrals (re-queues)"
                  ).set_fn(lambda: float(self._deferred_n))
        r.counter("tokens_emitted_total", "tokens delivered to clients"
                  ).set_fn(lambda: float(self._tokens_n))
        r.counter("prefill_tokens_total",
                  "prompt tokens prefetched/prefilled"
                  ).set_fn(lambda: float(self._prefill_n))
        r.counter("prefill_chunks_total",
                  "chunked-prefill chunks committed"
                  ).set_fn(lambda: float(self._chunks_n))
        r.counter("swap_ins_total", "swapped requests restored to device"
                  ).set_fn(lambda: float(self._swapins_n))
        r.counter("requests_cancelled_total",
                  "requests aborted by clients (disconnect / cancel)"
                  ).set_fn(lambda: float(self._cancelled_n))
        r.counter("sse_events_flushed_total",
                  "server-sent events written to client sockets"
                  ).set_fn(lambda: float(self._sse_events_n))
        r.counter("sse_bytes_flushed_total",
                  "SSE bytes written to client sockets"
                  ).set_fn(lambda: float(self._sse_bytes_n))
        self._conns = r.counter(
            "connection_events_total", "server connection lifecycle events",
            ("event",))
        self._drains = r.counter(
            "drain_events_total", "graceful-shutdown drain phases",
            ("phase",))
        self._preempts = r.counter(
            "preemptions_total", "batch evictions by mode", ("mode",))
        self._sched = r.counter(
            "schedule_decisions_total", "scheduler invocations",
            ("policy", "triggered"))
        self._routes = r.counter(
            "route_decisions_total", "fleet routing choices", ("replica",))
        self._admission = r.counter(
            "admission_decisions_total", "admission verdicts", ("action",))
        self._scales = r.counter(
            "autoscale_events_total", "autoscaler actions", ("action",))
        self._ttft = r.histogram(
            "ttft_seconds", "time to first token",
            buckets=(0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0))
        self._tds = r.histogram(
            "tds_tokens_per_second", "observed token delivery speed",
            buckets=(0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0))
        self._qoe = r.histogram(
            "request_qoe", "final per-request QoE (Eq. 1)", ("tenant",),
            buckets=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.95,
                     0.99, 1.0))
        self._attain = r.gauge(
            "weighted_attainment",
            "running contract-weighted SLO attainment over finished requests")
        # clock/live update on EVERY event; keep them as plain attributes
        # read through bound gauges so the hot path pays an attribute
        # compare, not a gauge lookup (the benchmark's ~2% overhead gate)
        self._clock_t = 0.0
        self._live_n = 0
        self._clock = r.gauge("clock_seconds", "virtual clock high-water mark")
        self._clock.set_fn(lambda: self._clock_t)
        self._live = r.gauge("live_requests", "admitted, unfinished requests")
        self._live.set_fn(lambda: float(self._live_n))
        self._w_sum = 0.0
        self._wa_sum = 0.0

    # ------------------------------------------------------------- plumbing
    def _tick(self, t: float) -> None:
        if t > self._clock_t:
            self._clock_t = t
        ns = self._next_snap
        if ns is not None and t >= ns:
            self.registry.snapshot(t)
            period = self.snapshot_every
            self._next_snap = (t // period + 1) * period

    # ------------------------------------------------------------ lifecycle
    def submit(self, req, t, *, replica=-1):
        self._submitted_n += 1
        self._tick(t)

    def admit(self, req, t, *, replica=-1):
        self._admitted_n += 1
        self._live_n += 1
        self._tick(t)

    def prefill(self, req, t, n_tokens, *, replica=-1):
        self._prefill_n += n_tokens
        self._tick(t)

    def prefill_chunk(self, req, t, cursor, total, *, replica=-1):
        self._chunks_n += 1
        self._tick(t)

    def emit(self, req, t, k=1, *, replica=-1):
        # hottest hook (per token): _tick inlined
        self._tokens_n += k
        if t > self._clock_t:
            self._clock_t = t
        if self._next_snap is not None and t >= self._next_snap:
            self.registry.snapshot(t)
            period = self.snapshot_every
            self._next_snap = (t // period + 1) * period

    def preempt(self, req, t, mode="swap", *, replica=-1):
        self._preempts.inc(mode=mode)
        self._tick(t)

    def swap_in(self, req, t, *, replica=-1):
        self._swapins_n += 1
        self._tick(t)

    def finish(self, req, t, *, replica=-1):
        self._finished_n += 1
        self._live_n -= 1
        ttft = req.final_ttft()
        if ttft != _INF:
            self._ttft.observe(ttft)
        tds = req.final_tds()
        if tds != _INF:
            self._tds.observe(tds)
        self._qoe.observe(req.final_qoe(), tenant=req.tenant or "default")
        w = request_weight(req)
        self._w_sum += w
        self._wa_sum += w * slo_attained(req, self.qoe_floor)
        self._attain.set(self._wa_sum / self._w_sum)
        self._tick(t)

    def shed(self, req, t, *, replica=-1):
        self._shed_n += 1
        self._tick(t)

    def defer(self, req, t, *, replica=-1):
        self._deferred_n += 1
        self._tick(t)

    def cancel(self, req, t, *, replica=-1):
        self._cancelled_n += 1
        self._live_n -= 1 if req.fluid_idx >= 0 else 0  # admitted only
        self._tick(t)

    # ------------------------------------------------------------ scheduler
    def schedule(self, t, info, *, replica=-1):
        self._sched.inc(policy=str(info.get("policy", "?")),
                        triggered=str(bool(info.get("triggered", False))))
        self._tick(t)

    # ---------------------------------------------------------------- fleet
    def route(self, req, t, replica_id, gain, scores, *, replica=-1):
        self._routes.inc(replica=str(replica_id))
        self._tick(t)

    def admission(self, req, t, action, gain, *, replica=-1):
        self._admission.inc(action=str(action))
        self._tick(t)

    def scale(self, t, action, replica_id, signal=None, *, replica=-1):
        self._scales.inc(action=str(action))
        self._tick(t)

    # --------------------------------------------------------- wire / server
    def connection(self, t, conn_id, event, info=None, *, replica=-1):
        self._conns.inc(event=str(event))
        self._tick(t)

    def sse_flush(self, t, conn_id, rid, n_events, n_bytes, *, replica=-1):
        self._sse_events_n += n_events
        self._sse_bytes_n += n_bytes
        self._tick(t)

    def drain(self, t, phase, conns, live, *, replica=-1):
        self._drains.inc(phase=str(phase))
        self._tick(t)


def register_backend_gauges(registry: MetricsRegistry, backend,
                            replica: Optional[int] = None) -> None:
    """Bind live-state gauges onto a backend.

    KV occupancy (current / peak tokens, utilization, slots in use) comes
    straight off `backend.kv` (PR 5's peak tracking, now readable from
    outside); clock and live-set size work for any SteppableBackend.
    Bound gauges survive `backend.reset()` because `KVSlotManager.reset()`
    clears the same object in place."""
    labels = {} if replica is None else {"replica": str(replica)}
    names = () if replica is None else ("replica",)

    def bind(name, help, fn):
        registry.gauge(name, help, names).set_fn(fn, **labels)

    bind("backend_clock_seconds", "backend virtual clock",
         lambda: backend.now)
    bind("backend_live_requests", "live (admitted, unfinished) requests",
         lambda: len(backend.live))
    kv = getattr(backend, "kv", None)
    if kv is not None:
        bind("kv_tokens_used", "KV cache tokens currently resident",
             lambda: backend.kv.tokens_used)
        bind("kv_tokens_peak", "peak KV cache tokens resident",
             lambda: backend.kv.peak_tokens_used)
        bind("kv_utilization", "KV token occupancy / capacity",
             lambda: backend.kv.utilization)
        bind("kv_peak_utilization", "peak KV occupancy / capacity",
             lambda: backend.kv.peak_utilization)
        bind("kv_slots_in_use", "engine slots holding a request",
             lambda: backend.kv.slots_in_use)
        bind("kv_swap_bytes_total", "bytes moved by KV swap in/out",
             lambda: backend.kv.swap_bytes_total)
        bind("kv_swaps_out_total", "requests parked to host by swap_out",
             lambda: getattr(backend.kv, "swaps_out_total", 0))
        bind("kv_drops_total", "KV slices discarded by drop()",
             lambda: getattr(backend.kv, "drops_total", 0))
        bind("kv_dropped_bytes_total",
             "parked host/draft bytes discarded by drop()",
             lambda: getattr(backend.kv, "dropped_bytes_total", 0))
        if getattr(kv, "paged", False):
            bind("kv_pages_used", "KV pages currently allocated",
                 lambda: backend.kv.pages_used)
            bind("kv_pages_peak", "peak KV pages allocated",
                 lambda: backend.kv.peak_pages_used)
            bind("kv_pages_total", "KV page-pool capacity",
                 lambda: backend.kv.total_pages)
            bind("kv_page_utilization", "KV page occupancy / page pool",
                 lambda: backend.kv.page_utilization)
            bind("kv_physical_pages_used",
                 "device page-pool rows holding data (overdraft clamped)",
                 lambda: backend.kv.physical_pages_used)
            bind("kv_physical_page_utilization",
                 "physical page occupancy / pool (never exceeds 1.0)",
                 lambda: backend.kv.physical_page_utilization)
            bind("kv_overdraft_pages",
                 "ledger pages past the physical pool (fictional ids)",
                 lambda: getattr(backend.kv, "overdraft_pages", 0))
        if getattr(backend, "physical_pages", False):
            bind("kv_page_gathers_total",
                 "pool->contiguous row gathers (swap-out reads)",
                 lambda: backend.page_gathers)
            bind("kv_page_scatters_total",
                 "contiguous->pool scatter commits (prefill/swap-in)",
                 lambda: backend.page_scatters)
            bind("kv_page_gather_bytes_total",
                 "bytes moved by page-pool gathers",
                 lambda: backend.page_gather_bytes)
