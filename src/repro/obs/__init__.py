"""repro.obs — zero-overhead-when-off observability for the serving stack.

Andes's thesis is that serving systems optimize metrics users don't feel;
observability is how you *watch* the metrics they do feel. This package
threads one `Observer` protocol through every layer — simulator, engine
(including its speculative and hot-path machinery), scheduler, and the
whole cluster (router / admission / autoscaler) — so a single attached
object sees the complete story of a run:

  * request lifecycle — arrival / admit / prefill / first-token / emit /
    preempt / swap-in / finish / shed / defer, with exact virtual-clock
    timestamps;
  * scheduler decisions with their pricing inputs — `QoEPricer` gains,
    victim sets, the multi-step `idle_steps` certificates;
  * fleet events — routing choices with per-replica scores, admission
    verdicts, autoscale up/down/drain/reap (with the attainment signal
    that triggered them);
  * hot-path profiling — host↔device syncs, device dispatches by kind,
    prefill jit compiles, fused multi-step blocks, speculative
    acceptance.

Consumers:

  TraceRecorder     (obs.trace)     structured typed events; JSONL and
                                    Chrome-trace/Perfetto export; QoE
                                    reconciliation (`qoe_from_trace`)
  MetricsObserver   (obs.metrics)   counters/gauges/histograms (TTFT,
                                    TDS, per-tenant QoE, attainment, KV
                                    occupancy) with Prometheus-text and
                                    JSON export + virtual-clock snapshots
  ProfilingObserver (obs.profiling) PR 5's sync/compile/dispatch counting
                                    formalized into the same registry the
                                    benchmarks read

The default observer is None everywhere — instrumentation points guard
with a single `is not None` test, so an unobserved run executes the exact
pre-observability code path. The verification spine is differential
(tests/test_obs.py): an instrumented run is bit-for-bit identical —
tokens, timestamps, preemptions, QoE — to an uninstrumented one, and QoE
recomputed purely from the emitted trace equals the engine-reported QoE.

PR 4's `event_sink` lifecycle callables remain supported as a thin
`EventSinkAdapter` shim (deprecated; new code should implement Observer).
"""
from repro.obs.observer import (
    EventSinkAdapter,
    MultiObserver,
    Observer,
    ScopedObserver,
    compose,
)
from repro.obs.trace import TraceEvent, TraceRecorder, qoe_from_trace
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsObserver,
    MetricsRegistry,
    parse_prometheus,
    register_backend_gauges,
)
from repro.obs.profiling import ProfilingObserver, profile_engine

__all__ = [
    "Observer", "MultiObserver", "ScopedObserver", "EventSinkAdapter",
    "compose",
    "TraceEvent", "TraceRecorder", "qoe_from_trace",
    "MetricsRegistry", "MetricsObserver", "Counter", "Gauge", "Histogram",
    "parse_prometheus", "register_backend_gauges",
    "ProfilingObserver", "profile_engine",
]
