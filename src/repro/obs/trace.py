"""Structured trace recording: typed events, JSONL and Perfetto export,
and QoE reconciliation straight from the trace.

The recorder is itself an `Observer` — attach it to any backend (or to a
ClusterSimulator, where `ScopedObserver` stamps replica ids) and it
accumulates `TraceEvent`s carrying everything needed to replay the run's
quality story offline:

  * the "arrival" event snapshots the request's QoE contract (ttft, tds,
    prompt/output lengths, tenant, priority, SLO weight), so a trace file
    is self-contained;
  * "emit" events carry the exact virtual-clock floats the engine
    appended to `Request.emit_times` — which is why `qoe_from_trace`
    reconciles *bit-for-bit* with `Request.final_qoe()`: both push the
    same floats through the same `qoe_exact`;
  * a synthetic "first_token" event precedes each request's first emit
    (TTFT is first-class in Andes, so it is first-class in the trace);
  * scheduler / route / admission / scale events carry their decision
    payloads (gains, victim sets, scores, autoscale signals).

Export formats:

  to_jsonl / from_jsonl       lossless round-trip (floats via repr)
  to_chrome_trace             Chrome trace-event JSON loadable in
                              Perfetto / chrome://tracing: one process
                              per replica (pid 0 = fleet), one thread per
                              request, an "X" span from arrival to
                              finish/shed, instants for everything else
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

from repro.core.pricing import request_weight
from repro.core.qoe import QoESpec, qoe_exact
from repro.obs.observer import Observer


@dataclasses.dataclass(slots=True)
class TraceEvent:
    """One typed event. `rid` is None for request-less events (schedule,
    scale, sync, ...); `replica` is -1 outside a cluster. `data` is a
    JSON-able payload whose keys depend on `kind`.

    `slots=True`: a trace of a few-minute run holds 10^5-10^6 of these;
    slots halve the per-event footprint and keep allocation (and GC
    pressure on the engine hot path) inside the benchmark's overhead
    budget."""
    kind: str
    t: float
    rid: Optional[int]
    replica: int
    data: Dict

    def to_json(self) -> str:
        return json.dumps(
            {"kind": self.kind, "t": self.t, "rid": self.rid,
             "replica": self.replica, "data": self.data},
            default=_jsonable, sort_keys=True)

    @staticmethod
    def from_json(line: str) -> "TraceEvent":
        d = json.loads(line)
        return TraceEvent(d["kind"], d["t"], d["rid"], d["replica"],
                          d["data"])


def _jsonable(x):
    """json.dumps default= hook: numpy scalars/arrays -> python."""
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, np.ndarray):
        return x.tolist()
    raise TypeError(f"not JSON-serializable: {type(x)!r}")


class TraceRecorder(Observer):
    """Accumulate TraceEvents from any instrumented backend."""

    #: hot-path event kinds excluded when `lifecycle_only=True` (they
    #: dominate event counts without changing the QoE story)
    HOTPATH_KINDS = frozenset({"sync", "dispatch"})

    def __init__(self, lifecycle_only: bool = False):
        self.lifecycle_only = lifecycle_only
        self.events: List[TraceEvent] = []
        self._tokens_seen: Dict[int, int] = {}

    def clear(self) -> None:
        self.events.clear()
        self._tokens_seen.clear()

    # ------------------------------------------------------------- internals
    def _rec(self, kind, t, rid, replica, **data) -> None:
        self.events.append(TraceEvent(kind, float(t), rid, replica, data))

    # ------------------------------------------------------------- lifecycle
    def submit(self, req, t, *, replica=-1):
        # A cluster emits a fleet-level arrival and the chosen replica
        # backend emits its own on hand-off; keep only the first per rid
        # so qoe reconciliation sees the true arrival.
        if req.rid in self._tokens_seen:
            return
        self._tokens_seen[req.rid] = 0
        self._rec("arrival", t, req.rid, replica,
                  prompt_len=int(req.prompt_len),
                  output_len=int(req.output_len),
                  ttft=float(req.spec.ttft), tds=float(req.spec.tds),
                  tenant=req.tenant, priority=float(req.priority),
                  weight=float(request_weight(req)))

    def admit(self, req, t, *, replica=-1):
        self._rec("admit", t, req.rid, replica)

    def prefill(self, req, t, n_tokens, *, replica=-1):
        self._rec("prefill", t, req.rid, replica, n_tokens=int(n_tokens))

    def prefill_chunk(self, req, t, cursor, total, *, replica=-1):
        self._rec("prefill_chunk", t, req.rid, replica,
                  cursor=int(cursor), total=int(total))

    def emit(self, req, t, k=1, *, replica=-1):
        # hottest hook (per token): TraceEvent built inline, no _rec hop
        rid = req.rid
        seen = self._tokens_seen.get(rid, 0)
        if seen == 0:
            self.events.append(
                TraceEvent("first_token", float(t), rid, replica, {}))
        total = seen + int(k)
        self._tokens_seen[rid] = total
        self.events.append(
            TraceEvent("emit", float(t), rid, replica,
                       {"k": int(k), "total": total}))

    def preempt(self, req, t, mode="swap", *, replica=-1):
        self._rec("preempt", t, req.rid, replica, mode=mode,
                  generated=int(req.generated))

    def swap_in(self, req, t, *, replica=-1):
        self._rec("swap_in", t, req.rid, replica,
                  context_len=int(req.context_len))

    def finish(self, req, t, *, replica=-1):
        self._rec("finish", t, req.rid, replica,
                  generated=int(req.generated),
                  preemptions=int(req.preemptions))

    def shed(self, req, t, *, replica=-1):
        self._rec("shed", t, req.rid, replica)

    def defer(self, req, t, *, replica=-1):
        self._rec("defer", t, req.rid, replica)

    def cancel(self, req, t, *, replica=-1):
        self._rec("cancel", t, req.rid, replica,
                  generated=int(req.generated))

    # ------------------------------------------------------------- scheduler
    def schedule(self, t, info, *, replica=-1):
        self._rec("schedule", t, None, replica, **info)

    def multi_step(self, t, j, committed, *, replica=-1):
        self._rec("multi_step", t, None, replica, j=int(j),
                  committed=int(committed))

    # ----------------------------------------------------------------- fleet
    def route(self, req, t, replica_id, gain, scores, *, replica=-1):
        self._rec("route", t, req.rid, replica,
                  replica_id=int(replica_id),
                  gain=None if gain is None else float(gain),
                  scores=None if scores is None else
                  {str(k): float(v) for k, v in scores.items()})

    def admission(self, req, t, action, gain, *, replica=-1):
        self._rec("admission", t, req.rid, replica, action=action,
                  gain=None if gain is None else float(gain))

    def scale(self, t, action, replica_id, signal=None, *, replica=-1):
        self._rec("scale", t, None, replica, action=action,
                  replica_id=int(replica_id), signal=signal)

    # -------------------------------------------------------------- hot path
    def sync(self, t, n=1, *, replica=-1):
        if not self.lifecycle_only:
            self.events.append(
                TraceEvent("sync", float(t), None, replica, {"n": int(n)}))

    def dispatch(self, t, kind, n=1, *, replica=-1):
        if not self.lifecycle_only:
            self.events.append(
                TraceEvent("dispatch", float(t), None, replica,
                           {"op": kind, "n": int(n)}))

    def jit_compile(self, t, key, *, replica=-1):
        self._rec("jit_compile", t, None, replica, key=list(key))

    def spec(self, t, proposed, accepted, *, replica=-1):
        self._rec("spec", t, None, replica, proposed=int(proposed),
                  accepted=int(accepted))

    # --------------------------------------------------------- wire / server
    def connection(self, t, conn_id, event, info=None, *, replica=-1):
        self._rec("connection", t, None, replica, conn_id=int(conn_id),
                  event=event, info=info)

    def sse_flush(self, t, conn_id, rid, n_events, n_bytes, *, replica=-1):
        self._rec("sse_flush", t, rid, replica, conn_id=int(conn_id),
                  n_events=int(n_events), n_bytes=int(n_bytes))

    def drain(self, t, phase, conns, live, *, replica=-1):
        self._rec("drain", t, None, replica, phase=phase,
                  conns=int(conns), live=int(live))

    # --------------------------------------------------------------- exports
    def to_jsonl(self) -> str:
        return "\n".join(ev.to_json() for ev in self.events) + "\n" \
            if self.events else ""

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    @staticmethod
    def from_jsonl(text: str) -> List[TraceEvent]:
        return [TraceEvent.from_json(line)
                for line in text.splitlines() if line.strip()]

    @staticmethod
    def load_jsonl(path: str) -> List[TraceEvent]:
        with open(path) as f:
            return TraceRecorder.from_jsonl(f.read())

    def to_chrome_trace(self) -> Dict:
        """Chrome trace-event format (Perfetto / chrome://tracing).

        pid = replica + 1 (pid 0 is the fleet control plane), tid = rid
        (tid 0 for request-less events). Each request gets one "X"
        complete span from arrival to finish/shed; every event is also an
        "i" instant. Events are sorted by timestamp, so per-(pid, tid)
        timestamps are monotone."""
        instants, spans = [], []
        arrivals: Dict[int, TraceEvent] = {}
        pids, tids = set(), set()
        for ev in sorted(self.events, key=lambda e: e.t):
            pid = ev.replica + 1
            tid = ev.rid if ev.rid is not None else 0
            pids.add(pid)
            tids.add((pid, tid))
            instants.append({
                "name": ev.kind, "ph": "i", "s": "t",
                "ts": ev.t * 1e6, "pid": pid, "tid": tid,
                "args": json.loads(json.dumps(ev.data, default=_jsonable)),
            })
            if ev.kind == "arrival":
                arrivals[ev.rid] = ev
            elif ev.kind in ("finish", "shed") and ev.rid in arrivals:
                start = arrivals.pop(ev.rid)
                spans.append({
                    "name": f"req {ev.rid}", "ph": "X", "cat": "request",
                    "ts": start.t * 1e6, "dur": (ev.t - start.t) * 1e6,
                    "pid": pid, "tid": tid,
                    "args": {"outcome": ev.kind, **start.data},
                })
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "ts": 0,
                 "args": {"name": "fleet" if pid == 0
                          else f"replica {pid - 1}"}}
                for pid in sorted(pids)]
        meta += [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                  "ts": 0, "args": {"name": "control" if tid == 0
                                    else f"req {tid}"}}
                 for pid, tid in sorted(tids)]
        return {"traceEvents": meta + spans + instants,
                "displayTimeUnit": "ms"}

    def save_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


def merge_traces(*event_lists: List[TraceEvent]) -> List[TraceEvent]:
    """Merge per-source traces (replicas, server connections, pump vs.
    loop thread) into one timestamp-sorted stream. The sort is stable, so
    equal-timestamp events keep their per-source relative order."""
    merged: List[TraceEvent] = []
    for evs in event_lists:
        merged.extend(evs)
    merged.sort(key=lambda e: e.t)
    return merged


def qoe_from_trace(events: List[TraceEvent]) -> Dict[int, float]:
    """Recompute per-request QoE purely from a trace.

    Uses only "arrival" (contract snapshot) and "emit" (delivery
    timestamps) events, pushed through the same `qoe_exact` as
    `Request.final_qoe()`. Because emit events carry the identical
    floats the backend appended to `emit_times`, the result matches the
    backend-reported QoE exactly — the trace-reconciliation oracle.

    Robust to event *file order*: wall-clock runs interleave replicas and
    server connections, so a merged trace may deliver a request's events
    out of order (and a fleet hand-off records two "arrival" events whose
    order depends on the writer). The reconstruction is therefore
    permutation-invariant — the earliest-timestamp arrival wins and each
    request's emit timeline is sorted before pacing — because
    `pace_delivery` is order-sensitive: feeding it an unsorted timeline
    silently computes a different (wrong) delivery curve."""
    specs: Dict[int, tuple] = {}
    emits: Dict[int, List[float]] = {}
    for ev in events:
        if ev.kind == "arrival":
            if ev.rid not in specs or ev.t < specs[ev.rid][0]:
                specs[ev.rid] = (ev.t, QoESpec(ttft=ev.data["ttft"],
                                               tds=ev.data["tds"]))
        elif ev.kind == "emit":
            emits.setdefault(ev.rid, []).extend(
                [ev.t] * int(ev.data["k"]))
    out: Dict[int, float] = {}
    for rid, (arrival, spec) in specs.items():
        times = emits.get(rid, [])
        if not times:
            out[rid] = 0.0          # shed / never served
        else:
            times = np.sort(np.asarray(times, np.float64))
            out[rid] = float(qoe_exact(times, arrival, spec,
                                       response_len=len(times)))
    return out
