"""Hot-path profiling: PR 5's sync / dispatch / compile counting as a
reusable Observer feeding a MetricsRegistry.

PR 5 instrumented the engine's hot path by hand — `host_syncs`,
`multi_step_blocks`, the `BucketedPrefill.shapes_seen` compile cache —
and the benchmark read those private counters directly. This module
formalizes the same signals as Observer events (`sync`, `dispatch`,
`jit_compile`, `multi_step`, `spec`), so any consumer (benchmarks,
dashboards, tests) reads them from the registry instead of reaching into
engine internals. The engine still keeps its cheap integer counters
(`host_syncs`, `dispatches`, ...) for `hotpath_stats()`; with a
ProfilingObserver attached the two must agree — the benchmark asserts it.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.obs.observer import Observer


class ProfilingObserver(Observer):
    """Map hot-path events onto registry counters.

    Counters (all under the `engine_` prefix; labels in brackets):
      engine_host_syncs_total          host<->device synchronizations
      engine_dispatches_total[kind]    device computation dispatches
      engine_jit_compiles_total        new jit shape signatures
      engine_multi_step_blocks_total   fused decode blocks executed
      engine_multi_step_iters_total    iterations covered by those blocks
      engine_persistent_blocks_total   of which: device while_loop blocks
      engine_persistent_iters_total    device loop iterations executed
      engine_spec_proposed_total       speculative tokens drafted
      engine_spec_accepted_total       speculative tokens accepted

    Series are *bound* to this observer's internal tallies
    (Counter.set_fn), so attach at most one ProfilingObserver per
    registry — a second would rebind them.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        # these hooks fire on EVERY device interaction, so the counts live
        # in plain attributes and the registry series are bound readers
        # (Counter.set_fn) — one `+=` per event, no metric lookup
        self._syncs_n = 0
        self._compiles_n = 0
        self._mblocks_n = 0
        self._miters_n = 0
        self._pblocks_n = 0
        self._piters_n = 0
        self._spec_p_n = 0
        self._spec_a_n = 0
        self._disp_n: Dict[str, int] = {}
        r.counter("engine_host_syncs_total",
                  "host-device synchronizations"
                  ).set_fn(lambda: float(self._syncs_n))
        self._dispatches = r.counter(
            "engine_dispatches_total", "device dispatches by kind",
            ("kind",))
        r.counter("engine_jit_compiles_total",
                  "new jit shape signatures compiled"
                  ).set_fn(lambda: float(self._compiles_n))
        r.counter("engine_multi_step_blocks_total",
                  "fused multi-step blocks"
                  ).set_fn(lambda: float(self._mblocks_n))
        r.counter("engine_multi_step_iters_total",
                  "decode iterations inside fused blocks"
                  ).set_fn(lambda: float(self._miters_n))
        r.counter("engine_persistent_blocks_total",
                  "device-resident while_loop decode blocks"
                  ).set_fn(lambda: float(self._pblocks_n))
        r.counter("engine_persistent_iters_total",
                  "decode iterations executed inside the device loop"
                  ).set_fn(lambda: float(self._piters_n))
        r.counter("engine_spec_proposed_total",
                  "speculative tokens drafted"
                  ).set_fn(lambda: float(self._spec_p_n))
        r.counter("engine_spec_accepted_total",
                  "speculative tokens accepted"
                  ).set_fn(lambda: float(self._spec_a_n))
        r.gauge("spec_acceptance_rate",
                "running speculative acceptance rate"
                ).set_fn(lambda: (self._spec_a_n / self._spec_p_n
                                  if self._spec_p_n else 0.0))
        self.compile_keys: List[Tuple] = []

    # ---------------------------------------------------------------- hooks
    def sync(self, t, n=1, *, replica=-1):
        self._syncs_n += n

    def dispatch(self, t, kind, n=1, *, replica=-1):
        d = self._disp_n
        if kind in d:
            d[kind] += n
        else:
            # first sight of this kind: tally + bind its labeled series
            d[kind] = n
            self._dispatches.set_fn(
                lambda _k=kind: float(self._disp_n[_k]), kind=kind)

    def jit_compile(self, t, key, *, replica=-1):
        self._compiles_n += 1
        self.compile_keys.append(tuple(key))

    def multi_step(self, t, j, committed, *, replica=-1):
        self._mblocks_n += 1
        self._miters_n += j

    def persistent_loop(self, t, j, steps, *, replica=-1):
        self._pblocks_n += 1
        self._piters_n += steps

    def spec(self, t, proposed, accepted, *, replica=-1):
        self._spec_p_n += proposed
        self._spec_a_n += accepted

    # -------------------------------------------------------------- reading
    def total_dispatches(self) -> int:
        return sum(self._disp_n.values())

    def dispatches_by_kind(self) -> Dict[str, int]:
        return dict(self._disp_n)

    def summary(self) -> Dict:
        """Registry view mirroring `ServingEngine.hotpath_stats()` keys
        (plus the per-kind dispatch breakdown)."""
        return {
            "host_syncs": self._syncs_n,
            "dispatches": self.total_dispatches(),
            "dispatches_by_kind": self.dispatches_by_kind(),
            "jit_compiles": self._compiles_n,
            "multi_step_blocks": self._mblocks_n,
            "multi_step_iters": self._miters_n,
            "persistent_blocks": self._pblocks_n,
            "persistent_iters": self._piters_n,
            "spec_proposed": self._spec_p_n,
            "spec_accepted": self._spec_a_n,
        }


def profile_engine(engine,
                   registry: Optional[MetricsRegistry] = None
                   ) -> ProfilingObserver:
    """Attach a ProfilingObserver (composing with whatever observer is
    already installed) and return it."""
    prof = ProfilingObserver(registry)
    engine.attach_observer(prof)
    return prof
