"""KV slot manager: static-slot cache accounting + swap/recompute store.

The TPU adaptation of vLLM's paged KV (DESIGN.md §3): the device cache is a
fixed (L, B_slots, S_max, ...) pytree; this manager owns

  * slot allocation (request -> batch slot),
  * token-granular accounting (the scheduler's knapsack weights / capacity M),
  * the request metadata store: swapped-out KV/state lives here as host
    numpy arrays (paper Fig. 6 step 3) until swap-in or recompute.

Speculative engines keep a *second* device cache (the draft model's, same
slot layout — serving/speculative.py); its parked slices ride alongside the
target's in `draft_store`, keyed by the same rid, so a preempted request's
two caches round-trip host RAM together and release together. Accounting
stays in target-KV tokens (that is the scheduler's capacity M); the draft's
proportional cost enters through SpeculativeLatencyModel's swap/prefill
pricing instead. `burst_reserve` lets a speculative engine leave k+1 tokens
of admission headroom per request, since one verify step can grow a request
by up to k+1 tokens before the scheduler next runs.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.request import Request


class KVSlotManager:
    def __init__(self, num_slots: int, max_seq: int,
                 capacity_tokens: Optional[int] = None,
                 burst_reserve: int = 0):
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.capacity_tokens = capacity_tokens or num_slots * max_seq
        self.burst_reserve = burst_reserve
        self.reset()

    def reset(self) -> None:
        """Clear all occupancy state in place (including the peak
        high-water mark and swap accounting, which a fresh run must not
        inherit). In-place so external references — observability gauges
        bound to an engine's `kv` — stay valid across `engine.reset()`."""
        self.free_slots: List[int] = list(range(self.num_slots))
        self.slot_of: Dict[int, int] = {}          # rid -> slot
        self.tokens_used = 0
        self.peak_tokens_used = 0                  # high-water mark
        self.host_store: Dict[int, dict] = {}      # rid -> host pytree slice
        self.draft_store: Dict[int, dict] = {}     # rid -> parked draft slice
        self.swap_bytes_total = 0

    @property
    def slots_in_use(self) -> int:
        """Batch slots currently holding a resident request."""
        return self.num_slots - len(self.free_slots)

    def occupancy(self) -> dict:
        """Point-in-time occupancy snapshot (per-step gauge source)."""
        return {
            "tokens_used": self.tokens_used,
            "peak_tokens_used": self.peak_tokens_used,
            "capacity_tokens": self.capacity_tokens,
            "utilization": self.utilization,
            "peak_utilization": self.peak_utilization,
            "slots_in_use": self.slots_in_use,
            "num_slots": self.num_slots,
            "swapped_requests": len(self.host_store),
            "swap_bytes_total": self.swap_bytes_total,
        }

    # ---- allocation ---------------------------------------------------------
    def can_allocate(self, req: Request) -> bool:
        return (bool(self.free_slots)
                and self.tokens_used + req.context_len + self.burst_reserve
                <= self.capacity_tokens)

    def allocate(self, req: Request) -> int:
        slot = self.free_slots.pop()
        self.slot_of[req.rid] = slot
        self.tokens_used += req.context_len
        self.peak_tokens_used = max(self.peak_tokens_used, self.tokens_used)
        req.engine_slot = slot
        return slot

    def grow(self, req: Request, n: int = 1) -> None:
        """Account for n freshly generated tokens."""
        self.tokens_used += n
        self.peak_tokens_used = max(self.peak_tokens_used, self.tokens_used)

    def release(self, req: Request) -> None:
        slot = self.slot_of.pop(req.rid)
        self.free_slots.append(slot)
        self.tokens_used -= req.context_len
        req.engine_slot = -1
        self.draft_store.pop(req.rid, None)

    # ---- preemption ---------------------------------------------------------
    def swap_out(self, req: Request, host_slice: dict,
                 draft_slice: Optional[dict] = None) -> None:
        """Park device slices (already fetched to host) and free the slot."""
        self.release(req)                      # also clears any stale draft
        self.host_store[req.rid] = host_slice
        self.swap_bytes_total += sum(
            np.asarray(v).nbytes for v in jax.tree.leaves(host_slice)
        )
        if draft_slice is not None:
            self.draft_store[req.rid] = draft_slice
            self.swap_bytes_total += sum(
                np.asarray(v).nbytes for v in jax.tree.leaves(draft_slice)
            )

    def swap_in(self, req: Request) -> dict:
        return self.host_store.pop(req.rid)

    def swap_in_draft(self, req: Request) -> Optional[dict]:
        return self.draft_store.pop(req.rid, None)

    def drop(self, req: Request) -> None:
        """Recompute-style preemption: nothing parked, slot freed."""
        self.host_store.pop(req.rid, None)
        self.release(req)

    @property
    def utilization(self) -> float:
        return self.tokens_used / self.capacity_tokens

    @property
    def peak_utilization(self) -> float:
        """High-water KV occupancy over the manager's lifetime (benchmark
        reporting: confirms the hot-path engine fills the same memory the
        baseline does — the optimizations change dispatch, not packing)."""
        return self.peak_tokens_used / self.capacity_tokens
