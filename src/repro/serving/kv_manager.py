"""KV manager: paged/block cache accounting + swap/recompute store.

The TPU adaptation of vLLM's paged KV (DESIGN.md §3): the device cache is a
fixed (L, B_slots, S_max, ...) pytree; this manager owns

  * slot allocation (request -> batch slot),
  * token-granular accounting (the scheduler's knapsack weights / capacity M),
  * page-granular allocation (``page_size``): capacity is a pool of
    fixed-size pages, each resident holds a **block table** (an ordered
    list of page ids covering its committed context), and admission /
    ``grow`` / release move whole pages between the pool and the tables,
  * the request metadata store: swapped-out KV/state lives here as host
    numpy arrays (paper Fig. 6 step 3) until swap-in or recompute.

Page/block-table layout
-----------------------
Pages are an *accounting* granularity, not a device layout: each request
still owns one contiguous cache row (attention masks by ``length``, so a
row is always a valid prefix), and a page id is a handle into the
capacity pool. ``block_table[rid]`` maps a resident's context onto
``ceil(held_tokens / page_size)`` page ids; the last page may be
partially filled, and eviction (release / swap_out / drop / evict_tail)
returns partial pages to the pool with the full ones — that is what
makes preemption and admission finer-grained than whole ``max_seq``
slots. Two degenerate cases pin the refactor against the PR 1-7
differential suites:

  * ``page_size=None`` or ``page_size >= max_seq`` — the legacy
    fixed-depth slot manager, bit-for-bit (a request can never span two
    pages, so the page pool is exactly the slot pool);
  * ``page_size=1`` — one page per token: the page-pool check is
    arithmetically identical to the token-capacity check, so a paged
    engine reproduces the legacy engine bit-for-bit
    (tests/test_paged_kv.py runs the engine differential both ways).

Speculative engines keep a *second* device cache (the draft model's, same
slot layout — serving/speculative.py); its parked slices ride alongside the
target's in `draft_store`, keyed by the same rid, so a preempted request's
two caches round-trip host RAM together and release together. Accounting
stays in target-KV tokens (that is the scheduler's capacity M); the draft's
proportional cost enters through SpeculativeLatencyModel's swap/prefill
pricing instead.

``burst_reserve`` is the admission headroom for speculative growth: one
verify step can grow a request by up to k+1 tokens before the scheduler
next runs — and EVERY resident can, simultaneously. ``can_allocate``
therefore charges the reserve once per already-resident request plus once
for the candidate (charging it once per *admission* under-reserves by
``burst_reserve * residents`` tokens and a synchronized verify burst can
overfill capacity — tests/test_kv_accounting.py holds the regression).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.request import Request


def _slice_bytes(host_slice: Optional[dict]) -> int:
    if host_slice is None:
        return 0
    return sum(np.asarray(v).nbytes for v in jax.tree.leaves(host_slice))


class KVSlotManager:
    def __init__(self, num_slots: int, max_seq: int,
                 capacity_tokens: Optional[int] = None,
                 burst_reserve: int = 0,
                 page_size: Optional[int] = None):
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.capacity_tokens = capacity_tokens or num_slots * max_seq
        self.burst_reserve = burst_reserve
        # page_size >= max_seq collapses to the legacy slot manager: one
        # page per request IS a slot, and the free-slot check subsumes the
        # pool check. Kept as the explicit degenerate case so every
        # pre-paging differential suite pins this refactor.
        self.page_size = page_size
        self.paged = page_size is not None and 0 < page_size < max_seq
        if self.paged:
            self.total_pages = -(-self.capacity_tokens // page_size)
        else:
            self.total_pages = num_slots
        self.reset()

    def reset(self) -> None:
        """Clear all occupancy state in place (including the peak
        high-water mark and swap accounting, which a fresh run must not
        inherit). In-place so external references — observability gauges
        bound to an engine's `kv` — stay valid across `engine.reset()`."""
        # monotone edition counter of the page/slot assignment: bumped on
        # every page movement (take/free) and on reset, so a physically
        # paged engine can cheaply detect "tables moved, re-upload the
        # device block tables" without diffing them
        self.version = getattr(self, "version", 0) + 1
        self.free_slots: List[int] = list(range(self.num_slots))
        self.slot_of: Dict[int, int] = {}          # rid -> slot
        self.tokens_used = 0
        self.peak_tokens_used = 0                  # high-water mark
        self.held_tokens: Dict[int, int] = {}      # rid -> tokens charged
        self.host_store: Dict[int, dict] = {}      # rid -> host pytree slice
        self.draft_store: Dict[int, dict] = {}     # rid -> parked draft slice
        # page pool (paged mode): LIFO free list + per-request block tables
        self.block_table: Dict[int, List[int]] = {}
        self.free_pages: List[int] = (
            list(range(self.total_pages - 1, -1, -1)) if self.paged else [])
        self.pages_used = 0
        self.peak_pages_used = 0
        # overdraft pages (ids >= total_pages) are ledger fictions — they
        # name no row of a physical pool, so the physical_* reporting
        # surface excludes them (pages_used keeps counting them: that is
        # the visible overdraft signal)
        self.overdraft_pages = 0
        # preemption accounting: swap_out moves bytes (DMA priced by the
        # LatencyModel); drop discards — both are visible, per mode
        self.swap_bytes_total = 0
        self.swaps_out_total = 0
        self.drops_total = 0
        self.dropped_bytes_total = 0     # parked host bytes discarded by drop

    @property
    def slots_in_use(self) -> int:
        """Batch slots currently holding a resident request."""
        return self.num_slots - len(self.free_slots)

    def pages_for(self, tokens: int) -> int:
        """Pages covering `tokens` (0 in unpaged mode: the slot is the
        page and the free-slot check already charges it)."""
        if not self.paged or tokens <= 0:
            return 0
        return -(-tokens // self.page_size)

    def occupancy(self) -> dict:
        """Point-in-time occupancy snapshot (per-step gauge source)."""
        return {
            "tokens_used": self.tokens_used,
            "peak_tokens_used": self.peak_tokens_used,
            "capacity_tokens": self.capacity_tokens,
            "utilization": self.utilization,
            "peak_utilization": self.peak_utilization,
            "slots_in_use": self.slots_in_use,
            "num_slots": self.num_slots,
            "paged": self.paged,
            "page_size": self.page_size if self.paged else 0,
            "pages_used": self.pages_used,
            "peak_pages_used": self.peak_pages_used,
            "total_pages": self.total_pages,
            "page_utilization": self.page_utilization,
            "physical_pages_used": self.physical_pages_used,
            "physical_page_utilization": self.physical_page_utilization,
            "overdraft_pages": self.overdraft_pages,
            "swapped_requests": len(self.host_store),
            "swap_bytes_total": self.swap_bytes_total,
            "swaps_out_total": self.swaps_out_total,
            "drops_total": self.drops_total,
            "dropped_bytes_total": self.dropped_bytes_total,
        }

    # ---- allocation ---------------------------------------------------------
    def _reserve_tokens(self) -> int:
        """Admission headroom: every resident may grow burst_reserve
        tokens before the scheduler re-runs, and so may the candidate."""
        return self.burst_reserve * (self.slots_in_use + 1)

    def can_allocate(self, req: Request, tokens: Optional[int] = None) -> bool:
        need = req.context_len if tokens is None else tokens
        reserve = self._reserve_tokens()
        if not self.free_slots:
            return False
        if self.tokens_used + need + reserve > self.capacity_tokens:
            return False
        if self.paged:
            return (self.pages_used + self.pages_for(need + reserve)
                    <= self.total_pages)
        return True

    def allocate(self, req: Request, tokens: Optional[int] = None) -> int:
        """Claim a slot (and its pages) charging `tokens` of context —
        the full committed context by default; chunked prefill passes the
        first chunk and grows page-by-page as the cursor advances."""
        charge = req.context_len if tokens is None else tokens
        slot = self.free_slots.pop()
        self.slot_of[req.rid] = slot
        self.held_tokens[req.rid] = charge
        self.tokens_used += charge
        self.peak_tokens_used = max(self.peak_tokens_used, self.tokens_used)
        if self.paged:
            self.block_table[req.rid] = [
                self._take_page() for _ in range(self.pages_for(charge))]
        req.engine_slot = slot
        return slot

    def _take_page(self) -> int:
        # the scheduler's watermark keeps demand under capacity, but like
        # the token ledger the pool tolerates transient overdraft (ids
        # past total_pages) instead of corrupting state — utilization > 1
        # is the visible signal, exactly as tokens_used > capacity is
        if self.free_pages:
            page = self.free_pages.pop()
        else:
            page = self.total_pages + self.pages_used
            self.overdraft_pages += 1
        self.version += 1
        self.pages_used += 1
        self.peak_pages_used = max(self.peak_pages_used, self.pages_used)
        return page

    def _free_pages_of(self, rid: int, down_to: int = 0) -> int:
        """Return block-table pages beyond `down_to` tokens to the pool
        (partial pages included). Returns the number freed."""
        table = self.block_table.get(rid)
        if table is None:
            return 0
        keep = self.pages_for(down_to)
        freed = table[keep:]
        del table[keep:]
        if freed:
            self.version += 1
        for p in reversed(freed):
            if p < self.total_pages:
                self.free_pages.append(p)
            else:
                self.overdraft_pages -= 1
        self.pages_used -= len(freed)
        if not table:
            self.block_table.pop(rid, None)
        return len(freed)

    def grow(self, req: Request, n: int = 1) -> None:
        """Account for n freshly generated (or freshly prefilled) tokens."""
        self.tokens_used += n
        self.peak_tokens_used = max(self.peak_tokens_used, self.tokens_used)
        rid = req.rid
        if rid in self.held_tokens:
            held = self.held_tokens[rid] + n
            self.held_tokens[rid] = held
            if self.paged:
                table = self.block_table.setdefault(rid, [])
                while len(table) < self.pages_for(held):
                    table.append(self._take_page())

    def ensure_pages(self, req: Request, tokens: int) -> int:
        """Physically pre-extend a resident's block table to cover `tokens`
        total context WITHOUT touching the token ledger — the physical
        engine's block pre-reservation: before dispatching a certified
        j-step decode block it reserves every page the block can write
        (positions up to tokens-1), so the device loop never needs a
        host-side `grow` mid-block. `grow`'s page top-up is idempotent
        against this (it only appends while the table is short), and
        `trim_pages` returns the unused reserve after the commit (EOS
        truncation). Returns pages newly taken."""
        rid = req.rid
        if not self.paged or rid not in self.slot_of:
            return 0
        table = self.block_table.setdefault(rid, [])
        n0 = len(table)
        while len(table) < self.pages_for(tokens):
            table.append(self._take_page())
        return len(table) - n0

    def trim_pages(self, req: Request) -> int:
        """Return pre-reserved pages beyond the committed context (the
        `ensure_pages` reserve a truncated block never wrote) to the pool.
        Returns pages freed."""
        held = self.held_tokens.get(req.rid)
        if held is None:
            return 0
        return self._free_pages_of(req.rid, held)

    def evict_tail(self, req: Request, down_to_tokens: int) -> int:
        """Partial preemption: shrink a resident's footprint to
        `down_to_tokens`, returning its tail pages (the partially filled
        last page included) to the pool. The device row is untouched —
        the cache is length-gated, so the caller only has to stop
        attending past the new length. Returns pages freed."""
        rid = req.rid
        held = self.held_tokens.get(rid)
        if held is None or down_to_tokens >= held:
            return 0
        self.tokens_used -= held - down_to_tokens
        self.held_tokens[rid] = down_to_tokens
        return self._free_pages_of(rid, down_to_tokens)

    def release(self, req: Request) -> None:
        slot = self.slot_of.pop(req.rid)
        self.free_slots.append(slot)
        self.tokens_used -= self.held_tokens.pop(req.rid, req.context_len)
        self._free_pages_of(req.rid)
        req.engine_slot = -1
        self.draft_store.pop(req.rid, None)

    # ---- preemption ---------------------------------------------------------
    def swap_out(self, req: Request, host_slice: dict,
                 draft_slice: Optional[dict] = None) -> None:
        """Park device slices (already fetched to host) and free the slot."""
        self.release(req)                      # also clears any stale draft
        self.host_store[req.rid] = host_slice
        self.swaps_out_total += 1
        self.swap_bytes_total += _slice_bytes(host_slice)
        if draft_slice is not None:
            self.draft_store[req.rid] = draft_slice
            self.swap_bytes_total += _slice_bytes(draft_slice)

    def swap_in(self, req: Request) -> dict:
        return self.host_store.pop(req.rid)

    def swap_in_draft(self, req: Request) -> Optional[dict]:
        return self.draft_store.pop(req.rid, None)

    def drop(self, req: Request) -> None:
        """Recompute-style preemption (or shedding a parked request):
        nothing survives — slot and pages freed, and any parked host
        slices are discarded WITH accounting: `swap_bytes_total` counted
        them in on swap_out, so the discard shows up in
        `dropped_bytes_total` / `drops_total` (occupancy() and the
        kv_* gauges expose both, aligned with the swap counters)."""
        dropped = self.host_store.pop(req.rid, None)
        draft_dropped = self.draft_store.get(req.rid)
        self.dropped_bytes_total += (_slice_bytes(dropped)
                                     + _slice_bytes(draft_dropped))
        self.drops_total += 1
        if req.rid in self.slot_of:
            self.release(req)
        else:
            self.draft_store.pop(req.rid, None)

    @property
    def utilization(self) -> float:
        return self.tokens_used / self.capacity_tokens

    @property
    def page_utilization(self) -> float:
        return self.pages_used / self.total_pages if self.paged else 0.0

    @property
    def physical_pages_used(self) -> int:
        """Pages of the *physical* pool in use: pages_used minus the
        overdraft fictions (ids >= total_pages name no device row).
        This is the figure HBM dashboards must see — at most total_pages
        — while `page_utilization` keeps reporting > 1 under overdraft."""
        return self.pages_used - self.overdraft_pages

    @property
    def physical_page_utilization(self) -> float:
        """Clamped utilization of the physical pool (always <= 1.0)."""
        if not self.paged:
            return 0.0
        return self.physical_pages_used / self.total_pages

    @property
    def peak_utilization(self) -> float:
        """High-water KV occupancy over the manager's lifetime (benchmark
        reporting: confirms the hot-path engine fills the same memory the
        baseline does — the optimizations change dispatch, not packing)."""
        return self.peak_tokens_used / self.capacity_tokens
