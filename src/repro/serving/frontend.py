"""Deprecated alias: the modality stubs moved to repro.serving.modality
(the `frontend` name now refers to the client-facing serving API in
repro.api). This shim re-exports everything and warns once on import."""
import warnings

from repro.serving.modality import (  # noqa: F401
    audio_frame_specs,
    synthetic_frames,
    synthetic_patches,
    vision_patch_specs,
)

warnings.warn(
    "repro.serving.frontend moved to repro.serving.modality; the client-"
    "facing serving API lives in repro.api",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["audio_frame_specs", "vision_patch_specs",
           "synthetic_frames", "synthetic_patches"]
