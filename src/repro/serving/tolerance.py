"""Tolerance-based differential harness for wall-clock serving (PR 9).

Every verification spine in this repo so far is *bit-exact*: same trace in,
byte-identical timeline out (steppable vs. legacy loop, sim vs. engine,
hotpath on vs. off, ...). A wall-clock engine (`clock="wall"`) breaks that
by construction — its timestamps are real `time.monotonic()` readings
carrying OS scheduling jitter, sleep quantization, and host load — so
wall runs need a different contract, split in two:

* **Token text stays bit-exact.** The clock decides *when* things happen,
  never *what* is computed: per-slot decode is row-independent and swap
  preemption moves exact cache slices. So for the same trace with the
  same admission order, the wall run's emitted token ids must match the
  virtual-clock reference 1:1 per rid — a hard gate, no tolerance.

* **Timing agrees in distribution.** Per-request TTFT/TDS/QoE cannot
  match exactly, so the harness gates summary statistics of the paired
  differences (mean / p95 / max of |Δ|) under stated absolute+relative
  tolerances. The tolerances ARE the spec of `clock="wall"`: a host too
  slow to keep the LatencyModel schedule fails here, visibly, instead of
  silently reporting drifted QoE numbers.

`compare_requests(ref, cand)` pairs two request populations by rid and
returns a `ToleranceReport` whose `assert_ok()` raises with the full gate
table — what tests/test_tolerance.py and the CI server smoke job call.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Request


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """|cand - ref| <= abs_tol + rel_tol * |ref| (numpy.isclose shape)."""
    abs_tol: float = 0.0
    rel_tol: float = 0.0

    def ok(self, ref: float, cand: float) -> bool:
        if np.isnan(ref) and np.isnan(cand):
            return True
        return abs(cand - ref) <= self.abs_tol + self.rel_tol * abs(ref)

    def __str__(self) -> str:
        return f"abs={self.abs_tol:g} rel={self.rel_tol:g}"


@dataclasses.dataclass(frozen=True)
class ToleranceSpec:
    """The gate set for one wall-vs-virtual comparison.

    The distribution gates bound statistics of the *paired per-request
    absolute differences* (|metric_cand - metric_ref| per rid), except the
    `*_mean_of` gates which compare the two population means directly.
    Defaults are sized for the smoke-model timescale (~4-16 ms per decode
    iteration): generous enough for CI-runner sleep jitter, tight enough
    that a host failing to keep the schedule (or a logic change altering
    admission order) trips them.
    """
    # paired per-request |Δ| statistics (seconds / tokens-per-s / QoE units)
    ttft_mean_diff: Tolerance = Tolerance(abs_tol=0.050)
    ttft_p95_diff: Tolerance = Tolerance(abs_tol=0.150)
    ttft_max_diff: Tolerance = Tolerance(abs_tol=0.500)
    tds_mean_diff: Tolerance = Tolerance(abs_tol=0.50, rel_tol=0.10)
    qoe_mean_diff: Tolerance = Tolerance(abs_tol=0.05)
    qoe_max_diff: Tolerance = Tolerance(abs_tol=0.25)
    # population-mean agreement (catches one-sided drift the paired means
    # also see, but reads directly as "the reported headline number moved")
    qoe_mean_of: Tolerance = Tolerance(abs_tol=0.03)
    require_token_identity: bool = True


@dataclasses.dataclass(frozen=True)
class GateResult:
    name: str
    ref: float          # reference-side value (0.0 for |Δ| statistics)
    cand: float         # candidate-side / statistic value
    tol: Tolerance
    passed: bool

    def line(self) -> str:
        mark = "PASS" if self.passed else "FAIL"
        return (f"  [{mark}] {self.name:<18} stat={self.cand:.6g} "
                f"(ref={self.ref:.6g}, tol {self.tol})")


@dataclasses.dataclass
class ToleranceReport:
    """Outcome of one differential comparison (see compare_requests)."""
    gates: List[GateResult]
    n_pairs: int
    missing_rids: List[int]           # in ref but not in cand (or reverse)
    token_mismatches: List[int]       # rids whose token ids differ
    skipped_rids: List[int]           # cancelled/shed on either side

    @property
    def ok(self) -> bool:
        return (not self.missing_rids and not self.token_mismatches
                and all(g.passed for g in self.gates))

    def summary(self) -> str:
        lines = [f"tolerance report: {self.n_pairs} paired requests, "
                 f"{len(self.skipped_rids)} skipped, "
                 f"{'OK' if self.ok else 'FAILED'}"]
        if self.missing_rids:
            lines.append(f"  [FAIL] unpaired rids: {self.missing_rids[:10]}"
                         + (" ..." if len(self.missing_rids) > 10 else ""))
        if self.token_mismatches:
            lines.append("  [FAIL] token text differs for rids: "
                         f"{self.token_mismatches[:10]}"
                         + (" ..." if len(self.token_mismatches) > 10
                            else ""))
        lines.extend(g.line() for g in self.gates)
        return "\n".join(lines)

    def assert_ok(self) -> None:
        if not self.ok:
            raise AssertionError(self.summary())


def _finite_pairs(ref: np.ndarray, cand: np.ndarray):
    """Drop pairs where either side is non-finite (TDS of a 0/1-token
    response is inf on both sides; comparing inf-inf would poison every
    statistic)."""
    m = np.isfinite(ref) & np.isfinite(cand)
    return ref[m], cand[m]


def _gate(name: str, stat: float, tol: Tolerance,
          ref_val: float = 0.0) -> GateResult:
    """Gate on a non-negative |Δ| statistic: stat must stay within
    abs_tol + rel_tol * |ref_val| (ref_val = the reference-side scale the
    relative part is measured against; 0 for purely absolute gates)."""
    bound = tol.abs_tol + tol.rel_tol * abs(ref_val)
    return GateResult(name, ref_val, stat, tol, stat <= bound)


def compare_requests(
    ref: Sequence[Request],
    cand: Sequence[Request],
    spec: Optional[ToleranceSpec] = None,
) -> ToleranceReport:
    """Differential-compare two served populations of the same trace.

    `ref` is the ground truth (virtual-clock run), `cand` the run under
    test (wall-clock). Pairing is by rid. Requests cancelled or unserved
    on either side are excluded from timing statistics (reported in
    `skipped_rids`) but still token-checked over the shorter prefix.
    """
    spec = spec if spec is not None else ToleranceSpec()
    ref_by: Dict[int, Request] = {r.rid: r for r in ref}
    cand_by: Dict[int, Request] = {r.rid: r for r in cand}
    missing = sorted(set(ref_by) ^ set(cand_by))
    common = sorted(set(ref_by) & set(cand_by))

    token_mismatches: List[int] = []
    skipped: List[int] = []
    ttft_r, ttft_c, tds_r, tds_c, qoe_r, qoe_c = [], [], [], [], [], []
    for rid in common:
        a, b = ref_by[rid], cand_by[rid]
        if spec.require_token_identity:
            ta, tb = list(a.output_tokens), list(b.output_tokens)
            partial = a.cancelled or b.cancelled
            n = min(len(ta), len(tb))
            if (ta[:n] != tb[:n]) or (not partial and len(ta) != len(tb)):
                token_mismatches.append(rid)
        if a.cancelled or b.cancelled or not a.emit_times \
                or not b.emit_times:
            skipped.append(rid)
            continue
        ttft_r.append(a.final_ttft()); ttft_c.append(b.final_ttft())
        tds_r.append(a.final_tds());   tds_c.append(b.final_tds())
        qoe_r.append(a.final_qoe());   qoe_c.append(b.final_qoe())

    gates: List[GateResult] = []
    n_pairs = len(ttft_r)
    if n_pairs:
        ttft_r = np.asarray(ttft_r); ttft_c = np.asarray(ttft_c)
        qoe_r = np.asarray(qoe_r);   qoe_c = np.asarray(qoe_c)
        d_ttft = np.abs(ttft_c - ttft_r)
        gates.append(_gate("ttft_mean_diff", float(d_ttft.mean()),
                           spec.ttft_mean_diff))
        gates.append(_gate("ttft_p95_diff",
                           float(np.percentile(d_ttft, 95)),
                           spec.ttft_p95_diff))
        gates.append(_gate("ttft_max_diff", float(d_ttft.max()),
                           spec.ttft_max_diff))
        fr, fc = _finite_pairs(np.asarray(tds_r), np.asarray(tds_c))
        if fr.size:
            d_tds = np.abs(fc - fr)
            gates.append(_gate("tds_mean_diff", float(d_tds.mean()),
                               spec.tds_mean_diff,
                               ref_val=float(fr.mean())))
        d_qoe = np.abs(qoe_c - qoe_r)
        gates.append(_gate("qoe_mean_diff", float(d_qoe.mean()),
                           spec.qoe_mean_diff))
        gates.append(_gate("qoe_max_diff", float(d_qoe.max()),
                           spec.qoe_max_diff))
        gates.append(GateResult(
            "qoe_mean_of", float(qoe_r.mean()), float(qoe_c.mean()),
            spec.qoe_mean_of,
            spec.qoe_mean_of.ok(float(qoe_r.mean()), float(qoe_c.mean()))))

    return ToleranceReport(gates=gates, n_pairs=n_pairs,
                           missing_rids=missing,
                           token_mismatches=token_mismatches,
                           skipped_rids=skipped)


__all__ = ["Tolerance", "ToleranceSpec", "GateResult", "ToleranceReport",
           "compare_requests"]
