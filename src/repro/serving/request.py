"""Re-export: Request lifecycle lives in repro.core.request (the scheduler
is part of the paper's core and owns the request model)."""
from repro.core.request import Request, ReqState

__all__ = ["Request", "ReqState"]
