"""Compatibility shim: the request model lives in repro.core.request (the
scheduler is part of the paper's core and owns it). All in-repo call sites
import repro.core.request directly; this re-export stays for external users."""
from repro.core.request import Request, ReqState  # noqa: F401

__all__ = ["Request", "ReqState"]
