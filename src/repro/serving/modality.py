"""Modality stubs — the ONE allowed carve-out (DESIGN.md §5).

(Formerly serving/frontend.py; renamed so the `frontend` name is free for
the client-facing serving API in repro.api and the module name matches its
contents — these are modality input stubs, not a serving frontend.)

The assigned [audio] and [vlm] architectures specify the *transformer
backbone*; the conv/mel codec (SeamlessM4T) and the ViT tower (Pixtral) are
stubs that produce correctly-shaped, deterministic embeddings:

  * dry-run:   `audio_frame_specs` / `vision_patch_specs` — ShapeDtypeStructs
  * runtime:   `synthetic_frames` / `synthetic_patches` — smooth, bounded
               embeddings (sinusoidal features of a hashed input id) so
               engine/tests exercise the real cross-attention / prefix paths
               with stable numerics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def audio_frame_specs(cfg: ModelConfig, batch: int, frames: int,
                      dtype=jnp.bfloat16) -> jax.ShapeDtypeStruct:
    """Precomputed mel+conv frame embeddings the encoder consumes."""
    return jax.ShapeDtypeStruct((batch, frames, cfg.d_model), dtype)


def vision_patch_specs(cfg: ModelConfig, batch: int, patches: int,
                       dtype=jnp.bfloat16) -> jax.ShapeDtypeStruct:
    """Precomputed ViT patch embeddings the decoder prefixes."""
    return jax.ShapeDtypeStruct((batch, patches, cfg.d_model), dtype)


def _sinusoid_embed(ids: jax.Array, length: int, d_model: int) -> jax.Array:
    """Deterministic smooth embeddings keyed by per-sample ids (B,)."""
    pos = jnp.arange(length, dtype=jnp.float32)[None, :, None]
    freq = jnp.exp(
        -jnp.arange(d_model, dtype=jnp.float32) / d_model * 4.0
    )[None, None, :]
    phase = (ids.astype(jnp.float32) * 0.7)[:, None, None]
    return 0.1 * jnp.sin(pos * freq + phase)


def synthetic_frames(cfg: ModelConfig, ids: jax.Array, frames: int) -> jax.Array:
    """(B,) sample ids -> (B, frames, d_model) audio-frame embeddings."""
    return _sinusoid_embed(ids, frames, cfg.d_model)


def synthetic_patches(cfg: ModelConfig, ids: jax.Array, patches: int) -> jax.Array:
    """(B,) sample ids -> (B, patches, d_model) vision-patch embeddings."""
    return _sinusoid_embed(ids, patches, cfg.d_model)
