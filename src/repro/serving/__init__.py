from repro.serving.engine import BucketedPrefill, HotpathConfig, ServingEngine
from repro.serving.kv_manager import KVSlotManager
from repro.core.request import Request, ReqState
from repro.serving.simulator import ServingSimulator, SimConfig, SimResult
from repro.serving.speculative import DraftProposer, check_speculation_compatible

__all__ = [
    "Request", "ReqState", "KVSlotManager", "ServingEngine",
    "HotpathConfig", "BucketedPrefill",
    "ServingSimulator", "SimConfig", "SimResult",
    "DraftProposer", "check_speculation_compatible",
]
