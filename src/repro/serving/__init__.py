from repro.serving.engine import ServingEngine
from repro.serving.kv_manager import KVSlotManager
from repro.serving.request import Request, ReqState
from repro.serving.simulator import ServingSimulator, SimConfig, SimResult

__all__ = [
    "Request", "ReqState", "KVSlotManager", "ServingEngine",
    "ServingSimulator", "SimConfig", "SimResult",
]
