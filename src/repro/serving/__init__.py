from repro.serving.engine import BucketedPrefill, HotpathConfig, ServingEngine
from repro.serving.kv_manager import KVSlotManager
from repro.core.request import Request, ReqState
from repro.serving.lossless import (FLIP_TOL, all_flips_documented,
                                    audit_flips, classify_flip, exact_margin,
                                    fingerprint, first_divergence,
                                    timing_fingerprint)
from repro.serving.simulator import ServingSimulator, SimConfig, SimResult
from repro.serving.speculative import DraftProposer, check_speculation_compatible
from repro.serving.tolerance import (Tolerance, ToleranceReport,
                                     ToleranceSpec, compare_requests)

__all__ = [
    "Request", "ReqState", "KVSlotManager", "ServingEngine",
    "HotpathConfig", "BucketedPrefill",
    "ServingSimulator", "SimConfig", "SimResult",
    "DraftProposer", "check_speculation_compatible",
    "FLIP_TOL", "fingerprint", "timing_fingerprint", "first_divergence",
    "exact_margin", "classify_flip", "audit_flips", "all_flips_documented",
    "Tolerance", "ToleranceSpec", "ToleranceReport", "compare_requests",
]
