"""Losslessness fingerprints and the near-tie flip classifier.

The hot-path benchmark (PR 5) and the differential test suites all make
the same two promises about an engine rewrite:

* **exact** — token ids, emission timestamps, preemptions, and final QoE
  reproduce the reference bit-for-bit (`fingerprint`);
* **timing-exact** — the virtual-clock half alone (`timing_fingerprint`),
  used against the pre-PR-5 legacy engine whose *prefill numerics* differ:
  padded, lengths-masked bucketed prefill is mathematically equivalent to
  exact-length prefill but not bitwise equal (last-ulp reduction-order
  differences), so a greedy argmax near-tie can flip a token id.

This module is the single owner of what "documented ulp flip" means.
The initial perturbation is last-ulp scale (the padded-vs-exact logit
gap measures ~1e-6 on the smoke model, pinned in
tests/test_lossless_flips.py), but it does not stay there: the cache
rows it lands in feed every subsequent decode step, so by the position
where a token actually flips the accumulated divergence can reach the
1e-3 scale. A flip is therefore ACCEPTABLE iff, at the first diverging
position, the exact-length model's top-2 logit margin is below
`FLIP_TOL` — the two paths disagreed only where the model sat in its
indecision tail, where amplified float noise is the deciding vote.
Anything larger is a real numerical divergence and the benchmark gate
(and the pinned test in tests/test_lossless_flips.py) fails.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

#: largest exact-path top-2 logit margin a padded-vs-exact prefill flip
#: may hide behind. Measured on the smoke model's 50-request benchmark
#: trace: all observed flips sit at margins 4e-4..9e-3, while the
#: model's typical margins run p10 ~1.1e-2 / median ~6e-2 — the gate
#: sits in the gap, above every amplified-noise flip and below the
#: decided bulk of the margin distribution.
FLIP_TOL = 1e-2


def fingerprint(out) -> list:
    """Everything exact losslessness promises: token ids, emit
    timestamps, preemptions, final QoE."""
    return [(r.rid, tuple(r.output_tokens), tuple(r.emit_times),
             r.preemptions, r.final_qoe()) for r in out]


def timing_fingerprint(out) -> list:
    """The virtual-clock half of the promise (token-id-agnostic)."""
    return [(r.rid, r.generated, tuple(r.emit_times), r.preemptions,
             r.final_qoe()) for r in out]


def first_divergence(a_tokens, b_tokens) -> Optional[int]:
    """Index of the first position where two token streams disagree
    (length mismatch counts at the shared-prefix boundary); None when
    identical."""
    n = min(len(a_tokens), len(b_tokens))
    for i in range(n):
        if a_tokens[i] != b_tokens[i]:
            return i
    return None if len(a_tokens) == len(b_tokens) else n


def exact_margin(model, params, prompt_tokens, prefix) -> float:
    """Top-2 logit margin of the EXACT-LENGTH path at the position that
    emitted token `len(prefix)`: prefill `prompt + prefix` at its true
    length (batch 1, no padding) and measure how decided the model was.

    This is the reference the flip classifier trusts: the exact-length
    forward is the numerics both engines are approximating, so its margin
    at the divergence point is the honest size of the tie."""
    toks = np.concatenate([
        np.asarray(prompt_tokens, np.int32),
        np.asarray(list(prefix), np.int32),
    ]) if len(prefix) else np.asarray(prompt_tokens, np.int32)
    s = int(toks.shape[0])
    cache = model.init_cache(1, s + 1, enc_seq=model.enc_seq(s + 1))
    logits, _ = model.prefill(params, {"tokens": jnp.asarray(toks[None, :])},
                              cache)
    row = np.asarray(logits[0], np.float64)
    top2 = np.partition(row, -2)[-2:]
    return float(top2[1] - top2[0])


def classify_flip(margin: float, tol: float = FLIP_TOL) -> str:
    """'documented_ulp_flip' when the exact path was indifferent at
    float-noise scale; 'real_divergence' otherwise."""
    return "documented_ulp_flip" if abs(margin) <= tol else "real_divergence"


def audit_flips(model, params, out_a, out_b,
                tol: float = FLIP_TOL) -> List[dict]:
    """Compare two runs of the same workload request-by-request and
    classify every token-id mismatch. Returns one record per diverging
    request: rid, first diverging position, the exact-path top-2 margin
    there, and the classification. An empty list means token-identical."""
    flips = []
    by_rid = {r.rid: r for r in out_b}
    for ra in out_a:
        rb = by_rid.get(ra.rid)
        if rb is None:
            continue
        pos = first_divergence(ra.output_tokens, rb.output_tokens)
        if pos is None:
            continue
        prefix = ra.output_tokens[:pos]
        margin = exact_margin(model, params, ra.prompt_tokens, prefix)
        flips.append({
            "rid": int(ra.rid),
            "position": int(pos),
            "margin": margin,
            "classification": classify_flip(margin, tol),
        })
    return flips


def all_flips_documented(flips: List[dict]) -> bool:
    """The benchmark's tolerance gate: every observed flip must be a
    documented ulp flip (margin within FLIP_TOL); vacuously true when
    the runs were token-identical."""
    return all(f["classification"] == "documented_ulp_flip" for f in flips)
