"""Real continuous-batching engine: runs an actual JAX model on device.

This is the integration target for the Andes scheduler — the same
Scheduler/FluidQoE/Request machinery as the simulator, but every decode
iteration executes the model's jitted ``decode_step`` against a static-slot
KV cache, prefills run the real prompt, preemption really moves cache
slices to host numpy (swap) or re-prefills (recompute), and tokens are
greedily sampled.

The clock is virtual by default (advanced by the LatencyModel per step) so
QoE specs in seconds are meaningful on a CPU container and tests are
deterministic.

``clock="wall"`` (PR 9) runs in *real time, paced to the LatencyModel
schedule*: every ``_tick(dt)`` sleeps off whatever part of ``dt`` the host
computation didn't already consume, then stamps ``self.now`` with a real
``time.monotonic()`` reading relative to ``reset()``. The engine therefore
advances on the same schedule as the virtual clock — idle engines sleep
until the next arrival instead of jumping the clock — but every recorded
timestamp carries genuine OS scheduling jitter, sleep quantization, and
whatever the host stole. Consequences, by design:

* **Token text is identical** to the virtual-clock run of the same trace:
  the clock only decides *when* things happen, never *what* is computed —
  per-slot decode is row-independent and swap preemption is exact — so as
  long as admission order is preserved the emitted ids match 1:1
  (tests/test_tolerance.py pins this; the CI server smoke re-asserts it
  over a real socket).
* **Timestamps are NOT bit-exact**, so wall-clock runs are validated by
  the tolerance-based differential harness (repro.serving.tolerance):
  TTFT/TDS/QoE distributions must agree with the virtual reference within
  stated tolerances. If the host cannot keep up with the modeled
  schedule, the drift shows up there — that is the harness *measuring*
  the gap, not a bug in the clock.
* The multi-step fast path is disabled (see below) and ``run()`` takes
  real seconds: wall engines are for serving (repro.server), not sweeps.

The engine also serves as the oracle for validating the simulator
(tests/test_sim_vs_engine.py): same scheduler, same workload, same latency
model ⇒ near-identical scheduling traces.

Like the simulator, the engine is *steppable*: ``submit()`` enqueues
arrivals, ``step()`` executes one continuous-batching iteration (schedule
→ preempt → swap-in/prefill → decode), and ``result()``
snapshots a SimResult. ``run()`` is a thin loop over ``step()`` that
reproduces the pre-refactor batch loop bit-for-bit
(tests/test_engine_steppable.py holds a transcription of the legacy loop
as the differential oracle). This makes ServingEngine satisfy
``repro.cluster.replica.SteppableBackend`` verbatim, so real-model
replicas plug into the cluster layer unchanged.

Speculative decoding (``draft_model``/``spec_k``): each scheduled step a
small draft model greedily proposes ``k`` tokens per running request
(serving/speculative.py), the target verifies the whole window in one
``verify_step`` call, and the longest prefix matching the target's own
greedy argmax is committed plus the correction/bonus token — so every
request's emitted token sequence is *identical* to the non-speculative
engine's (lossless by construction; tests/test_speculative.py asserts it
trace-for-trace) while decode steps shrink by the acceptance factor. A
step emits a 1..k+1 token burst at one timestamp; FluidQoE.emit absorbs
it and the client-side pace_delivery smooths it back to the spec'd TDS,
which is precisely the paper's QoE machinery rewarding burst delivery.

Hot path (``HotpathConfig``, ON by default — PR 5)
--------------------------------------------------
Three optimizations make the loop run as fast as the hardware allows
without changing a single emitted token or timestamp (the four
differential suites run with them enabled):

* **Bucketed, batched prefill** (``prefill_buckets``): prompts are
  right-padded to a small geometric bucket grid (powers of two from
  ``bucket_min`` up past ``max_seq``) and driven through a jitted
  ``Model.prefill`` via its ``lengths`` masking, so prefill compile count
  is bounded by #length-buckets × #row-buckets instead of one compile per
  distinct prompt length. All requests admitted in the same ``step()``
  prefill together — grouped BY BUCKET, because a request's bucket must
  depend only on its own length for the batched call to stay bit-identical
  to the sequential batch-1 path the legacy oracle drives (row
  independence of the padded forward; pinned in tests/test_hotpath.py) —
  and land in their slots with one fused multi-row ``_write_slots``
  scatter instead of N separate dispatches. Virtual-clock bookkeeping
  (per-request prefill ticks, first-token emit times, KV accounting)
  is staged in admission order on the host, so timestamps are exactly
  the sequential path's. MoE models are excluded: expert capacity is
  proportional to the forward's TOTAL token count, padding included, so
  padded or batched prefill would change which tokens the capacity gate
  drops — MoE engines keep the eager exact-length path.

* **Fused on-device sampling** (``fused_sampling``): the jitted decode /
  verify entry points return argmax token ids ((slots,) int32) instead of
  ``(slots, vocab)`` logits, shrinking the per-iteration device→host
  transfer by a factor of vocab_size. The speculative accept-prefix scan
  (cumprod of proposal/greedy matches) moves on-device too, so one
  speculative iteration is ONE fused dispatch + ONE host sync
  (draft propose → window concat → target verify → argmax → accept counts)
  instead of two round-trips. Greedy ties break identically to the
  host-side argmax (first max wins) — the losslessness foundation.

* **Multi-step decode** (``multi_step`` = j_max): when the Andes selective
  trigger (§4.2 #1) is certifiably off for the whole window
  (``Scheduler.idle_steps`` projects the memory/latency triggers forward),
  every live request is decoding, no pending arrival (or driver ``until``
  bound) lands strictly inside the window, and no slot can finish inside
  it (output_len margin), the engine runs j decode iterations in one
  jitted ``lax.scan`` (``Model.decode_multi``) and commits j tokens per
  slot off a single host sync. Per-step virtual-clock emit timestamps are
  reconstructed EXACTLY: the clock is deterministic, so the commit loop
  replays the identical ``iter_latency(B, ctx)`` tick sequence (context
  grows by B per step) the one-step loop would have produced. j is
  quantized to powers of two so scan compile count stays bounded. EOS is
  unpredictable, so with ``eos_id`` enabled the scan may overshoot an
  end-of-sequence: committing stops exactly where the one-step baseline
  stops and the overshoot is discarded by the length gate
  (models/cache.py: attention never reads past ``length``) — which is why
  the EOS-enabled fast path is only legal on length-rollback-capable
  caches (``supports_length_rollback``; SSM/hybrid state cannot roll
  back, so those run multi-step only with EOS disabled, where the
  output_len margin makes overshoot impossible). Wall-clock engines
  (``clock="wall"``) cannot reconstruct per-step timestamps and always
  single-step.

``hotpath_stats()`` reports host syncs, prefill compile signatures, and
multi-step block counts — benchmarks/engine_hotpath.py gates the speedup
and compile-count claims on them.

Scale substrate (PR 8): chunked prefill + paged KV
--------------------------------------------------
Two knobs turn the 8-slot smoke engine into a 100x-scale serving
substrate (benchmarks/engine_hotpath.py --scale drives a 1000-request
heavy-tail trace through them):

* **Chunked prefill** (``prefill_chunk`` > 0): a prompt longer than the
  chunk size no longer monopolizes the device for one monolithic
  prefill. Admission commits only the first chunk; the request then
  holds its slot with a ``prefill_cursor`` and advances one chunk per
  scheduled iteration, interleaved with every other resident's decode
  tick — the §2.2 TTFT/TDS interference knob. The chunk-scheduling
  contract: a mid-prefill request is a RUNNING resident (the Andes
  knapsack prices it through ``QoEPricer.serve_delay`` by the chunks it
  still owes), it never joins the decode batch while its cursor is
  nonzero, KV charges grow chunk-by-chunk (page-granular when paged),
  and preemption either parks the committed prefix (swap; the cursor
  survives and chunking resumes after swap-in) or rewinds the cursor to
  zero (recompute). Each chunk recomputes the prefix at the cursor's
  bucket through the SAME jitted bucketed call the monolithic path
  uses, so the final chunk — full prompt length, full-length bucket —
  is bit-identical to the monolithic prefill: committed cache and first
  token match exactly (the differential oracle in
  tests/test_chunked_prefill.py), while the per-chunk
  ``LatencyModel.prefill_chunk_latency`` keeps its TTFT honest.
  Requires the bucketed prefill path (non-MoE) and ``spec_k=0``.

* **Paged KV** (``page_size``): ``KVSlotManager`` prices capacity as a
  pool of fixed-size pages with a block table per request
  (serving/kv_manager.py module docstring has the layout) —
  admission/`grow` charge whole pages, preemption returns partial
  pages, and the scheduler's capacity views round knapsack weights up
  to page multiples (``SchedulerConfig.page_size``, wired
  automatically). The device cache stays per-slot rows; pages govern
  accounting granularity. ``page_size=None`` (or >= max_seq) is the
  legacy fixed-depth manager bit-for-bit; ``page_size=1`` reproduces
  token-granular admission exactly (both pinned differentially in
  tests/test_paged_kv.py).

Physical paging + persistent decode loop
----------------------------------------
Two device-side follow-ons lift PR 8's host-side accounting onto the
accelerator (``tests/test_physical_paging.py`` / ``test_persistent_loop.py``):

* **Physical page pool** (``physical_pages``; auto-ON for paged,
  non-speculative engines over archs whose decode state is pure
  length-gated attention KV — ``cache_lib.supports_physical_paging``):
  the device cache becomes the pool layout of
  ``models/cache.py:init_paged_cache`` — ``k``/``v`` hold
  ``KVSlotManager.total_pages`` physical pages shared by all slots, and
  a ``block_tables`` leaf maps each slot's context onto the pages its
  manager-side block table names. The manager is now the ALLOCATOR, not
  just the accountant: ``evict_tail``/release free real HBM rows and
  admission capacity IS the physical pool. Decode routes through the
  pallas paged-attention kernel (kernels/paged_attention.py; gather
  resolved at DMA-issue time via scalar-prefetched tables), prefill
  commits scatter through ``paged_write_tokens``, and swap-out gathers
  a slot's pages back into one contiguous host row (identical bytes to
  the fixed-row slice, so swap accounting and the tolerance fingerprints
  carry over unchanged). Device tables re-upload lazily: the manager
  bumps a ``version`` on every page movement and the engine re-pins
  ``block_tables`` (pure data, no recompile) only when it changed.
  Every emitted token and timestamp is bit-identical to the
  accounting-only engine — the paged kernel's masked tiles contribute
  exact zeros — pinned at ``page_size=1`` and ``page_size >= max_seq``
  (the degenerate oracles) and at interior page sizes, both preemption
  modes. Because overdraft page ids name no physical row, the physical
  engine *pre-reserves* (``ensure_pages``) every page a decode block can
  write before dispatching and raises if the pool is exhausted — the
  scheduler watermark keeps certified demand under capacity, so this
  fires only on a genuinely over-admitting policy.

* **Persistent device decode loop** (``HotpathConfig.persistent``): the
  multi-step scan becomes a device-resident ``lax.while_loop``
  (``Model.decode_persistent``) whose iteration bound j is a *dynamic*
  scalar — ``Scheduler.idle_steps`` is the "how long may the device run
  unsupervised" certificate, and the loop runs until it expires or every
  live row hits EOS, committing the whole block off ONE host sync.
  Dynamic j means no power-of-two quantization (one compile per out-
  buffer depth serves every block size), so blocks are longer and host
  syncs strictly fewer than the PR 5 scan on the same trace, while the
  committed region replays the scan bit-for-bit (the while body IS the
  scan body; rows past the certificate are discarded by the length
  gate). With ``wall_multi_step`` a wall-clock engine (the HTTP server
  pump) runs j-step blocks too: emissions are paced per-step by `_tick`
  as always, and a mid-block check breaks the commit early when a
  pending arrival lands so admission latency stays one iteration, not j
  — timestamps there are tolerance-gated (serving/tolerance.py), token
  text identical.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.qoe import FluidQoE
from repro.core.scheduler import Scheduler
from repro.models import cache as cache_lib
from repro.models.model import Model
from repro.serving.kv_manager import KVSlotManager
from repro.core.request import Request, ReqState
from repro.serving.simulator import SimResult
from repro.serving.speculative import DraftProposer, check_speculation_compatible


@dataclasses.dataclass(frozen=True)
class HotpathConfig:
    """Engine hot-path optimization switches (module docstring; all
    lossless and ON by default — the benchmark baseline is
    ``HotpathConfig.baseline()``)."""
    prefill_buckets: bool = True    # bucketed/batched/jitted prefill
    bucket_min: int = 16            # smallest prompt-length bucket
    fused_sampling: bool = True     # on-device argmax (+ spec accept scan)
    multi_step: int = 8             # max decode iters per dispatch (1 = off)
    persistent: bool = True         # fused blocks via the device-resident
                                    # while_loop (dynamic, unquantized j)
                                    # instead of the static-j scan
    wall_multi_step: bool = True    # let wall-clock engines run fused
                                    # blocks (length-rollback archs only;
                                    # timestamps tolerance-gated)

    @staticmethod
    def baseline() -> "HotpathConfig":
        """The pre-PR-5 hot path: eager exact-length batch-1 prefill,
        full-logit host argmax, one decode iteration per dispatch."""
        return HotpathConfig(prefill_buckets=False, fused_sampling=False,
                             multi_step=1)


def _slot_axis(leaf_ndim: int) -> int:
    return 0 if leaf_ndim == 1 else 1   # length (B,) vs (L, B, ...)


@functools.partial(jax.jit, static_argnames=("slot",))
def _write_slot(cache, src, slot):
    """Insert batch-1 `src` pytree into `cache` at batch slot `slot`."""
    def ins(c, s):
        ax = _slot_axis(c.ndim)
        idx = [slice(None)] * c.ndim
        idx[ax] = slot
        return c.at[tuple(idx)].set(jnp.squeeze(s, ax).astype(c.dtype))
    return jax.tree.map(ins, cache, src)


@jax.jit
def _write_slots(cache, src, slots):
    """Insert an N-row `src` pytree into `cache` at batch slots `slots`
    ((N,) int32) — ONE fused scatter per leaf instead of N dispatches.
    Rows whose slot id is out of range (row-bucket padding uses
    num_slots as the sentinel) are dropped by the scatter."""
    def ins(c, s):
        ax = _slot_axis(c.ndim)
        cm = jnp.moveaxis(c, ax, 0)
        sm = jnp.moveaxis(s, ax, 0).astype(c.dtype)
        return jnp.moveaxis(cm.at[slots].set(sm, mode="drop"), 0, ax)
    return jax.tree.map(ins, cache, src)


@functools.partial(jax.jit, static_argnames=("slot",))
def _read_slot(cache, slot):
    def rd(c):
        ax = _slot_axis(c.ndim)
        return jax.lax.index_in_dim(c, slot, ax, keepdims=True)
    return jax.tree.map(rd, cache)


@jax.jit
def _paged_commit(cache, bt_rows, starts, k_seg, v_seg, counts):
    """Scatter contiguous k/v token segments into the physical page pool
    (the paged image of `_write_slots`): row i of the segs holds
    `counts[i]` tokens landing at absolute positions starts[i].. through
    the pages named by bt_rows[i]. Sentinel-routed positions drop, so
    padding rows (all-sentinel table row, count 0) are free."""
    return dict(
        cache,
        k=cache_lib.paged_write_tokens(
            cache["k"], bt_rows, starts, k_seg, counts),
        v=cache_lib.paged_write_tokens(
            cache["v"], bt_rows, starts, v_seg, counts),
    )


@functools.partial(jax.jit, static_argnames=("max_seq",))
def _paged_read_row(cache, table_row, slot, *, max_seq):
    """Gather one slot's pages back into a contiguous cache row — the
    paged image of `_read_slot`, same leaf shapes/bytes, so swap
    accounting and restore are layout-blind."""
    return {
        "length": cache["length"][slot][None],
        "k": cache_lib.paged_gather_rows(cache["k"], table_row, max_seq),
        "v": cache_lib.paged_gather_rows(cache["v"], table_row, max_seq),
    }


class BucketedPrefill:
    """Jitted, shape-bucketed prefill front-end for one model.

    Pads a group of prompts (all mapping to the same length bucket —
    the caller groups) to (row_bucket, len_bucket), runs one jitted
    ``Model.prefill`` with per-row ``lengths`` masking, takes the
    first-token argmax on device, and returns (first_ids (N,), cache rows)
    for a fused `_write_slots` scatter. Compile count is bounded by
    #length-buckets × #row-buckets; `shapes_seen` records the signatures
    actually compiled (the compile-count regression gate)."""

    def __init__(self, model: Model, cache_seq: int, cache_dtype, *,
                 max_seq: int, bucket_min: int = 16):
        self.model = model
        self.cache_seq = cache_seq
        self.cache_dtype = cache_dtype
        self.enc_seq = model.enc_seq(max_seq)
        # geometric (x2) grid from bucket_min; the terminal bucket is
        # clamped to the physical cache depth (prefill writes the padded
        # rows with dynamic_update_slice, which must fit) and still covers
        # max_seq because cache_seq >= max_seq always
        self.buckets: List[int] = []
        b = max(2, int(bucket_min))
        while b < max_seq and b < cache_seq:
            self.buckets.append(b)
            b *= 2
        self.buckets.append(min(b, cache_seq))
        self.shapes_seen = set()        # (rows, len_bucket) jit signatures
        self.on_compile = None          # optional fn(key) on new signature
        self._jit = jax.jit(self._call)

    def note_shape(self, key) -> None:
        """Record a jit signature entering the compile cache (fires the
        observability callback exactly once per new shape)."""
        if key not in self.shapes_seen:
            self.shapes_seen.add(key)
            if self.on_compile is not None:
                self.on_compile(key)

    def bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    @staticmethod
    def row_bucket(n: int) -> int:
        b = 1
        while b < n:
            b *= 2
        return b

    def _call(self, params, tokens, lengths, frames):
        cache = self.model.init_cache(
            tokens.shape[0], self.cache_seq, enc_seq=self.enc_seq,
            dtype=self.cache_dtype,
        )
        batch = {"tokens": tokens, "lengths": lengths}
        if self.enc_seq:
            batch["frames"] = frames
        logits, cache = self.model.prefill(params, batch, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def prefill_into(self, params, cache, slots, toks_list,
                     frames_list=None, *, need_first=True, write=None):
        """Grouped flush: prefill every (slot, tokens) pair and scatter the
        rows into `cache` — one padded multi-row call + one fused
        `_write_slots` per bucket group (grouping BY BUCKET keeps each
        row bit-identical to its own batch-1 call). The single flush
        implementation shared by the engine's admission path and the
        draft proposer's cache build. Returns (cache', first_ids (N,)
        int32 aligned with the inputs — zeros when need_first=False,
        which also skips the device→host fetch — the number of
        device→host sync rounds performed, and the number of bucket
        groups dispatched). `write` overrides the slot-row scatter (the
        physically paged engine passes its page-pool committer; rows map
        to slots via the same padded (N,) id array, sentinel=num_slots)."""
        if write is None:
            write = lambda c, s, pad: _write_slots(c, s, jnp.asarray(pad))
        groups: dict = {}
        for i, t in enumerate(toks_list):
            groups.setdefault(self.bucket(len(t)), []).append(i)
        first_out = np.zeros(len(toks_list), np.int32)
        oob = cache["length"].shape[0]          # row-pad scatter sentinel
        syncs = 0
        for bucket in sorted(groups):
            idxs = groups[bucket]
            first, src = self.run(
                params, [toks_list[i] for i in idxs],
                [frames_list[i] for i in idxs] if frames_list else None,
            )
            rows = src["length"].shape[0]
            pad = np.full((rows,), oob, np.int32)
            pad[: len(idxs)] = [slots[i] for i in idxs]
            cache = write(cache, src, pad)
            if need_first:
                first = np.asarray(first)
                syncs += 1
                for j, i in enumerate(idxs):
                    first_out[i] = first[j]
        return cache, first_out, syncs, len(groups)

    def run(self, params, toks_list, frames_list=None):
        """Prefill one same-bucket group. toks_list: per-request token
        arrays; returns (first_ids np (N,), padded cache rows)."""
        n = len(toks_list)
        rows = self.row_bucket(n)
        seq = self.bucket(max(len(t) for t in toks_list))
        tokens = np.zeros((rows, seq), np.int32)
        lengths = np.zeros((rows,), np.int32)
        for i, t in enumerate(toks_list):
            tokens[i, : len(t)] = t
            lengths[i] = len(t)
        frames = 0
        if self.enc_seq:
            d = self.model.cfg.d_model
            frames = np.zeros((rows, self.enc_seq, d), np.float32)
            for i in range(n):
                f = frames_list[i] if frames_list else None
                if f is not None:
                    frames[i] = f
        self.note_shape((rows, seq))
        first, cache = self._jit(
            params, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(frames) if self.enc_seq else None,
        )
        return first, cache


@dataclasses.dataclass
class _StagedPrefill:
    """One admission whose host bookkeeping is done but whose device work
    (prefill + slot write + first-token id) is deferred to the batched
    flush. `emit_t` is the already-ticked first-token timestamp (None for
    recompute resumes, which emit nothing at prefill)."""
    req: Request
    slot: int
    toks: np.ndarray
    emit_t: Optional[float]
    frames: Optional[np.ndarray] = None


class ServingEngine:
    """Real continuous-batching engine over a jitted JAX model.

    Incremental API (used by the cluster layer's `Replica`, identical to
    ServingSimulator's):
      submit(req)  enqueue an arrival (any time, in any order)
      step()       one scheduling+decode iteration; False when out of work
                   (`until=t` bounds the multi-step fast path so the clock
                   crosses t at the same single iteration it would when
                   single-stepping — what Replica.advance_to passes)
      has_work     pending or live requests remain
      result()     SimResult over every request ever submitted

    Batch API (classic single-node experiments):
      run(workload)  submit all + step to completion
    """

    def __init__(
        self,
        model: Model,
        params,
        scheduler: Scheduler,
        lat: LatencyModel,
        *,
        num_slots: int = 8,
        max_seq: int = 256,
        capacity_tokens: Optional[int] = None,
        preemption_mode: str = "swap",
        clock: str = "virtual",
        eos_id: int = -1,
        cache_dtype=jnp.float32,
        draft_model: Optional[Model] = None,
        draft_params=None,
        spec_k: int = 0,
        hotpath: Optional[HotpathConfig] = None,
        prefill_chunk: int = 0,
        page_size: Optional[int] = None,
        physical_pages: Optional[bool] = None,
    ):
        self.model = model
        self.params = params
        self.sched = scheduler
        self.lat = lat
        self.preemption_mode = preemption_mode
        self.clock = clock
        self.eos_id = eos_id
        self.hotpath = hotpath if hotpath is not None else HotpathConfig()
        # observability (repro.obs): `self.obs` is the effective observer
        # (None = off; every instrumentation point guards on that) composed
        # from an installed Observer and/or a legacy `event_sink` callable
        # (deprecated; wrapped in EventSinkAdapter). Survives reset() so
        # run() keeps reporting to installed consumers.
        self._observer = None
        self._event_sink = None
        self.obs = None
        self.max_seq = max_seq
        self._num_slots = num_slots
        self._capacity_tokens = capacity_tokens
        # EOS-enabled multi-step may overshoot and roll back by length —
        # only legal on length-gated caches (models/cache.py)
        self._rollback_ok = cache_lib.supports_length_rollback(model.cfg)

        # ---- speculative decoding (optional) --------------------------
        self.spec_k = int(spec_k)
        # a verify window writes up to k+1 positions past a slot's
        # committed context; pad the *physical* cache by that much so the
        # writes never hit the dynamic_update_slice index clamp (which
        # would silently corrupt position max_seq-1 for requests ending
        # within k tokens of the boundary — breaking the lossless gate).
        # max_seq stays the logical per-request bound: emission is capped
        # at it and KV accounting never counts the slack.
        self._cache_seq = max_seq + (self.spec_k + 1 if self.spec_k else 0)
        if self.spec_k:
            if draft_model is None or draft_params is None:
                raise ValueError("spec_k > 0 requires draft_model/draft_params")
            check_speculation_compatible(model, draft_model)
            self.draft = DraftProposer(
                draft_model, draft_params, num_slots=num_slots,
                max_seq=self._cache_seq, cache_dtype=cache_dtype,
                bucketed=(BucketedPrefill(
                    draft_model, self._cache_seq, cache_dtype,
                    max_seq=max_seq, bucket_min=self.hotpath.bucket_min,
                ) if self.hotpath.prefill_buckets else None),
            )
            self._verify = jax.jit(model.verify_step)
            self._spec_fused = self._make_spec_fused()
            self._spec_block = self._make_spec_block()
        else:
            self.draft = None

        # ---- physical paging (PR 10): real device page pool ------------
        # page-granular *accounting* (PR 8) is `paged`; backing the block
        # tables with a physical pool is opt-in by capability: any paged,
        # non-speculative engine whose model family supports it (dense/
        # vlm/moe — the cache is a plain k/v pytree). `physical_pages`
        # overrides: True forces it (raising when unsupported, so callers
        # cannot silently fall back to accounting-only), False forces the
        # contiguous cache (the accounting-only differential baseline).
        paged = page_size is not None and 0 < int(page_size) < max_seq
        if physical_pages is None:
            physical_pages = (paged and not self.spec_k
                              and model.supports_physical_paging())
        elif physical_pages:
            if not paged:
                raise ValueError(
                    "physical_pages=True requires a paged engine "
                    "(0 < page_size < max_seq)")
            if self.spec_k:
                raise ValueError(
                    "physical_pages=True is incompatible with speculative "
                    "decoding (verify windows write past the block table)")
            if not model.supports_physical_paging():
                raise ValueError(
                    f"model kind {model.cfg.kind!r} does not support a "
                    "physically paged KV cache")
        self.physical_pages = bool(physical_pages)
        if self.physical_pages:
            # pool geometry mirrors KVSlotManager exactly: the physical
            # pool IS the admission capacity — every page id the manager
            # hands out names a real device row
            cap_tokens = capacity_tokens or num_slots * max_seq
            self._pool_pages = -(-cap_tokens // page_size)
            self._max_pages = -(-self._cache_seq // page_size)
            self.cache = model.init_paged_cache(
                num_slots, self._pool_pages, page_size, self._cache_seq,
                dtype=cache_dtype,
            )
        else:
            self._pool_pages = 0
            self._max_pages = 0
            self.cache = model.init_cache(
                num_slots, self._cache_seq, enc_seq=model.enc_seq(max_seq),
                dtype=cache_dtype
            )
        self._decode = jax.jit(model.decode_step)
        self._decode_tok = jax.jit(model.decode_tokens)
        self._decode_multi = jax.jit(model.decode_multi,
                                     static_argnames=("j",))
        self._decode_persist = jax.jit(model.decode_persistent,
                                       static_argnames=("j_cap", "eos_id"))
        self._prefill = BucketedPrefill(
            model, self._cache_seq, cache_dtype, max_seq=max_seq,
            bucket_min=self.hotpath.bucket_min,
        )
        # MoE expert capacity is proportional to the TOTAL token count of
        # the forward (padding included), so padding a prompt — or batching
        # it with others — changes which tokens the capacity gate drops:
        # bucketed prefill cannot be exact there. MoE engines keep the
        # eager exact-length path (tests/test_hotpath.py pins the
        # exclusion); every other family buckets and batches.
        self._prefill_bucketable = model.cfg.kind != "moe"
        # ---- scale substrate: chunked prefill + paged KV (PR 8) --------
        self.prefill_chunk = int(prefill_chunk)
        self._page_size = page_size
        if self.prefill_chunk:
            if self.spec_k:
                raise ValueError("chunked prefill requires spec_k=0")
            if not (self.hotpath.prefill_buckets
                    and self._prefill_bucketable):
                raise ValueError(
                    "chunked prefill requires the bucketed prefill path "
                    "(hotpath.prefill_buckets=True, non-MoE model)")
        self.reset()
        # scheduler capacity/pricing views follow the engine's granularity
        # (only when the caller hasn't configured them explicitly)
        if self.kv.paged and not self.sched.cfg.page_size:
            self.sched.cfg.page_size = self.kv.page_size
        if self.prefill_chunk and not self.sched.cfg.prefill_chunk:
            self.sched.cfg.prefill_chunk = self.prefill_chunk

    # ------------------------------------------------------------------ state
    def reset(self) -> None:
        """Clear all serving state (the device cache pytree is reused; live
        slots are always re-written at prefill/swap-in time). The
        KVSlotManager object is reused too — cleared in place — so gauges
        bound to `engine.kv` (repro.obs.metrics.register_backend_gauges)
        stay valid across run()/reset() cycles."""
        if getattr(self, "kv", None) is None:
            self.kv = KVSlotManager(self._num_slots, self.max_seq,
                                    self._capacity_tokens,
                                    burst_reserve=(self.spec_k + 1
                                                   if self.spec_k else 0),
                                    page_size=self._page_size)
        else:
            self.kv.reset()
        self.sched.reset()           # policy state (counters, orders)
        self.fluid = FluidQoE()
        self.spec_steps = 0          # verify iterations executed
        self.spec_proposed = 0       # draft tokens proposed per verify (k each)
        self.spec_accepted = 0       # draft tokens accepted by the target
        if hasattr(self.lat, "reset"):
            self.lat.reset()         # speculative acceptance EMA -> prior
        self.now = 0.0
        self.slot_req: Dict[int, Request] = {}
        self.preemptions = 0
        self.total_tokens = 0
        self.iterations = 0
        self.batch_sizes: List[int] = []
        self._pending: List[Request] = []    # sorted arrivals; admitted
        self._pending_pos = 0                #   prefix tracked by cursor
        self.live: List[Request] = []
        self.seen: List[Request] = []        # submit order
        self.stuck = False                   # deadlocked (cleared by submit)
        self.host_syncs = 0                  # device→host transfer rounds
        self.dispatches = 0                  # device computation launches
        self.multi_step_blocks = 0           # fused multi-iteration dispatches
        self.multi_step_iters = 0            # iterations committed by them
        self.persistent_blocks = 0           # of which: device while_loop blocks
        self.persistent_iters = 0            # device loop iterations executed
        self.page_gathers = 0                # pool→contiguous row gathers (swap)
        self.page_scatters = 0               # contiguous→pool scatters (commits)
        self.page_gather_bytes = 0           # bytes moved by those gathers
        # device block tables are re-uploaded lazily: only when the page
        # assignment edition (kv.version) moved since the last upload
        self._kv_version_seen = -1
        self._bt_host = None
        self._wall0 = time.monotonic()

    # ------------------------------------------------------------ observers
    @property
    def observer(self):
        """Installed Observer (repro.obs); None = observability off."""
        return self._observer

    @observer.setter
    def observer(self, obs) -> None:
        self._observer = obs
        self._rewire_obs()

    @property
    def event_sink(self):
        """Legacy lifecycle callable `sink(kind, req, t, k)` (deprecated;
        kept as an EventSinkAdapter shim — prefer `observer`)."""
        return self._event_sink

    @event_sink.setter
    def event_sink(self, sink) -> None:
        self._event_sink = sink
        self._rewire_obs()

    def set_observer(self, obs) -> None:
        self.observer = obs

    def attach_observer(self, obs) -> None:
        """Add `obs` alongside any already-installed observer."""
        from repro.obs.observer import compose
        self.observer = compose(self._observer, obs)

    def _rewire_obs(self) -> None:
        from repro.obs.observer import EventSinkAdapter, compose
        sink_obs = (EventSinkAdapter(self._event_sink)
                    if self._event_sink is not None else None)
        self.obs = compose(self._observer, sink_obs)
        self.sched.obs = self.obs
        obs = self.obs
        cb = ((lambda key: obs.jit_compile(self.now, key))
              if obs is not None else None)
        self._prefill.on_compile = cb
        if self.spec_k and self.draft.bucketed is not None:
            self.draft.bucketed.on_compile = cb

    def _sync(self, n: int = 1) -> None:
        """Count host<->device synchronization rounds."""
        if n:
            self.host_syncs += n
            if self.obs is not None:
                self.obs.sync(self.now, n)

    def _dispatch(self, kind: str, n: int = 1) -> None:
        """Count device computation dispatches (model-forward launches;
        cheap metadata ops like `with_lengths` are not counted)."""
        if n:
            self.dispatches += n
            if self.obs is not None:
                self.obs.dispatch(self.now, kind, n)

    # ------------------------------------------------------ physical paging
    def _refresh_block_tables(self) -> None:
        """Re-pin the device block tables to the manager's current page
        assignment — a no-op unless pages moved since the last upload
        (kv.version gates it), so steady-state decode re-uploads nothing.

        The host mirror has num_slots+1 rows: row `slot` holds that slot's
        table (sentinel = pool size past its end — scatters drop, gathers
        clamp under the length mask), and the extra all-sentinel last row
        is the scatter target for padding rows in grouped prefills. Rows
        of slots that do not currently own a table are all-sentinel too,
        so a garbage decode write from an inactive batch lane drops
        instead of landing in a page some other slot now owns.

        Raises RuntimeError on overdraft ids (>= pool size): in physical
        mode those name no device row, and clamping them would alias a
        real page. The admission watermark (policies/andes.py) keeps a
        certified engine below the pool, so this firing means the policy
        overcommitted physical memory."""
        if not self.physical_pages or self.kv.version == self._kv_version_seen:
            return
        P = self._pool_pages
        bt = np.full((self.kv.num_slots + 1, self._max_pages), P, np.int32)
        for rid, table in self.kv.block_table.items():
            slot = self.kv.slot_of.get(rid)
            if slot is None:
                continue
            if table and max(table) >= P:
                raise RuntimeError(
                    f"physical page pool overdrawn (page id {max(table)} "
                    f">= pool size {P}): the scheduler admitted more "
                    "context than the device pool holds")
            if len(table) > self._max_pages:
                raise RuntimeError(
                    f"request {rid} holds {len(table)} pages but a slot "
                    f"spans at most {self._max_pages} "
                    f"(max_seq={self.max_seq}): its prompt_len + "
                    "output_len exceeds the engine's context budget — the "
                    "contiguous layout silently clamps such overflow "
                    "writes; the physical pool refuses it")
            bt[slot, : len(table)] = table
        self._bt_host = bt
        self.cache = cache_lib.with_block_tables(self.cache, bt[:-1])
        self._kv_version_seen = self.kv.version

    def _paged_writer(self, cache, src, pad):
        """Scatter a contiguous prefill result `src` (rows of k/v planes
        plus lengths) into the page pool — the paged image of
        `_write_slots`. `pad` maps rows to slots exactly as the contiguous
        path's scatter does (sentinel = num_slots → the all-sentinel extra
        block-table row → every write drops). Chunked prefill recomputes
        the whole prefix each chunk, so starts are always 0 and counts the
        committed length."""
        rows = np.asarray(pad, np.int32)
        bt_rows = jnp.asarray(self._bt_host[rows])
        # counts = length + 1: the contiguous path writes the FULL padded
        # row, and the one junk position a fresh request ever attends is
        # index `prompt` (its first emitted token's KV is never written —
        # the decode window reaches it from the first iteration on). The
        # +1 copies that position's contiguous content; rows whose page
        # coverage stops at `length` (recompute resumes) route it to the
        # sentinel and drop, exactly where the extra position is
        # overwritten in-step by the next decode anyway.
        counts = src["length"].astype(jnp.int32) + 1
        starts = jnp.zeros_like(counts)
        self.page_scatters += 1
        return _paged_commit(cache, bt_rows, starts,
                             src["k"], src["v"], counts)

    def submit(self, req: Request) -> None:
        """Enqueue an arrival. Stable insert keeps equal-arrival order
        (bisect_right above the admitted-prefix cursor — identical order
        to the old insort-into-a-popped-list, without its O(n²) drain)."""
        i = bisect.bisect_right(self._pending, req.arrival,
                                lo=self._pending_pos,
                                key=lambda r: r.arrival)
        self._pending.insert(i, req)
        self.seen.append(req)
        if self.obs is not None:
            self.obs.submit(req, req.arrival)
        # a new arrival may change the scheduler's choice even if the
        # current live set deadlocked — try again
        self.stuck = False

    def cancel(self, rid: int) -> bool:
        """Abort a request by rid (client disconnect / explicit cancel).

        The request is finalized immediately with whatever it has emitted:
        marked ``cancelled`` + FINISHED, its KV slot (or parked host swap
        slices) freed, and the scheduler notified — so the next step()'s
        knapsack prices the freed memory. Safe in any state; returns False
        if the rid is unknown or already finished (cancel racing normal
        completion is expected with live clients and must be a no-op)."""
        t = self.wall_now()
        for i in range(self._pending_pos, len(self._pending)):
            r = self._pending[i]
            if r.rid == rid:
                # never admitted: no fluid slot, scheduler never saw it
                del self._pending[i]
                r.cancelled = True
                r.state = ReqState.FINISHED
                r.finish_time = t
                if self.obs is not None:
                    self.obs.cancel(r, t)
                return True
        for r in self.live:
            if r.rid == rid:
                if r.state == ReqState.RUNNING:
                    slot = r.engine_slot
                    self.kv.release(r)
                    self.slot_req.pop(slot, None)
                elif r.state == ReqState.SWAPPED:
                    self.kv.host_store.pop(r.rid, None)
                    self.kv.draft_store.pop(r.rid, None)
                r.cancelled = True
                r.state = ReqState.FINISHED
                r.finish_time = t
                r.prefill_cursor = 0
                self.sched.on_request_finish(r)
                self.live = [x for x in self.live if x is not r]
                self.stuck = False   # freed memory may unblock the rest
                if self.obs is not None:
                    self.obs.cancel(r, t)
                return True
        return False

    @property
    def pending(self) -> List[Request]:
        """Submitted-but-not-admitted requests (protocol view; the hot loop
        uses the cursor directly and never materializes this slice)."""
        return self._pending[self._pending_pos:]

    @property
    def has_work(self) -> bool:
        return self._pending_pos < len(self._pending) or bool(self.live)

    def hotpath_stats(self) -> dict:
        """Hot-path instrumentation (benchmarks/engine_hotpath.py)."""
        shapes = set(self._prefill.shapes_seen)
        if self.spec_k and self.draft.bucketed is not None:
            shapes |= self.draft.bucketed.shapes_seen
        return {
            "host_syncs": self.host_syncs,
            "dispatches": self.dispatches,
            "prefill_shapes": sorted(shapes),
            "prefill_compiles": len(shapes),
            "prefill_bucket_grid": list(self._prefill.buckets),
            "multi_step_blocks": self.multi_step_blocks,
            "multi_step_iters": self.multi_step_iters,
            "persistent_blocks": self.persistent_blocks,
            "persistent_iters": self.persistent_iters,
            "page_gathers": self.page_gathers,
            "page_scatters": self.page_scatters,
            "page_gather_bytes": self.page_gather_bytes,
        }

    # ---------------------------------------------------------------- clock
    def _tick(self, seconds: float) -> None:
        """Advance the clock by one modeled operation.

        Virtual: now += seconds (deterministic). Wall: the operation's
        *deadline* is now + seconds; sleep off whatever the host's real
        computation left of it, then stamp a real monotonic reading — so
        the engine is paced to the LatencyModel schedule but timestamps
        carry true wall jitter. A host slower than the schedule never
        sleeps and simply drifts late (the tolerance harness measures it).
        """
        if self.clock == "virtual":
            self.now += seconds
        else:
            deadline = self.now + seconds
            w = time.monotonic() - self._wall0
            if deadline > w:
                time.sleep(deadline - w)
                w = time.monotonic() - self._wall0
            self.now = w

    def wall_now(self) -> float:
        """Current time on this engine's clock for *external* events
        (arrival stamping by a live frontend): a fresh monotonic reading
        in wall mode, `self.now` in virtual mode (where time only exists
        between steps)."""
        if self.clock == "virtual":
            return self.now
        return time.monotonic() - self._wall0

    # -------------------------------------------------------------- prefill
    def _prompt_tokens(self, r: Request) -> np.ndarray:
        """The request's committed context: prompt (synthesized
        deterministically from the rid for token-less simulator-style
        requests) plus any generated prefix (recompute resume)."""
        if r.prompt_tokens is None:
            rng = np.random.default_rng(r.rid)
            r.prompt_tokens = rng.integers(
                0, self.model.cfg.vocab_size, r.prompt_len
            ).astype(np.int32)
        return np.concatenate([
            np.asarray(r.prompt_tokens, np.int32),
            np.asarray(r.output_tokens[: r.generated], np.int32),
        ])

    def _can_stage_prefill(self, r: Request) -> bool:
        """May this admission join the step's batched prefill? The staged
        flush defers only the first token's *value*; it must not be able
        to finish the request mid-admission (slot reuse), so EOS-enabled
        engines and single-token responses take the sequential path."""
        if not self.hotpath.prefill_buckets or not self._prefill_bucketable:
            return False
        return r.generated > 0 or (self.eos_id < 0 and r.output_len > 1)

    def _stage_prefill(self, r: Request) -> _StagedPrefill:
        """Host half of one admission: slot allocation, the prefill tick,
        and (for fresh requests) the first-token emission bookkeeping —
        everything the sequential path does except the token id itself,
        which `_flush_prefills` fills in after the batched device call.
        Clock/fluid/KV state is therefore bit-identical to sequential
        admission regardless of how many requests share the flush."""
        toks = self._prompt_tokens(r)
        slot = self.kv.allocate(r)
        self.slot_req[slot] = r
        self._tick(self.lat.prefill_latency(len(toks)))
        if self.obs is not None:
            self.obs.prefill(r, self.now, len(toks))
        emit_t = None
        if r.generated == 0:
            emit_t = self.now
            r.generated = 1
            r.emit_times.append(emit_t)
            self.fluid.emit(r.fluid_idx, emit_t, 1)
            self.kv.grow(r)
            self.total_tokens += 1
        frames = getattr(r, "frames", None) if self._prefill.enc_seq else None
        return _StagedPrefill(r, slot, toks, emit_t, frames)

    # ------------------------------------------------------ chunked prefill
    def _should_chunk(self, r: Request) -> bool:
        """Route this admission through chunked prefill? Only prompts
        longer than one chunk, and only when the staged machinery applies
        (the same exclusions as `_can_stage_prefill`: the final chunk's
        first token must not be able to finish the request mid-flush)."""
        return (self.prefill_chunk > 0
                and r.context_len > self.prefill_chunk
                and self._can_stage_prefill(r))

    def _stage_chunk(self, r: Request) -> _StagedPrefill:
        """Advance one chunked prefill by one chunk: commit up to
        `prefill_chunk` more context tokens, stage the device recompute
        of the prefix at the new cursor's bucket (the same jitted
        bucketed call the monolithic path makes — so the FINAL chunk,
        whose prefix is the whole prompt, is bit-identical to monolithic
        prefill), and tick the per-chunk cost. On the final chunk the
        first-token bookkeeping fires exactly as `_stage_prefill`'s."""
        toks = self._prompt_tokens(r)
        total = len(toks)
        if r.prefill_cursor == 0:                  # admission: first chunk
            slot = self.kv.allocate(r, tokens=0)
            self.slot_req[slot] = r
        else:
            slot = r.engine_slot
        step = min(self.prefill_chunk, total - r.prefill_cursor)
        r.prefill_cursor += step
        self.kv.grow(r, step)
        self._tick(self.lat.prefill_chunk_latency(step, r.prefill_cursor))
        if self.obs is not None:
            self.obs.prefill_chunk(r, self.now, r.prefill_cursor, total)
        prefix = toks[: r.prefill_cursor]
        emit_t = None
        if r.prefill_cursor >= total:              # final chunk
            r.prefill_cursor = 0
            if self.obs is not None:
                self.obs.prefill(r, self.now, total)
            if r.generated == 0:
                emit_t = self.now
                r.generated = 1
                r.emit_times.append(emit_t)
                self.fluid.emit(r.fluid_idx, emit_t, 1)
                self.kv.grow(r)
                self.total_tokens += 1
        frames = getattr(r, "frames", None) if self._prefill.enc_seq else None
        return _StagedPrefill(r, slot, prefix, emit_t, frames)

    def _flush_prefills(self, staged: List[_StagedPrefill]) -> None:
        """Run every staged admission's device work (the shared
        `BucketedPrefill.prefill_into` grouped flush). First-token
        emissions finalize in STAGED (admission) order, not group order,
        so event-sink consumers observe the same chronology the
        sequential path produces."""
        if not staged:
            return
        writer = None
        if self.physical_pages:
            # staging allocated/grew pages — pin the moved tables before
            # the grouped scatter lands in them
            self._refresh_block_tables()
            writer = self._paged_writer
        slots = [rec.slot for rec in staged]
        self.cache, first, syncs, n_groups = self._prefill.prefill_into(
            self.params, self.cache, slots,
            [rec.toks for rec in staged],
            [rec.frames for rec in staged],
            write=writer,
        )
        self._sync(syncs)
        self._dispatch("prefill", n_groups)
        self._dispatch("write", n_groups)
        if self.spec_k:
            # draft invariant: committed[:-1] — the full staged context
            # for fresh prefills (their first token was committed at
            # stage time), minus the trailing token on recompute resume
            n_draft = self.draft.prefill_batch(
                slots,
                [rec.toks if rec.emit_t is not None else rec.toks[:-1]
                 for rec in staged],
            )
            self._dispatch("draft_prefill", n_draft)
            self._dispatch("write", n_draft)
        obs = self.obs
        for i, rec in enumerate(staged):
            if rec.emit_t is not None:
                rec.req.output_tokens.append(int(first[i]))
                if obs is not None:
                    obs.emit(rec.req, rec.emit_t, 1)

    def _prefill_request(self, r: Request) -> None:
        """Run the prompt (plus any generated prefix on recompute) —
        the sequential path: one request, one prefill, one slot write.
        With the hot path enabled this is the staged machinery applied to
        a single request (same bucketed jitted call the batched flush
        makes, so sequential ≡ batched bit-for-bit); the legacy eager
        exact-length path survives underneath as the benchmark baseline."""
        if self.hotpath.prefill_buckets and self._prefill_bucketable:
            # batch-1 through the bucketed jitted path (the EOS and
            # single-token fallback — cases `_can_stage_prefill` excludes
            # from multi-request flushes; MoE never reaches here)
            rec = self._stage_prefill(r)
            self._flush_prefills([rec])
            if rec.emit_t is not None:
                # replay `_emit`'s done check, which the deferred-token
                # staging skips: the first token may finish the request
                tok = r.output_tokens[-1]
                if (r.generated >= r.output_len
                        or (self.eos_id >= 0 and tok == self.eos_id)):
                    self._finish(r)
            return
        toks = self._prompt_tokens(r)
        enc_seq = self.model.enc_seq(self.max_seq)
        kv_dtype = self.cache["k"].dtype if "k" in self.cache \
            else self.cache["ssm_conv"].dtype
        one = self.model.init_cache(
            1, self._cache_seq, enc_seq=enc_seq, dtype=kv_dtype
        )
        batch = {"tokens": jnp.asarray(toks)[None]}
        if self.model.cfg.kind in ("encdec", "audio"):
            frames = getattr(r, "frames", None)
            batch["frames"] = (jnp.asarray(frames)[None] if frames is not None
                               else jnp.zeros((1, enc_seq, self.model.cfg.d_model),
                                              jnp.float32))
        logits, one = self.model.prefill(self.params, batch, one)
        self._prefill.note_shape((1, len(toks)))        # exact-length compile
        self._dispatch("prefill")
        slot = self.kv.allocate(r)
        if self.physical_pages:
            if r.generated == 0:
                # own the page under position len(toks) now: the first
                # emitted token's KV never lands there, so the decode
                # window reads whatever this scatter leaves (zeros from
                # the scratch row — the contiguous path's content). The
                # emit below re-counts the token; grow is idempotent on
                # the already-taken page.
                self.kv.ensure_pages(r, len(toks) + 1)
            self._refresh_block_tables()
            self.page_scatters += 1
            self.cache = _paged_commit(
                self.cache, jnp.asarray(self._bt_host[[slot]]),
                jnp.zeros((1,), jnp.int32), one["k"], one["v"],
                jnp.asarray([len(toks) + 1], jnp.int32),
            )
        else:
            self.cache = _write_slot(self.cache, one, slot)
        self._dispatch("write")
        self.slot_req[slot] = r
        if self.spec_k:
            # the draft holds committed[:-1] (speculative.py invariant): on a
            # fresh prefill the first token is emitted just below, so `toks`
            # is already that prefix; on recompute-resume drop the last
            # committed token — it is the next proposal round's input.
            self.draft.prefill(slot, toks if r.generated == 0 else toks[:-1])
            self._dispatch("draft_prefill")
            self._dispatch("write")
        self._tick(self.lat.prefill_latency(len(toks)))
        if self.obs is not None:
            self.obs.prefill(r, self.now, len(toks))
        if r.generated == 0:
            tok = int(jnp.argmax(logits[0]))
            self._sync()
            self._emit(r, tok)

    # ---------------------------------------------------------------- emit
    def _emit(self, r: Request, tok: int) -> None:
        r.output_tokens.append(tok)
        r.generated += 1
        r.emit_times.append(self.now)
        self.fluid.emit(r.fluid_idx, self.now, 1)
        self.kv.grow(r)
        self.total_tokens += 1
        if self.obs is not None:
            self.obs.emit(r, self.now, 1)
        done = (r.generated >= r.output_len
                or (self.eos_id >= 0 and tok == self.eos_id))
        if done:
            self._finish(r)

    def _emit_burst(self, r: Request, toks) -> int:
        """Commit a verify step's accepted tokens: all visible at self.now
        (one burst — FluidQoE.emit with k>1; pace_delivery re-smooths it
        client-side). Truncates at output_len / EOS exactly where the
        one-token-per-step baseline would have stopped. Returns the number
        actually emitted."""
        emitted = []
        for tok in toks:
            if r.generated >= r.output_len:
                break
            tok = int(tok)
            emitted.append(tok)
            r.output_tokens.append(tok)
            r.generated += 1
            r.emit_times.append(self.now)
            if self.eos_id >= 0 and tok == self.eos_id:
                break
        if emitted:
            self.fluid.emit(r.fluid_idx, self.now, len(emitted))
            self.kv.grow(r, len(emitted))
            self.total_tokens += len(emitted)
            if self.obs is not None:
                self.obs.emit(r, self.now, len(emitted))
        done = (r.generated >= r.output_len
                or (self.eos_id >= 0 and emitted and
                    emitted[-1] == self.eos_id))
        if done:
            self._finish(r)
        return len(emitted)

    def _finish(self, r: Request) -> None:
        r.state = ReqState.FINISHED
        r.finish_time = self.now
        self.sched.on_request_finish(r)
        slot = r.engine_slot
        self.kv.release(r)
        self.slot_req.pop(slot, None)
        if self.obs is not None:
            self.obs.finish(r, self.now)

    # ------------------------------------------------------------ preempt
    def _preempt(self, r: Request) -> None:
        r.preemptions += 1
        self.preemptions += 1
        slot = r.engine_slot
        if self.preemption_mode == "swap":
            self._dispatch("read")
            if self.physical_pages:
                # gather the victim's pages into a contiguous host row —
                # identical leaf shapes/bytes to the `_read_slot` slice,
                # so swap accounting and the restore path are layout-blind
                self._refresh_block_tables()
                host_slice = jax.device_get(_paged_read_row(
                    self.cache, jnp.asarray(self._bt_host[[slot]]), slot,
                    max_seq=self._cache_seq))
                self.page_gathers += 1
                self.page_gather_bytes += sum(
                    v.nbytes for k, v in host_slice.items() if k != "length")
            else:
                host_slice = jax.device_get(_read_slot(self.cache, slot))
            self._sync()
            draft_slice = self.draft.park(slot) if self.spec_k else None
            self.kv.swap_out(r, host_slice, draft_slice)
            r.state = ReqState.SWAPPED
            # a mid-prefill victim only moves its committed prefix (the
            # cursor survives; chunking resumes after swap-in)
            self._tick(self.lat.swap_latency(
                r.prefill_cursor or r.context_len))
        else:
            self.kv.drop(r)
            r.state = ReqState.WAITING
            r.prefilled = False
            r.prefill_cursor = 0        # recompute rewinds the chunk cursor
        self.slot_req.pop(slot, None)
        self.sched.record_preemptions(1)
        if self.obs is not None:
            self.obs.preempt(r, self.now, self.preemption_mode)

    def _swap_in(self, r: Request) -> None:
        host_slice = self.kv.swap_in(r)
        draft_slice = self.kv.swap_in_draft(r)
        slot = self.kv.allocate(r, tokens=(r.prefill_cursor or None))
        if self.physical_pages:
            # scatter the parked contiguous row into the freshly allocated
            # pages; counts = the committed context (mid-chunk victims
            # restore their cursor's prefix), exactly the page coverage
            # `allocate` just took
            self._refresh_block_tables()
            self.page_scatters += 1
            self.cache = _paged_commit(
                self.cache, jnp.asarray(self._bt_host[[slot]]),
                jnp.zeros((1,), jnp.int32),
                jnp.asarray(host_slice["k"]), jnp.asarray(host_slice["v"]),
                jnp.asarray([r.prefill_cursor or r.context_len], jnp.int32),
            )
        else:
            self.cache = _write_slot(
                self.cache, jax.tree.map(jnp.asarray, host_slice), slot
            )
        self._dispatch("write")
        if draft_slice is not None:
            self.draft.restore(slot, draft_slice)
            self._dispatch("write")
        self.slot_req[slot] = r
        r.state = ReqState.RUNNING
        self._tick(self.lat.swap_latency(r.prefill_cursor or r.context_len))
        if self.obs is not None:
            self.obs.swap_in(r, self.now)

    # ------------------------------------------------------- speculative
    def _make_spec_fused(self):
        """One jitted dispatch for a whole speculative iteration: draft
        propose → window concat → target verify → greedy argmax → accepted
        prefix length (cumprod-of-matches scan) — all on device, so
        `_speculative_iteration` syncs exactly once and the transfer is
        three small int arrays instead of (slots, k+1, vocab) logits."""
        model, k = self.model, self.spec_k
        dmodel = self.draft.model

        def fn(params, dparams, tokens, target_cache, draft_cache):
            props, draft_cache = dmodel.propose_step(
                dparams, tokens, draft_cache, k
            )
            window = jnp.concatenate([tokens[:, None], props[:, :k]], axis=1)
            logits, target_cache = model.verify_step(
                params, window, target_cache
            )
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            match = (window[:, 1:] == greedy[:, :k]).astype(jnp.int32)
            accepted = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
            return window, greedy, accepted, target_cache, draft_cache

        return jax.jit(fn)

    def _make_spec_block(self):
        """`_make_spec_fused`'s round, folded into a device-resident
        `lax.while_loop` over `s` verify rounds (multi-step INSIDE
        speculation; s is loop data, bounded by the static buffer cap).
        Each round re-pins both caches' length gates exactly as the host
        does between single rounds — the target's valid prefix is the
        committed context, the draft holds committed[:-1] (speculative.py
        invariant) — then advances the committed length by accepted+1 and
        feeds the correction/bonus token to the next round's draft. The
        host replays the per-round windows off ONE sync."""
        model, k = self.model, self.spec_k
        dmodel = self.draft.model

        def fn(params, dparams, tokens, lengths, tcache, dcache, s, *,
               s_cap):
            b = tokens.shape[0]

            def cond(c):
                return c[0] < s

            def body(c):
                r, tok, ln, tc, dc, W, G, A = c
                dc = dict(dc, length=jnp.maximum(ln - 1, 0))
                tc = dict(tc, length=ln)
                props, dc = dmodel.propose_step(dparams, tok, dc, k)
                window = jnp.concatenate([tok[:, None], props[:, :k]],
                                         axis=1)
                logits, tc = model.verify_step(params, window, tc)
                greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                match = (window[:, 1:] == greedy[:, :k]).astype(jnp.int32)
                accepted = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                nxt = jnp.take_along_axis(greedy, accepted[:, None],
                                          axis=1)[:, 0]
                return (r + 1, nxt, ln + accepted + 1, tc, dc,
                        W.at[r].set(window), G.at[r].set(greedy),
                        A.at[r].set(accepted))

            carry = (jnp.asarray(0, jnp.int32), tokens, lengths, tcache,
                     dcache,
                     jnp.zeros((s_cap, b, k + 1), jnp.int32),
                     jnp.zeros((s_cap, b, k + 1), jnp.int32),
                     jnp.zeros((s_cap, b), jnp.int32))
            c = jax.lax.while_loop(cond, body, carry)
            return c[5], c[6], c[7], c[3], c[4]

        return jax.jit(fn, static_argnames=("s_cap",))

    def _spec_block_plan(self, active) -> int:
        """Rounds of speculative verify that may run unsupervised in one
        device dispatch — the decode `_multi_step_plan` adapted to an
        acceptance-dependent clock. A round commits 1..k+1 tokens per
        slot, so the `idle_steps` certificate is spent in TOKENS (one
        round consumes up to k+1 of them) and the block is sized so
        neither output_len nor max_seq can truncate mid-block: what gets
        committed then depends on acceptance alone (EOS still truncates —
        the replay discards the tail and the length gates roll both
        caches back). The arrival/`until` bound is NOT precomputed here:
        round ticks depend on accepted context, so the commit replay
        breaks at the first crossing instead. Returns 1 when any
        condition fails."""
        cap = self.hotpath.multi_step
        if cap <= 1 or not self.hotpath.persistent:
            return 1
        if not self.hotpath.fused_sampling:
            return 1
        if self.clock != "virtual" and not self.hotpath.wall_multi_step:
            return 1
        if len(active) != len(self.live):
            return 1
        if not self._rollback_ok:
            return 1                    # discarded tails need the gate
        k1 = self.spec_k + 1
        s_max = min(
            cap,
            min((r.output_len - r.generated) // k1
                for r in active.values()),
            min((self.max_seq - r.context_len) // k1
                for r in active.values()),
        )
        if s_max < 2:
            return 1
        # the acceptance-dependent clock, folded into the certificate:
        # `idle_steps` projects the latency trigger at the CURRENT
        # acceptance EMA, but commits inside the block move the EMA — so
        # re-check the trigger at the EMA floor (expected_step_tokens→1,
        # i.e. per-token latency = full iter latency), which dominates
        # every acceptance trajectory the block can observe
        stiffest = max((r.spec.tds for r in active.values()), default=0.0)
        if stiffest > 0 and \
                self.lat.iter_latency(len(self.live)) > 1.0 / stiffest:
            return 1
        s_tok = self.sched.idle_steps(self.live, s_max * k1 - 1) + 1
        s_max = min(s_max, s_tok // k1)
        return s_max if s_max >= 2 else 1

    def _speculative_block(self, active, lengths, tokens, s: int,
                           until: Optional[float]) -> int:
        """Run up to `s` speculative verify rounds in one device-resident
        while_loop dispatch and replay the acceptance-dependent clock on
        the host off ONE sync: round r's tick is priced at the context the
        ledger reached after round r-1's commits — exactly the sequence
        single-round stepping produces. Returns rounds committed (< s when
        an EOS landed, a pending arrival came due, or the driver's `until`
        was crossed: the tail is discarded and both length gates roll the
        caches back)."""
        k = self.spec_k
        draft_lengths = np.maximum(lengths - 1, 0).astype(np.int32)
        self.draft.cache = cache_lib.with_lengths(
            self.draft.cache, draft_lengths
        )
        W, G, A, self.cache, self.draft.cache = self._spec_block(
            self.params, self.draft.params, jnp.asarray(tokens),
            jnp.asarray(lengths), self.cache, self.draft.cache,
            jnp.int32(s), s_cap=self.hotpath.multi_step)
        self._dispatch("spec_block")
        W, G, A = jax.device_get((W, G, A))     # ONE sync for s rounds
        self._sync()
        self.multi_step_blocks += 1
        self.persistent_blocks += 1
        items = list(active.items())
        b = len(items)
        committed = 0
        for rnd in range(s):
            if rnd:
                self.batch_sizes.append(b)
            ctx = sum(r.context_len for _slot, r in items)
            self._tick(self.lat.iter_latency(b, ctx))
            step_accepted = 0
            finished = False
            for slot, r in items:
                d, g = W[rnd, slot, 1:], G[rnd, slot]
                a = int(A[rnd, slot])
                m_safe = max(1, self.max_seq - r.context_len)
                toks = (list(d[:a]) + [int(g[a])])[:m_safe]
                self.spec_steps += 1
                self.spec_proposed += k
                self.spec_accepted += a
                step_accepted += a
                if hasattr(self.lat, "observe_acceptance"):
                    self.lat.observe_acceptance(a)
                self._emit_burst(r, toks)
                finished = finished or not r.is_live
            if self.obs is not None:
                self.obs.spec(self.now, k * b, step_accepted)
            committed += 1
            if committed < s:
                if finished:
                    break   # batch composition changes next round
                if (self._pending_pos < len(self._pending)
                        and self._pending[self._pending_pos].arrival
                        <= self.now):
                    break   # an arrival is waiting — the scheduler must
                            # see it at this iteration boundary
                if until is not None and not (self.now < until):
                    break   # incremental driver regains control
        self.multi_step_iters += committed
        self.persistent_iters += s
        self.sched.skip_iterations(committed - 1)
        if self.obs is not None:
            self.obs.multi_step(self.now, s, committed)
            self.obs.persistent_loop(self.now, s, s)
        return committed

    def _speculative_iteration(self, active, lengths, tokens,
                               total_ctx: int) -> None:
        """Draft-propose k tokens per running slot, verify the whole window
        in one target pass, commit the longest greedy-matching prefix plus
        the correction/bonus token (lossless; 1..k+1 tokens per step)."""
        k = self.spec_k
        # draft cache holds committed[:-1]; its next write goes one position
        # below the target's (speculative.py invariant)
        draft_lengths = np.maximum(lengths - 1, 0).astype(np.int32)
        if self.hotpath.fused_sampling:
            self.draft.cache = cache_lib.with_lengths(
                self.draft.cache, draft_lengths
            )
            window, greedy, accepted, self.cache, self.draft.cache = \
                self._spec_fused(self.params, self.draft.params,
                                 jnp.asarray(tokens), self.cache,
                                 self.draft.cache)
            self._dispatch("spec_fused")
            self._tick(self.lat.iter_latency(len(active), total_ctx))
            window, greedy, accepted = jax.device_get(
                (window, greedy, accepted)
            )
            self._sync()
        else:
            proposals = self.draft.propose(tokens, draft_lengths, k)
            self._dispatch("propose")
            self._sync()
            window = np.concatenate([tokens[:, None], proposals], axis=1)
            logits, self.cache = self._verify(
                self.params, jnp.asarray(window), self.cache
            )
            self._dispatch("verify")
            # one step's cost: k+1 draft decodes + the fused verify (the
            # SpeculativeLatencyModel's iter_latency — same call as baseline)
            self._tick(self.lat.iter_latency(len(active), total_ctx))
            greedy = np.asarray(jnp.argmax(logits, axis=-1))  # (slots, k+1)
            self._sync()
            accepted = None
        step_accepted = 0
        for s, r in list(active.items()):
            d, g = window[s, 1:], greedy[s]
            if accepted is not None:
                a = int(accepted[s])
            else:
                a = 0
                while a < k and d[a] == g[a]:
                    a += 1
            # logical max_seq bound: the cache slack (_cache_seq) makes
            # every window position's logits well-defined, but committed
            # context must never exceed what a baseline engine could hold
            m_safe = max(1, self.max_seq - int(lengths[s]))
            toks = (list(d[:a]) + [int(g[a])])[:m_safe]
            self.spec_steps += 1
            self.spec_proposed += k
            self.spec_accepted += a
            step_accepted += a
            if hasattr(self.lat, "observe_acceptance"):
                self.lat.observe_acceptance(a)
            self._emit_burst(r, toks)
        if self.obs is not None:
            self.obs.spec(self.now, k * len(active), step_accepted)

    def spec_stats(self) -> dict:
        """Acceptance-side counters (speculative engines only)."""
        return {
            "spec_k": self.spec_k,
            "spec_steps": self.spec_steps,
            "proposed": self.spec_proposed,
            "accepted": self.spec_accepted,
            "acceptance_rate": (self.spec_accepted / self.spec_proposed
                                if self.spec_proposed else 0.0),
        }

    # ------------------------------------------------------ multi-step decode
    def _multi_step_plan(self, active, total_ctx: int,
                         until: Optional[float]) -> int:
        """Largest j (quantized to a power of two) for which running j
        decode iterations in one fused scan is *provably* bit-identical to
        single-stepping — see the module docstring for the full invariant.
        Returns 1 whenever any condition fails."""
        cap = self.hotpath.multi_step
        if cap <= 1 or self.spec_k:
            return 1
        if self.clock != "virtual" and not (
                self.hotpath.wall_multi_step and self._rollback_ok):
            # wall-clock engines may fuse only when a mid-block arrival
            # can be honored by rolling back the uncommitted tail —
            # length-gated caches only (timestamps are tolerance-gated;
            # token ids stay exact either way: greedy decode rows are
            # batch-independent)
            return 1
        if len(active) != len(self.live):
            return 1                    # a waiting/swapped request needs
                                        # the per-iteration scheduler
        if self.eos_id >= 0 and not self._rollback_ok:
            return 1                    # overshoot would be unrecoverable
        margin = min(r.output_len - r.generated for r in active.values())
        j_max = min(cap, margin)
        if j_max < 2:
            return 1
        j_max = min(j_max, self.sched.idle_steps(self.live, j_max - 1) + 1)
        if j_max < 2:
            return 1
        # arrival/driver bound: every INTERMEDIATE step end must stay
        # strictly before the next pending arrival and the driver's
        # `until`, so admission lands at the same iteration boundary as
        # single-stepping (the block's last step may cross — that is
        # exactly the crossing iteration the baseline runs)
        bound = np.inf
        if self._pending_pos < len(self._pending):
            bound = self._pending[self._pending_pos].arrival
        if until is not None:
            bound = min(bound, until)
        j = 1
        if bound != np.inf:
            t = self.now
            ticks = self.lat.iter_latency_schedule(
                len(active), total_ctx, j_max
            )
            while j < j_max:
                t = t + ticks[j - 1]                    # end of step j
                if not (t < bound):
                    break
                j += 1
        else:
            j = j_max
        if j < 2:
            return 1
        if self.hotpath.persistent:
            # the device while_loop takes j as DATA — no compile grid, so
            # the certificate is spent at full, unquantized resolution
            return j
        return 1 << (j.bit_length() - 1)        # pow-2 compile grid

    def _commit_block(self, active, ids, total_ctx: int, j: int) -> int:
        """Replay a fused block's per-step bookkeeping exactly as the
        one-step loop performs it (same `iter_latency` tick sequence —
        context grows by B per step — same per-slot emit order). Returns
        iterations committed (< j when an EOS landed mid-block — the
        remainder is discarded and the length gate rolls the cache back —
        or, on a wall clock, when a pending arrival came due mid-block:
        the tail is dropped the same way so admission lands at the next
        iteration boundary)."""
        items = list(active.items())
        b = len(items)
        ticks = self.lat.iter_latency_schedule(b, total_ctx, j)
        committed = 0
        for s in range(j):
            if s:
                self.batch_sizes.append(b)
            self._tick(ticks[s])
            finished = False
            for slot, r in items:
                self._emit(r, int(ids[s, slot]))
                finished = finished or not r.is_live
            committed += 1
            if committed < j:
                if finished:
                    break   # batch composition changes next iteration;
                            # drop the overshoot (length-gate rollback)
                if (self.clock != "virtual"
                        and self._pending_pos < len(self._pending)
                        and self._pending[self._pending_pos].arrival
                        <= self.now):
                    break   # wall mode: an arrival is waiting — stop the
                            # block so the scheduler sees it now
        return committed

    def _multi_step_decode(self, active, tokens, total_ctx: int,
                           j: int) -> int:
        """Run j fused decode iterations (static-j scan) and commit with
        `_commit_block` — ONE device→host sync for the whole block."""
        ids, self.cache = self._decode_multi(
            self.params, jnp.asarray(tokens), self.cache, j=j
        )
        self._dispatch("decode_multi")
        ids = np.asarray(ids)                   # ONE sync for j iterations
        self._sync()
        self.multi_step_blocks += 1
        committed = self._commit_block(active, ids, total_ctx, j)
        self.multi_step_iters += committed
        self.sched.skip_iterations(committed - 1)
        if self.obs is not None:
            self.obs.multi_step(self.now, j, committed)
        return committed

    def _persistent_decode(self, active, tokens, total_ctx: int,
                           j: int) -> int:
        """Run up to j decode iterations in the device-resident
        `lax.while_loop` (models/model.py `decode_persistent`): j is data,
        not a compile-time constant, and EOS-enabled engines stop the
        device early once every active row has emitted its EOS. The
        scheduler's `idle_steps` certificate (core/policies/base.py) is
        what makes running that long unsupervised legal; the commit
        replay is the same `_commit_block` the scan path uses, so the
        persistent path inherits every bit-identity the scan proved."""
        act = np.zeros(self.kv.num_slots, bool)
        for s in active:
            act[s] = True
        ids, self.cache, steps = self._decode_persist(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.int32(j), jnp.asarray(act),
            j_cap=self.hotpath.multi_step, eos_id=self.eos_id,
        )
        self._dispatch("decode_persistent")
        ids, steps = jax.device_get((ids, steps))   # ONE sync for the block
        self._sync()
        self.multi_step_blocks += 1
        self.persistent_blocks += 1
        committed = self._commit_block(active, np.asarray(ids),
                                       total_ctx, j)
        self.multi_step_iters += committed
        self.persistent_iters += int(steps)
        self.sched.skip_iterations(committed - 1)
        if self.obs is not None:
            self.obs.multi_step(self.now, j, committed)
            self.obs.persistent_loop(self.now, j, int(steps))
        return committed

    # ----------------------------------------------------------- main loop
    def _admit_arrivals(self) -> None:
        pend = self._pending
        pos = self._pending_pos
        obs = self.obs
        while pos < len(pend) and pend[pos].arrival <= self.now:
            r = pend[pos]
            pos += 1
            r.fluid_idx = self.fluid.add(r.arrival, r.spec)
            r.state = ReqState.WAITING
            self.live.append(r)
            self.sched.on_request_arrival(r)
            if obs is not None:
                obs.admit(r, self.now)
        self._pending_pos = pos
        # amortized compaction: drop the consumed prefix once it dominates
        if pos and pos * 2 >= len(pend):
            del pend[:pos]
            self._pending_pos = 0

    def step(self, until: Optional[float] = None) -> bool:
        """One continuous-batching iteration (schedule → preempt →
        swap-in/prefill → decode over all occupied slots). Returns False
        when there is nothing left to do.

        `until`: incremental drivers that will submit more work once the
        clock reaches t (Replica.advance_to) pass it so the multi-step
        fast path never skips past t inside one block — the clock then
        crosses t at the same single iteration it would when
        single-stepping, keeping routed-engine timelines bit-identical to
        submit-everything-upfront runs. Single-step behavior is unaffected
        (iterations are indivisible; the crossing step still overshoots)."""
        if self.stuck or not self.has_work:
            return False
        if not self.live and self._pending_pos < len(self._pending):
            nxt = self._pending[self._pending_pos].arrival
            if self.clock != "virtual" and nxt > self.now:
                # an idle wall-clock engine waits out the gap for real
                # (the virtual clock jumps it); re-read after the sleep so
                # the admission timestamp is a genuine reading
                w = time.monotonic() - self._wall0
                if nxt > w:
                    time.sleep(nxt - w)
                self.now = max(self.now, time.monotonic() - self._wall0)
            else:
                self.now = max(self.now, nxt)
        self._admit_arrivals()
        if not self.live:
            return True

        target = self.sched.schedule(self.now, self.live, self.fluid)
        target_ids = {id(r) for r in target}

        n_preempted = 0
        for r in list(self.slot_req.values()):
            if id(r) not in target_ids and r.state == ReqState.RUNNING:
                self._preempt(r)
                n_preempted += 1
        n_admitted = 0
        staged: List[_StagedPrefill] = []
        for r in target:
            if r.state == ReqState.SWAPPED and self.kv.can_allocate(
                    r, tokens=(r.prefill_cursor or None)):
                self._swap_in(r)
                n_admitted += 1
            elif r.state == ReqState.RUNNING and r.prefill_cursor:
                # chunked prefill in flight: the resident advances one
                # chunk per scheduled iteration, interleaved with every
                # other slot's decode tick (it joins the decode batch
                # only once the cursor completes)
                staged.append(self._stage_chunk(r))
                n_admitted += 1
            elif r.state == ReqState.WAITING:
                if self._should_chunk(r):
                    # finer-grained admission: only the first chunk's
                    # tokens (pages) need to fit right now
                    if self.kv.can_allocate(r, tokens=self.prefill_chunk):
                        r.state = ReqState.RUNNING
                        r.prefilled = True
                        staged.append(self._stage_chunk(r))
                        n_admitted += 1
                elif self.kv.can_allocate(r):
                    r.state = ReqState.RUNNING
                    r.prefilled = True
                    if self._can_stage_prefill(r):
                        staged.append(self._stage_prefill(r))
                    else:
                        # a sequential prefill fires its emit (and possibly
                        # finish) events inline — flush what is staged first
                        # so event-sink chronology matches the sequential
                        # path (earlier admissions report first)
                        self._flush_prefills(staged)
                        staged = []
                        self._prefill_request(r)
                    n_admitted += 1
        self._flush_prefills(staged)

        # ---- decode over all occupied slots ---------------------------
        active = {s: r for s, r in self.slot_req.items()
                  if r.state == ReqState.RUNNING and not r.prefill_cursor}
        self.batch_sizes.append(len(active))
        committed_iters = 1
        if active:
            lengths = np.zeros(self.kv.num_slots, np.int32)
            tokens = np.zeros(self.kv.num_slots, np.int32)
            for s, r in active.items():
                lengths[s] = r.context_len
                tokens[s] = r.output_tokens[-1] if r.output_tokens else 0
            self.cache = cache_lib.with_lengths(self.cache, lengths)
            total_ctx = int(lengths.sum())
            if self.spec_k:
                s_rounds = self._spec_block_plan(active)
                if s_rounds > 1:
                    committed_iters = self._speculative_block(
                        active, lengths, tokens, s_rounds, until
                    )
                else:
                    self._speculative_iteration(active, lengths, tokens,
                                                total_ctx)
            else:
                j = self._multi_step_plan(active, total_ctx, until)
                if self.physical_pages:
                    # pre-reserve every page the block will write (decode
                    # step s writes position ctx+s): no host round-trip
                    # can grow a table mid-block, so the whole block's
                    # coverage must exist before dispatch. The scheduler's
                    # paged idle_steps projection certified the demand
                    # fits the pool. Pin the tables after.
                    for _s, r in active.items():
                        self.kv.ensure_pages(
                            r, min(r.context_len + j, self._cache_seq))
                    self._refresh_block_tables()
                if j > 1:
                    if self.hotpath.persistent:
                        committed_iters = self._persistent_decode(
                            active, tokens, total_ctx, j
                        )
                    else:
                        committed_iters = self._multi_step_decode(
                            active, tokens, total_ctx, j
                        )
                    if self.physical_pages:
                        # EOS truncation / mid-block break may leave pages
                        # reserved past the committed context — return
                        # them to the pool (admission capacity is real now)
                        for r in list(active.values()):
                            if r.is_live:
                                self.kv.trim_pages(r)
                elif self.hotpath.fused_sampling:
                    ids, self.cache = self._decode_tok(
                        self.params, jnp.asarray(tokens), self.cache
                    )
                    self._dispatch("decode")
                    self._tick(self.lat.iter_latency(len(active), total_ctx))
                    nxt = np.asarray(ids)
                    self._sync()
                    for s, r in list(active.items()):
                        self._emit(r, int(nxt[s]))
                else:
                    logits, self.cache = self._decode(
                        self.params, jnp.asarray(tokens), self.cache
                    )
                    self._dispatch("decode")
                    self._tick(self.lat.iter_latency(len(active), total_ctx))
                    nxt = np.asarray(jnp.argmax(logits, axis=-1))
                    self._sync()
                    for s, r in list(active.items()):
                        self._emit(r, int(nxt[s]))
        else:
            self._tick(self.lat.hw.overhead)

        self.iterations += committed_iters
        self.live = [r for r in self.live if r.is_live]
        n_live = len(self.live)
        self._admit_arrivals()
        newly_arrived = len(self.live) > n_live

        # ---- deadlock guard -------------------------------------------
        # Nothing decoded, admitted, preempted, or newly arrived (the
        # overhead tick can advance the clock past a pending arrival),
        # and no future arrival can change the picture: every live
        # request is permanently unschedulable (e.g. prompt larger than
        # KV capacity). The legacy loop spun on overhead ticks until
        # max_iterations; the steppable engine halts so unbounded drivers
        # (cluster drain) terminate. With arrivals still pending the
        # clock keeps advancing by the overhead tick exactly as the
        # legacy loop did, preserving bit-for-bit admission times.
        if not active and not n_admitted and not n_preempted \
                and not newly_arrived \
                and self._pending_pos >= len(self._pending):
            self.stuck = True                # a later submit() may clear it
            return False
        return True

    def result(self) -> SimResult:
        return SimResult(
            requests=list(self.seen),
            makespan=self.now,
            total_tokens=self.total_tokens,
            preemptions=self.preemptions,
            iterations=self.iterations,
            batch_sizes=self.batch_sizes,
        )

    def run(self, workload: List[Request], max_iterations: int = 100_000):
        """Serve the workload to completion. Returns the finished requests.

        A thin loop over step(): reset + submit all + iterate until
        drained — the same batch semantics as ServingSimulator.run (on a
        fresh engine the reset is a no-op, so this still reproduces the
        pre-refactor monolithic loop bit-for-bit; the differential oracle
        lives in tests/test_engine_steppable.py)."""
        self.reset()
        for r in sorted(workload, key=lambda r: r.arrival):
            self.submit(r)
        while self.iterations < max_iterations:
            if not self.step():
                break
        return workload
