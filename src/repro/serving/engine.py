"""Real continuous-batching engine: runs an actual JAX model on device.

This is the integration target for the Andes scheduler — the same
Scheduler/FluidQoE/Request machinery as the simulator, but every decode
iteration executes the model's jitted ``decode_step`` against a static-slot
KV cache, prefills run the real prompt, preemption really moves cache
slices to host numpy (swap) or re-prefills (recompute), and tokens are
greedily sampled.

The clock is virtual by default (advanced by the LatencyModel per step) so
QoE specs in seconds are meaningful on a CPU container and tests are
deterministic; ``clock="wall"`` uses wall time on real hardware.

The engine also serves as the oracle for validating the simulator
(tests/test_sim_vs_engine.py): same scheduler, same workload, same latency
model ⇒ near-identical scheduling traces.

Like the simulator, the engine is *steppable*: ``submit()`` enqueues
arrivals, ``step()`` executes one continuous-batching iteration (schedule
→ preempt → swap-in/prefill → one real decode), and ``result()``
snapshots a SimResult. ``run()`` is a thin loop over ``step()`` that
reproduces the pre-refactor batch loop bit-for-bit
(tests/test_engine_steppable.py holds a transcription of the legacy loop
as the differential oracle). This makes ServingEngine satisfy
``repro.cluster.replica.SteppableBackend`` verbatim, so real-model
replicas plug into the cluster layer unchanged.

Speculative decoding (``draft_model``/``spec_k``): each scheduled step a
small draft model greedily proposes ``k`` tokens per running request
(serving/speculative.py), the target verifies the whole window in one
``verify_step`` call, and the longest prefix matching the target's own
greedy argmax is committed plus the correction/bonus token — so every
request's emitted token sequence is *identical* to the non-speculative
engine's (lossless by construction; tests/test_speculative.py asserts it
trace-for-trace) while decode steps shrink by the acceptance factor. A
step emits a 1..k+1 token burst at one timestamp; FluidQoE.emit absorbs
it and the client-side pace_delivery smooths it back to the spec'd TDS,
which is precisely the paper's QoE machinery rewarding burst delivery.
"""
from __future__ import annotations

import bisect
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.qoe import FluidQoE
from repro.core.scheduler import Scheduler
from repro.models import cache as cache_lib
from repro.models.model import Model
from repro.serving.kv_manager import KVSlotManager
from repro.core.request import Request, ReqState
from repro.serving.simulator import SimResult
from repro.serving.speculative import DraftProposer, check_speculation_compatible


def _slot_axis(leaf_ndim: int) -> int:
    return 0 if leaf_ndim == 1 else 1   # length (B,) vs (L, B, ...)


@functools.partial(jax.jit, static_argnames=("slot",))
def _write_slot(cache, src, slot):
    """Insert batch-1 `src` pytree into `cache` at batch slot `slot`."""
    def ins(c, s):
        ax = _slot_axis(c.ndim)
        idx = [slice(None)] * c.ndim
        idx[ax] = slot
        return c.at[tuple(idx)].set(jnp.squeeze(s, ax).astype(c.dtype))
    return jax.tree.map(ins, cache, src)


@functools.partial(jax.jit, static_argnames=("slot",))
def _read_slot(cache, slot):
    def rd(c):
        ax = _slot_axis(c.ndim)
        return jax.lax.index_in_dim(c, slot, ax, keepdims=True)
    return jax.tree.map(rd, cache)


class ServingEngine:
    """Real continuous-batching engine over a jitted JAX model.

    Incremental API (used by the cluster layer's `Replica`, identical to
    ServingSimulator's):
      submit(req)  enqueue an arrival (any time, in any order)
      step()       one scheduling+decode iteration; False when out of work
      has_work     pending or live requests remain
      result()     SimResult over every request ever submitted

    Batch API (classic single-node experiments):
      run(workload)  submit all + step to completion
    """

    def __init__(
        self,
        model: Model,
        params,
        scheduler: Scheduler,
        lat: LatencyModel,
        *,
        num_slots: int = 8,
        max_seq: int = 256,
        capacity_tokens: Optional[int] = None,
        preemption_mode: str = "swap",
        clock: str = "virtual",
        eos_id: int = -1,
        cache_dtype=jnp.float32,
        draft_model: Optional[Model] = None,
        draft_params=None,
        spec_k: int = 0,
    ):
        self.model = model
        self.params = params
        self.sched = scheduler
        self.lat = lat
        self.preemption_mode = preemption_mode
        self.clock = clock
        self.eos_id = eos_id
        # optional lifecycle-event sink (repro.api): called as
        # sink(kind, request, t, k), kind in {"emit","preempt","finish"};
        # survives reset() so run() keeps reporting to an installed client
        self.event_sink = None
        self.max_seq = max_seq
        self._num_slots = num_slots
        self._capacity_tokens = capacity_tokens

        # ---- speculative decoding (optional) --------------------------
        self.spec_k = int(spec_k)
        # a verify window writes up to k+1 positions past a slot's
        # committed context; pad the *physical* cache by that much so the
        # writes never hit the dynamic_update_slice index clamp (which
        # would silently corrupt position max_seq-1 for requests ending
        # within k tokens of the boundary — breaking the lossless gate).
        # max_seq stays the logical per-request bound: emission is capped
        # at it and KV accounting never counts the slack.
        self._cache_seq = max_seq + (self.spec_k + 1 if self.spec_k else 0)
        if self.spec_k:
            if draft_model is None or draft_params is None:
                raise ValueError("spec_k > 0 requires draft_model/draft_params")
            check_speculation_compatible(model, draft_model)
            self.draft = DraftProposer(
                draft_model, draft_params, num_slots=num_slots,
                max_seq=self._cache_seq, cache_dtype=cache_dtype,
            )
            self._verify = jax.jit(model.verify_step)
        else:
            self.draft = None

        enc_seq = max_seq // 4 if model.cfg.kind in ("encdec", "audio") else 0
        self.cache = model.init_cache(
            num_slots, self._cache_seq, enc_seq=enc_seq, dtype=cache_dtype
        )
        self._decode = jax.jit(model.decode_step)
        self.reset()

    # ------------------------------------------------------------------ state
    def reset(self) -> None:
        """Clear all serving state (the device cache pytree is reused; live
        slots are always re-written at prefill/swap-in time)."""
        self.kv = KVSlotManager(self._num_slots, self.max_seq,
                                self._capacity_tokens,
                                burst_reserve=(self.spec_k + 1
                                               if self.spec_k else 0))
        self.fluid = FluidQoE()
        self.spec_steps = 0          # verify iterations executed
        self.spec_proposed = 0       # draft tokens proposed per verify (k each)
        self.spec_accepted = 0       # draft tokens accepted by the target
        if hasattr(self.lat, "reset"):
            self.lat.reset()         # speculative acceptance EMA -> prior
        self.now = 0.0
        self.slot_req: Dict[int, Request] = {}
        self.preemptions = 0
        self.total_tokens = 0
        self.iterations = 0
        self.batch_sizes: List[int] = []
        self.pending: List[Request] = []     # submitted, not yet admitted
        self.live: List[Request] = []
        self.seen: List[Request] = []        # submit order
        self.stuck = False                   # deadlocked (cleared by submit)
        self._wall0 = time.monotonic()

    def submit(self, req: Request) -> None:
        """Enqueue an arrival. Stable insert keeps equal-arrival order."""
        bisect.insort(self.pending, req, key=lambda r: r.arrival)
        self.seen.append(req)
        # a new arrival may change the scheduler's choice even if the
        # current live set deadlocked — try again
        self.stuck = False

    @property
    def has_work(self) -> bool:
        return bool(self.pending or self.live)

    # ---------------------------------------------------------------- clock
    def _tick(self, seconds: float) -> None:
        if self.clock == "virtual":
            self.now += seconds
        else:
            self.now = time.monotonic() - self._wall0

    # -------------------------------------------------------------- prefill
    def _prefill_request(self, r: Request) -> None:
        """Run the prompt (plus any generated prefix on recompute)."""
        if r.prompt_tokens is None:
            # simulator-style request (length only, no token ids) — e.g.
            # routed by the cluster layer from a synthetic trace. Derive a
            # deterministic prompt from the rid so reruns are reproducible.
            rng = np.random.default_rng(r.rid)
            r.prompt_tokens = rng.integers(
                0, self.model.cfg.vocab_size, r.prompt_len
            ).astype(np.int32)
        toks = np.concatenate([
            np.asarray(r.prompt_tokens, np.int32),
            np.asarray(r.output_tokens[: r.generated], np.int32),
        ])
        enc_seq = self.max_seq // 4 if self.model.cfg.kind in ("encdec", "audio") else 0
        kv_dtype = self.cache["k"].dtype if "k" in self.cache \
            else self.cache["ssm_conv"].dtype
        one = self.model.init_cache(
            1, self._cache_seq, enc_seq=enc_seq, dtype=kv_dtype
        )
        batch = {"tokens": jnp.asarray(toks)[None]}
        if self.model.cfg.kind in ("encdec", "audio"):
            frames = getattr(r, "frames", None)
            batch["frames"] = (jnp.asarray(frames)[None] if frames is not None
                               else jnp.zeros((1, enc_seq, self.model.cfg.d_model),
                                              jnp.float32))
        logits, one = self.model.prefill(self.params, batch, one)
        slot = self.kv.allocate(r)
        self.cache = _write_slot(self.cache, one, slot)
        self.slot_req[slot] = r
        if self.spec_k:
            # the draft holds committed[:-1] (speculative.py invariant): on a
            # fresh prefill the first token is emitted just below, so `toks`
            # is already that prefix; on recompute-resume drop the last
            # committed token — it is the next proposal round's input.
            self.draft.prefill(slot, toks if r.generated == 0 else toks[:-1])
        self._tick(self.lat.prefill_latency(len(toks)))
        if r.generated == 0:
            tok = int(jnp.argmax(logits[0]))
            self._emit(r, tok)

    # ---------------------------------------------------------------- emit
    def _emit(self, r: Request, tok: int) -> None:
        r.output_tokens.append(tok)
        r.generated += 1
        r.emit_times.append(self.now)
        self.fluid.emit(r.fluid_idx, self.now, 1)
        self.kv.grow(r)
        self.total_tokens += 1
        if self.event_sink is not None:
            self.event_sink("emit", r, self.now, 1)
        done = (r.generated >= r.output_len
                or (self.eos_id >= 0 and tok == self.eos_id))
        if done:
            self._finish(r)

    def _emit_burst(self, r: Request, toks) -> int:
        """Commit a verify step's accepted tokens: all visible at self.now
        (one burst — FluidQoE.emit with k>1; pace_delivery re-smooths it
        client-side). Truncates at output_len / EOS exactly where the
        one-token-per-step baseline would have stopped. Returns the number
        actually emitted."""
        emitted = []
        for tok in toks:
            if r.generated >= r.output_len:
                break
            tok = int(tok)
            emitted.append(tok)
            r.output_tokens.append(tok)
            r.generated += 1
            r.emit_times.append(self.now)
            if self.eos_id >= 0 and tok == self.eos_id:
                break
        if emitted:
            self.fluid.emit(r.fluid_idx, self.now, len(emitted))
            self.kv.grow(r, len(emitted))
            self.total_tokens += len(emitted)
            if self.event_sink is not None:
                self.event_sink("emit", r, self.now, len(emitted))
        done = (r.generated >= r.output_len
                or (self.eos_id >= 0 and emitted and
                    emitted[-1] == self.eos_id))
        if done:
            self._finish(r)
        return len(emitted)

    def _finish(self, r: Request) -> None:
        r.state = ReqState.FINISHED
        r.finish_time = self.now
        self.sched.on_request_finish(r)
        slot = r.engine_slot
        self.kv.release(r)
        self.slot_req.pop(slot, None)
        if self.event_sink is not None:
            self.event_sink("finish", r, self.now, 0)

    # ------------------------------------------------------------ preempt
    def _preempt(self, r: Request) -> None:
        r.preemptions += 1
        self.preemptions += 1
        slot = r.engine_slot
        if self.preemption_mode == "swap":
            host_slice = jax.device_get(_read_slot(self.cache, slot))
            draft_slice = self.draft.park(slot) if self.spec_k else None
            self.kv.swap_out(r, host_slice, draft_slice)
            r.state = ReqState.SWAPPED
            self._tick(self.lat.swap_latency(r.context_len))
        else:
            self.kv.drop(r)
            r.state = ReqState.WAITING
            r.prefilled = False
        self.slot_req.pop(slot, None)
        self.sched.record_preemptions(1)
        if self.event_sink is not None:
            self.event_sink("preempt", r, self.now, 0)

    def _swap_in(self, r: Request) -> None:
        host_slice = self.kv.swap_in(r)
        draft_slice = self.kv.swap_in_draft(r)
        slot = self.kv.allocate(r)
        self.cache = _write_slot(
            self.cache, jax.tree.map(jnp.asarray, host_slice), slot
        )
        if draft_slice is not None:
            self.draft.restore(slot, draft_slice)
        self.slot_req[slot] = r
        r.state = ReqState.RUNNING
        self._tick(self.lat.swap_latency(r.context_len))

    # ------------------------------------------------------- speculative
    def _speculative_iteration(self, active, lengths, tokens,
                               total_ctx: int) -> None:
        """Draft-propose k tokens per running slot, verify the whole window
        in one target pass, commit the longest greedy-matching prefix plus
        the correction/bonus token (lossless; 1..k+1 tokens per step)."""
        k = self.spec_k
        # draft cache holds committed[:-1]; its next write goes one position
        # below the target's (speculative.py invariant)
        draft_lengths = np.maximum(lengths - 1, 0).astype(np.int32)
        proposals = self.draft.propose(tokens, draft_lengths, k)
        window = np.concatenate([tokens[:, None], proposals], axis=1)
        logits, self.cache = self._verify(
            self.params, jnp.asarray(window), self.cache
        )
        # one step's cost: k+1 draft decodes + the fused verify (the
        # SpeculativeLatencyModel's iter_latency — same call as baseline)
        self._tick(self.lat.iter_latency(len(active), total_ctx))
        greedy = np.asarray(jnp.argmax(logits, axis=-1))    # (slots, k+1)
        for s, r in list(active.items()):
            d, g = window[s, 1:], greedy[s]
            a = 0
            while a < k and d[a] == g[a]:
                a += 1
            # logical max_seq bound: the cache slack (_cache_seq) makes
            # every window position's logits well-defined, but committed
            # context must never exceed what a baseline engine could hold
            m_safe = max(1, self.max_seq - int(lengths[s]))
            toks = (list(d[:a]) + [int(g[a])])[:m_safe]
            self.spec_steps += 1
            self.spec_proposed += k
            self.spec_accepted += a
            if hasattr(self.lat, "observe_acceptance"):
                self.lat.observe_acceptance(a)
            self._emit_burst(r, toks)

    def spec_stats(self) -> dict:
        """Acceptance-side counters (speculative engines only)."""
        return {
            "spec_k": self.spec_k,
            "spec_steps": self.spec_steps,
            "proposed": self.spec_proposed,
            "accepted": self.spec_accepted,
            "acceptance_rate": (self.spec_accepted / self.spec_proposed
                                if self.spec_proposed else 0.0),
        }

    # ----------------------------------------------------------- main loop
    def _admit_arrivals(self) -> None:
        while self.pending and self.pending[0].arrival <= self.now:
            r = self.pending.pop(0)
            r.fluid_idx = self.fluid.add(r.arrival, r.spec)
            r.state = ReqState.WAITING
            self.live.append(r)
            self.sched.on_request_arrival(r)

    def step(self) -> bool:
        """One continuous-batching iteration (schedule → preempt →
        swap-in/prefill → one real decode over all occupied slots).
        Returns False when there is nothing left to do."""
        if self.stuck or not (self.pending or self.live):
            return False
        if not self.live and self.pending:
            self.now = max(self.now, self.pending[0].arrival)
        self._admit_arrivals()
        if not self.live:
            return True

        target = self.sched.schedule(self.now, self.live, self.fluid)
        target_ids = {id(r) for r in target}

        n_preempted = 0
        for r in list(self.slot_req.values()):
            if id(r) not in target_ids and r.state == ReqState.RUNNING:
                self._preempt(r)
                n_preempted += 1
        n_admitted = 0
        for r in target:
            if r.state == ReqState.SWAPPED and self.kv.can_allocate(r):
                self._swap_in(r)
                n_admitted += 1
            elif r.state == ReqState.WAITING and self.kv.can_allocate(r):
                r.state = ReqState.RUNNING
                r.prefilled = True
                self._prefill_request(r)
                n_admitted += 1

        # ---- one decode iteration over all occupied slots -------------
        active = {s: r for s, r in self.slot_req.items()
                  if r.state == ReqState.RUNNING}
        self.batch_sizes.append(len(active))
        if active:
            lengths = np.zeros(self.kv.num_slots, np.int32)
            tokens = np.zeros(self.kv.num_slots, np.int32)
            for s, r in active.items():
                lengths[s] = r.context_len
                tokens[s] = r.output_tokens[-1] if r.output_tokens else 0
            self.cache = cache_lib.with_lengths(self.cache, lengths)
            total_ctx = int(lengths.sum())
            if self.spec_k:
                self._speculative_iteration(active, lengths, tokens,
                                            total_ctx)
            else:
                logits, self.cache = self._decode(
                    self.params, jnp.asarray(tokens), self.cache
                )
                self._tick(self.lat.iter_latency(len(active), total_ctx))
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
                for s, r in list(active.items()):
                    self._emit(r, int(nxt[s]))
        else:
            self._tick(self.lat.hw.overhead)

        self.iterations += 1
        self.live = [r for r in self.live if r.is_live]
        n_live = len(self.live)
        self._admit_arrivals()
        newly_arrived = len(self.live) > n_live

        # ---- deadlock guard -------------------------------------------
        # Nothing decoded, admitted, preempted, or newly arrived (the
        # overhead tick can advance the clock past a pending arrival),
        # and no future arrival can change the picture: every live
        # request is permanently unschedulable (e.g. prompt larger than
        # KV capacity). The legacy loop spun on overhead ticks until
        # max_iterations; the steppable engine halts so unbounded drivers
        # (cluster drain) terminate. With arrivals still pending the
        # clock keeps advancing by the overhead tick exactly as the
        # legacy loop did, preserving bit-for-bit admission times.
        if not active and not n_admitted and not n_preempted \
                and not newly_arrived and not self.pending:
            self.stuck = True                # a later submit() may clear it
            return False
        return True

    def result(self) -> SimResult:
        return SimResult(
            requests=list(self.seen),
            makespan=self.now,
            total_tokens=self.total_tokens,
            preemptions=self.preemptions,
            iterations=self.iterations,
            batch_sizes=self.batch_sizes,
        )

    def run(self, workload: List[Request], max_iterations: int = 100_000):
        """Serve the workload to completion. Returns the finished requests.

        A thin loop over step(): reset + submit all + iterate until
        drained — the same batch semantics as ServingSimulator.run (on a
        fresh engine the reset is a no-op, so this still reproduces the
        pre-refactor monolithic loop bit-for-bit; the differential oracle
        lives in tests/test_engine_steppable.py)."""
        self.reset()
        for r in sorted(workload, key=lambda r: r.arrival):
            self.submit(r)
        while self.iterations < max_iterations:
            if not self.step():
                break
        return workload
