"""Discrete-event simulator for paper-scale serving experiments.

Faithful continuous-batching semantics (vLLM-style, iteration-granular):
every iteration the scheduler picks the running set; newly admitted
requests pay prefill (which emits their first token, blocking decode like
vLLM's non-chunked prefill); preempted requests pay swap-out now and
swap-in (or full recompute) on readmission; then every running request
decodes one token whose latency comes from the roofline-derived
LatencyModel. The client-side token buffer and exact Eq. 1 QoE are applied
at reporting time.

This is where Figures 3/10–18/21 and Table 4 are reproduced (the container
is CPU-only; see DESIGN.md §7 — the real engine in engine.py runs the same
scheduler against real models on small configs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.qoe import FluidQoE
from repro.core.scheduler import Scheduler
from repro.serving.request import Request, ReqState


@dataclasses.dataclass
class SimConfig:
    kv_capacity_tokens: int            # M
    preemption_mode: str = "swap"      # "swap" | "recompute"
    host_kv_capacity_tokens: int = 10_000_000
    max_sim_time: float = 10_000.0
    # charge the *measured host wall time* of each scheduler.schedule() call
    # to the simulated clock — this is what exposes the DP solver's
    # O(M·N·B) cost end-to-end (paper §6.5 Fig. 18)
    charge_scheduler_overhead: bool = False


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    makespan: float
    total_tokens: int
    preemptions: int
    iterations: int
    batch_sizes: List[int]

    # ---- paper metrics -----------------------------------------------------
    def qoes(self) -> np.ndarray:
        return np.array([r.final_qoe() for r in self.requests])

    def avg_qoe(self) -> float:
        return float(np.mean(self.qoes())) if self.requests else 1.0

    def ttfts(self) -> np.ndarray:
        return np.array([r.final_ttft() for r in self.requests])

    def tds(self) -> np.ndarray:
        return np.array([r.final_tds() for r in self.requests])

    def throughput(self) -> float:
        return self.total_tokens / self.makespan if self.makespan > 0 else 0.0

    def preemption_freq(self) -> float:
        return self.preemptions / max(len(self.requests), 1)

    def normalized_latencies(self) -> np.ndarray:
        return np.array([r.normalized_latency() for r in self.requests])


class ServingSimulator:
    def __init__(
        self,
        scheduler: Scheduler,
        lat: LatencyModel,
        sim_cfg: SimConfig,
    ):
        self.sched = scheduler
        self.lat = lat
        self.cfg = sim_cfg

    def run(self, workload: List[Request]) -> SimResult:
        workload = sorted(workload, key=lambda r: r.arrival)
        fluid = FluidQoE()
        pending = list(workload)
        live: List[Request] = []
        now = 0.0
        total_tokens = 0
        preemptions = 0
        iterations = 0
        batch_sizes: List[int] = []
        host_kv_used = 0
        st_equiv = self.sched.cfg.state_equiv_tokens

        def admit_arrivals(t):
            nonlocal pending
            while pending and pending[0].arrival <= t:
                r = pending.pop(0)
                r.fluid_idx = fluid.add(r.arrival, r.spec)
                r.state = ReqState.WAITING
                live.append(r)
                self.sched.on_request_arrival(r)

        while pending or live:
            if not live:
                now = max(now, pending[0].arrival)
            admit_arrivals(now)
            if not live:
                continue
            if now > self.cfg.max_sim_time:
                break

            running = [r for r in live if r.state == ReqState.RUNNING]
            if self.cfg.charge_scheduler_overhead:
                import time as _time
                _t0 = _time.perf_counter()
                target = self.sched.schedule(now, live, fluid)
                now += _time.perf_counter() - _t0
            else:
                target = self.sched.schedule(now, live, fluid)
            target_set = set(id(r) for r in target)

            # ---- preemptions ------------------------------------------------
            iter_extra = 0.0
            newly_preempted = [r for r in running if id(r) not in target_set]
            for r in newly_preempted:
                r.preemptions += 1
                preemptions += 1
                ctx = r.context_len
                if (self.cfg.preemption_mode == "swap"
                        and host_kv_used + ctx <= self.cfg.host_kv_capacity_tokens):
                    r.state = ReqState.SWAPPED
                    host_kv_used += ctx
                    iter_extra += self.lat.swap_latency(ctx)
                else:
                    # paper §4.2: fall back to recomputation when host RAM full
                    r.state = ReqState.WAITING
                    r.prefilled = False
            self.sched.record_preemptions(len(newly_preempted))

            # ---- admissions -------------------------------------------------
            first_emits: List[Request] = []
            for r in target:
                if r.state == ReqState.SWAPPED:
                    host_kv_used -= r.context_len
                    iter_extra += self.lat.swap_latency(r.context_len)
                    r.state = ReqState.RUNNING
                elif r.state == ReqState.WAITING:
                    # prefill (recompute includes generated prefix)
                    iter_extra += self.lat.prefill_latency(r.context_len)
                    r.state = ReqState.RUNNING
                    r.prefilled = True
                    if r.generated == 0:
                        first_emits.append(r)

            running = [r for r in live if r.state == ReqState.RUNNING]
            batch_sizes.append(len(running))

            # first tokens come out of prefill itself
            prefill_done = now + iter_extra
            for r in first_emits:
                r.emit_times.append(prefill_done)
                fluid.emit(r.fluid_idx, prefill_done, 1)
                r.generated = 1
                total_tokens += 1

            # ---- decode iteration -------------------------------------------
            decoders = [r for r in running if r.generated < r.output_len]
            total_ctx = sum(r.context_len for r in decoders)
            step = self.lat.iter_latency(len(decoders), total_ctx)
            now = prefill_done + (step if decoders else 0.0)
            iterations += 1

            emit_idx = []
            for r in decoders:
                r.emit_times.append(now)
                r.generated += 1
                total_tokens += 1
                emit_idx.append(r.fluid_idx)
            if emit_idx:
                fluid.emit(np.array(emit_idx), now, 1)

            # ---- completions -------------------------------------------------
            for r in running:
                if r.generated >= r.output_len:
                    r.state = ReqState.FINISHED
                    r.finish_time = now
                    self.sched.on_request_finish(r)
            live = [r for r in live if r.is_live]
            admit_arrivals(now)

        return SimResult(
            requests=workload,
            makespan=now,
            total_tokens=total_tokens,
            preemptions=preemptions,
            iterations=iterations,
            batch_sizes=batch_sizes,
        )
