"""Discrete-event simulator for paper-scale serving experiments.

Faithful continuous-batching semantics (vLLM-style, iteration-granular):
every iteration the scheduler picks the running set; newly admitted
requests pay prefill (which emits their first token, blocking decode like
vLLM's non-chunked prefill); preempted requests pay swap-out now and
swap-in (or full recompute) on readmission; then every running request
decodes one token whose latency comes from the roofline-derived
LatencyModel. The client-side token buffer and exact Eq. 1 QoE are applied
at reporting time.

This is where Figures 3/10–18/21 and Table 4 are reproduced (the container
is CPU-only; see DESIGN.md §7 — the real engine in engine.py runs the same
scheduler against real models on small configs).

The simulator is *steppable*: `submit()` enqueues arrivals, `step()`
executes one continuous-batching iteration, and `result()` snapshots the
metrics. `run()` composes them for the classic single-node path, while the
cluster layer (repro.cluster) drives many simulators as replicas off a
shared arrival trace — stepping each only as far as the fleet clock
requires — without changing the per-iteration semantics.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.qoe import FluidQoE
from repro.core.scheduler import Scheduler
from repro.core.request import Request, ReqState


@dataclasses.dataclass
class SimConfig:
    kv_capacity_tokens: int            # M
    preemption_mode: str = "swap"      # "swap" | "recompute"
    host_kv_capacity_tokens: int = 10_000_000
    max_sim_time: float = 10_000.0
    # charge the *measured host wall time* of each scheduler.schedule() call
    # to the simulated clock — this is what exposes the DP solver's
    # O(M·N·B) cost end-to-end (paper §6.5 Fig. 18)
    charge_scheduler_overhead: bool = False


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    makespan: float
    total_tokens: int
    preemptions: int
    iterations: int
    batch_sizes: List[int]

    # ---- paper metrics -----------------------------------------------------
    def qoes(self) -> np.ndarray:
        return np.array([r.final_qoe() for r in self.requests])

    def avg_qoe(self) -> float:
        return float(np.mean(self.qoes())) if self.requests else 1.0

    def ttfts(self) -> np.ndarray:
        return np.array([r.final_ttft() for r in self.requests])

    def tds(self) -> np.ndarray:
        return np.array([r.final_tds() for r in self.requests])

    def throughput(self) -> float:
        return self.total_tokens / self.makespan if self.makespan > 0 else 0.0

    def preemption_freq(self) -> float:
        return self.preemptions / max(len(self.requests), 1)

    def normalized_latencies(self) -> np.ndarray:
        return np.array([r.normalized_latency() for r in self.requests])


class ServingSimulator:
    """Single-node continuous-batching simulator.

    Incremental API (used by the cluster layer's `Replica`):
      submit(req)  enqueue an arrival (any time, in any order)
      step()       one scheduling+decode iteration; False when out of work
      has_work     pending or live requests remain
      result()     SimResult over every request ever submitted

    Batch API (classic single-node experiments):
      run(workload)  reset + submit all + step to completion
    """

    def __init__(
        self,
        scheduler: Scheduler,
        lat: LatencyModel,
        sim_cfg: SimConfig,
    ):
        self.sched = scheduler
        self.lat = lat
        self.cfg = sim_cfg
        # observability (repro.obs): `self.obs` is the effective observer
        # (None = off) composed from an installed Observer and/or a legacy
        # `event_sink` callable (deprecated; wrapped in EventSinkAdapter).
        # Survives reset() so run() keeps reporting to installed consumers.
        self._observer = None
        self._event_sink = None
        self.obs = None
        self.reset()

    # ------------------------------------------------------------ observers
    @property
    def observer(self):
        """Installed Observer (repro.obs); None = observability off."""
        return self._observer

    @observer.setter
    def observer(self, obs) -> None:
        self._observer = obs
        self._rewire_obs()

    @property
    def event_sink(self):
        """Legacy lifecycle callable `sink(kind, req, t, k)` (deprecated;
        kept as an EventSinkAdapter shim — prefer `observer`)."""
        return self._event_sink

    @event_sink.setter
    def event_sink(self, sink) -> None:
        self._event_sink = sink
        self._rewire_obs()

    def set_observer(self, obs) -> None:
        self.observer = obs

    def attach_observer(self, obs) -> None:
        """Add `obs` alongside any already-installed observer."""
        from repro.obs.observer import compose
        self.observer = compose(self._observer, obs)

    def _rewire_obs(self) -> None:
        from repro.obs.observer import EventSinkAdapter, compose
        sink_obs = (EventSinkAdapter(self._event_sink)
                    if self._event_sink is not None else None)
        self.obs = compose(self._observer, sink_obs)
        self.sched.obs = self.obs

    # ------------------------------------------------------------------ state
    def reset(self) -> None:
        self.sched.reset()                   # policy state (counters, orders)
        self.fluid = FluidQoE()
        self._pending: List[Request] = []    # sorted arrivals; admitted
        self._pending_pos = 0                #   prefix tracked by cursor
        self.live: List[Request] = []
        self.now = 0.0
        self.total_tokens = 0
        self.preemptions = 0
        self.iterations = 0
        self.batch_sizes: List[int] = []
        self.host_kv_used = 0
        self.halted = False                  # hit max_sim_time (permanent)
        self.stuck = False                   # deadlocked (cleared by submit)
        self.seen: List[Request] = []        # submit order

    def submit(self, req: Request) -> None:
        """Enqueue an arrival. Stable insert keeps equal-arrival order
        (bisect_right above the admitted-prefix cursor — identical order
        to the old insort-into-a-popped-list, without its O(n²) drain)."""
        i = bisect.bisect_right(self._pending, req.arrival,
                                lo=self._pending_pos,
                                key=lambda r: r.arrival)
        self._pending.insert(i, req)
        self.seen.append(req)
        if self.obs is not None:
            self.obs.submit(req, req.arrival)
        # a new arrival may be schedulable even if the current live set
        # deadlocked (e.g. an oversized prompt) — try again
        self.stuck = False

    def cancel(self, rid: int) -> bool:
        """Abort a request by rid — steppable-backend parity with
        ServingEngine.cancel: mark cancelled + FINISHED with whatever was
        emitted, free its host-KV accounting, notify the scheduler. False
        when the rid is unknown or already finished (a cancel racing
        normal completion is a no-op)."""
        for i in range(self._pending_pos, len(self._pending)):
            r = self._pending[i]
            if r.rid == rid:
                del self._pending[i]
                r.cancelled = True
                r.state = ReqState.FINISHED
                r.finish_time = self.now
                if self.obs is not None:
                    self.obs.cancel(r, self.now)
                return True
        for r in self.live:
            if r.rid == rid:
                if r.state == ReqState.SWAPPED:
                    self.host_kv_used -= r.context_len
                r.cancelled = True
                r.state = ReqState.FINISHED
                r.finish_time = self.now
                self.sched.on_request_finish(r)
                self.live = [x for x in self.live if x is not r]
                self.stuck = False
                if self.obs is not None:
                    self.obs.cancel(r, self.now)
                return True
        return False

    @property
    def pending(self) -> List[Request]:
        """Submitted-but-not-admitted requests (protocol view; the hot loop
        uses the cursor directly and never materializes this slice)."""
        return self._pending[self._pending_pos:]

    @property
    def has_work(self) -> bool:
        return self._pending_pos < len(self._pending) or bool(self.live)

    # ---------------------------------------------------------------- helpers
    def _admit_arrivals(self, t: float) -> None:
        pend = self._pending
        pos = self._pending_pos
        obs = self.obs
        while pos < len(pend) and pend[pos].arrival <= t:
            r = pend[pos]
            pos += 1
            r.fluid_idx = self.fluid.add(r.arrival, r.spec)
            r.state = ReqState.WAITING
            self.live.append(r)
            self.sched.on_request_arrival(r)
            if obs is not None:
                obs.admit(r, t)
        self._pending_pos = pos
        # amortized compaction: drop the consumed prefix once it dominates
        if pos and pos * 2 >= len(pend):
            del pend[:pos]
            self._pending_pos = 0

    # ------------------------------------------------------------------- step
    def step(self, until: Optional[float] = None) -> bool:
        """One continuous-batching iteration. Returns False when there is
        nothing left to do (drained or past max_sim_time). `until` is
        accepted for SteppableBackend drive parity (Replica.advance_to
        passes it to bound the engine's multi-step fast path); simulator
        iterations are always indivisible, so it is a no-op here."""
        if self.halted or self.stuck or not self.has_work:
            return False
        if not self.live:
            self.now = max(self.now,
                           self._pending[self._pending_pos].arrival)
        self._admit_arrivals(self.now)
        if not self.live:
            return True
        if self.now > self.cfg.max_sim_time:
            self.halted = True
            return False

        fluid = self.fluid
        now = self.now
        running = [r for r in self.live if r.state == ReqState.RUNNING]
        if self.cfg.charge_scheduler_overhead:
            import time as _time
            _t0 = _time.perf_counter()
            target = self.sched.schedule(now, self.live, fluid)
            now += _time.perf_counter() - _t0
        else:
            target = self.sched.schedule(now, self.live, fluid)
        target_set = set(id(r) for r in target)

        # ---- preemptions ------------------------------------------------
        obs = self.obs
        iter_extra = 0.0
        newly_preempted = [r for r in running if id(r) not in target_set]
        for r in newly_preempted:
            r.preemptions += 1
            self.preemptions += 1
            ctx = r.context_len
            if (self.cfg.preemption_mode == "swap"
                    and self.host_kv_used + ctx <= self.cfg.host_kv_capacity_tokens):
                r.state = ReqState.SWAPPED
                self.host_kv_used += ctx
                iter_extra += self.lat.swap_latency(ctx)
                mode = "swap"
            else:
                # paper §4.2: fall back to recomputation when host RAM full
                r.state = ReqState.WAITING
                r.prefilled = False
                mode = "recompute"
            if obs is not None:
                obs.preempt(r, now, mode)
        self.sched.record_preemptions(len(newly_preempted))

        # ---- admissions -------------------------------------------------
        first_emits: List[Request] = []
        for r in target:
            if r.state == ReqState.SWAPPED:
                self.host_kv_used -= r.context_len
                iter_extra += self.lat.swap_latency(r.context_len)
                r.state = ReqState.RUNNING
                if obs is not None:
                    obs.swap_in(r, now)
            elif r.state == ReqState.WAITING:
                # prefill (recompute includes generated prefix)
                iter_extra += self.lat.prefill_latency(r.context_len)
                r.state = ReqState.RUNNING
                r.prefilled = True
                if obs is not None:
                    obs.prefill(r, now, r.context_len)
                if r.generated == 0:
                    first_emits.append(r)

        running = [r for r in self.live if r.state == ReqState.RUNNING]
        self.batch_sizes.append(len(running))

        # first tokens come out of prefill itself
        prefill_done = now + iter_extra
        for r in first_emits:
            r.emit_times.append(prefill_done)
            fluid.emit(r.fluid_idx, prefill_done, 1)
            r.generated = 1
            self.total_tokens += 1
            if obs is not None:
                obs.emit(r, prefill_done, 1)

        # ---- decode iteration -------------------------------------------
        decoders = [r for r in running if r.generated < r.output_len]
        total_ctx = sum(r.context_len for r in decoders)
        step = self.lat.iter_latency(len(decoders), total_ctx)
        now = prefill_done + (step if decoders else 0.0)
        self.iterations += 1

        emit_idx = []
        for r in decoders:
            r.emit_times.append(now)
            r.generated += 1
            self.total_tokens += 1
            emit_idx.append(r.fluid_idx)
            if obs is not None:
                obs.emit(r, now, 1)
        if emit_idx:
            fluid.emit(np.array(emit_idx), now, 1)

        # ---- completions -------------------------------------------------
        for r in running:
            if r.generated >= r.output_len:
                r.state = ReqState.FINISHED
                r.finish_time = now
                self.sched.on_request_finish(r)
                if obs is not None:
                    obs.finish(r, now)
        self.live = [r for r in self.live if r.is_live]
        self.now = now
        self._admit_arrivals(now)

        # ---- deadlock guard ----------------------------------------------
        # A live request that can never be scheduled (e.g. prompt larger
        # than KV capacity) makes no progress: no admissions or swap-ins
        # (iter_extra stays 0), no decoders, no preemptions. Jump to the
        # next arrival if one exists (it may change the scheduler's
        # choice); otherwise halt, leaving the unschedulable requests
        # unfinished (QoE 0) rather than spinning. (Progress is detected
        # from the work signals, not the clock — charge_scheduler_overhead
        # advances `now` by wall time even in an idle iteration.)
        if iter_extra == 0.0 and not decoders and not first_emits \
                and not newly_preempted:
            if self._pending_pos < len(self._pending):
                self.now = max(self.now,
                               self._pending[self._pending_pos].arrival)
            else:
                self.stuck = True            # a later submit() may clear it
                return False
        return True

    # ----------------------------------------------------------------- result
    def result(self) -> SimResult:
        return SimResult(
            requests=list(self.seen),
            makespan=self.now,
            total_tokens=self.total_tokens,
            preemptions=self.preemptions,
            iterations=self.iterations,
            batch_sizes=self.batch_sizes,
        )

    def run(self, workload: List[Request]) -> SimResult:
        self.reset()
        for r in sorted(workload, key=lambda r: r.arrival):
            self.submit(r)
        while self.step():
            pass
        return self.result()
