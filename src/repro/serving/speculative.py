"""Draft-model side of speculative decoding inside a ServingEngine.

A `DraftProposer` owns a second, smaller model (same tokenizer/vocab as the
target — e.g. a 1-layer granite-class config drafting for the full one) and
a second static-slot cache with the *same* slot layout as the target's, so
request -> slot mapping, preemption and swap round-trips stay one decision
made once by the engine's KVSlotManager.

Per scheduled step the proposer greedily autoregresses k+1 tokens in one
jitted scan (`Model.propose_step`); the engine verifies the window
[last_committed, d_1..d_k] against the target in one `Model.verify_step`
call and commits the longest matching prefix plus the correction/bonus
token — lossless by construction under greedy sampling.

Draft-cache bookkeeping reduces to ONE invariant, restored for free every
round:

    the draft cache's valid prefix is always committed[: context_len - 1]

i.e. the draft has consumed every committed token except the last, which is
exactly the next round's first input. Why it holds: the proposal scan
consumes k+1 inputs (the last committed token, then its own d_1..d_k — the
extra (k+1)-th step is what makes full acceptance not a special case).
After a tokens are accepted, the consumed inputs d_1..d_a coincide with the
newly committed tokens and everything after them is stale; re-pinning the
draft cache's `length` to the new context_len - 1 (done unconditionally at
the top of every `propose`) is the entire rollback, per the length-gate
contract in models/cache.py. No per-request draft state exists outside the
cache itself, which is why park/restore are plain slot-slice copies.

SSM/recurrent architectures are rejected up front: their state has no
length gate to roll back through (checkpoint-per-position would be needed),
and capacity-routed MoE couples slots within a batch, which would break the
per-request bit-identity the differential harness asserts. Dense attention
is the supported — and paper-relevant — regime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache as cache_lib
from repro.models.model import Model


def check_speculation_compatible(target: Model, draft: Model) -> None:
    """Both models must be attention-only and share the token space."""
    for role, m in (("target", target), ("draft", draft)):
        if m.cfg.kind != "dense":
            raise ValueError(
                f"speculative decoding supports dense attention models; "
                f"{role} is kind={m.cfg.kind!r} (SSM state cannot be "
                f"length-rolled-back; MoE capacity routing couples slots)"
            )
    if target.cfg.vocab_size != draft.cfg.vocab_size:
        raise ValueError(
            f"draft must share the target's vocab: "
            f"{draft.cfg.vocab_size} != {target.cfg.vocab_size}"
        )


class DraftProposer:
    """Slot-parallel greedy proposer over a shared draft (model, params).

    `bucketed` (a serving.engine.BucketedPrefill over the draft model)
    routes draft prefills through the same jitted shape-bucketed path the
    engine's target prefills use: admissions flushed in one step build
    their draft KV in one padded multi-row call + one fused slot scatter
    (`prefill_batch`), bounding draft prefill compiles by the bucket grid.
    None (hot path off) keeps the eager exact-length batch-1 path."""

    def __init__(
        self,
        model: Model,
        params,
        *,
        num_slots: int,
        max_seq: int,
        cache_dtype=jnp.float32,
        bucketed=None,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.bucketed = bucketed
        self.cache = model.init_cache(num_slots, max_seq, dtype=cache_dtype)
        self._propose = jax.jit(model.propose_step, static_argnames=("k",))

    # ---- per-slot cache lifecycle (mirrors the engine's target cache) ------
    def prefill(self, slot: int, tokens: np.ndarray) -> None:
        """Build the draft KV for a request's committed-minus-last prefix."""
        if self.bucketed is not None:
            self.prefill_batch([slot], [tokens])
            return
        from repro.serving.engine import _write_slot
        one = self.model.init_cache(
            1, self.max_seq, dtype=self.cache["k"].dtype
        )
        _, one = self.model.prefill(
            self.params, {"tokens": jnp.asarray(tokens, jnp.int32)[None]}, one
        )
        self.cache = _write_slot(self.cache, one, slot)

    def prefill_batch(self, slots, toks_list) -> int:
        """Bucketed multi-row draft prefill — the same grouped
        `BucketedPrefill.prefill_into` flush the engine's admission path
        uses (one padded call + one fused scatter per bucket group; each
        row bit-identical to a batch-1 prefill of the same request, so the
        slot-parallel propose scans see exactly the state the sequential
        path would have built). The draft never needs first-token ids, so
        the flush skips the device→host fetch entirely. Returns the number
        of bucket groups dispatched (the engine's dispatch accounting)."""
        self.cache, _, _, n_groups = self.bucketed.prefill_into(
            self.params, self.cache, list(slots), list(toks_list),
            need_first=False,
        )
        return n_groups

    def park(self, slot: int) -> dict:
        """Fetch a slot's draft slice to host (preemption swap-out)."""
        from repro.serving.engine import _read_slot
        return jax.device_get(_read_slot(self.cache, slot))

    def restore(self, slot: int, host_slice: dict) -> None:
        from repro.serving.engine import _write_slot
        self.cache = _write_slot(
            self.cache, jax.tree.map(jnp.asarray, host_slice), slot
        )

    # ---- proposal ----------------------------------------------------------
    def propose(self, last_tokens: np.ndarray, draft_lengths: np.ndarray,
                k: int) -> np.ndarray:
        """Greedy k-token proposals for every slot.

        last_tokens (num_slots,): the last committed token per slot (the
        single catch-up input — see the module-docstring invariant).
        draft_lengths (num_slots,): committed context_len - 1 per active
        slot (0 for inactive slots, whose outputs are ignored). Returns
        proposals (num_slots, k); the scan's (k+1)-th token is internal
        cache upkeep and is dropped here.
        """
        self.cache = cache_lib.with_lengths(self.cache, draft_lengths)
        toks, self.cache = self._propose(
            self.params, jnp.asarray(last_tokens, jnp.int32), self.cache, k=k
        )
        return np.asarray(toks)[:, :k]


__all__ = ["DraftProposer", "check_speculation_compatible"]
