"""Multi-tenant arrival traces with per-tenant QoE specs (cluster layer).

The paper's traces (Tables 1–2) draw every request's QoE spec from one
user-demographic mix. A fleet serves *tenants* — products with distinct
QoE contracts and traffic shapes: an interactive chat app (stringent TTFT,
reading-speed TDS), a voice assistant (speaking-speed TDS), a background
summarization API (lenient on both). Skewed tenant mixes are exactly the
scenario where QoE-aware routing and admission (repro.cluster, extending
paper §6.4 surge handling fleet-wide) diverge from load-only policies, so
the generator tags each Request with its tenant id for per-tenant
accounting.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.qoe import QoESpec
from repro.core.request import Request
from repro.workload.arrivals import gamma_arrivals, poisson_arrivals
from repro.workload.qoe_traces import EXPECTED_TTFT, reading_qoe_trace, voice_qoe_trace
from repro.workload.sharegpt import sample_lengths


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic contract."""
    name: str
    share: float                 # fraction of total request volume
    qoe: str = "reading"         # "reading" | "voice" | "fixed"
    ttft: float = EXPECTED_TTFT  # expected TTFT (s); also the fixed-mode TTFT
    tds: float = 4.8             # fixed-mode expected TDS (tokens/s)
    dataset: str = "sharegpt"    # length distribution ("sharegpt"|"multiround")


# A plausible production mix: latency-stringent chat dominates, a voice
# product needs slower-but-steady delivery, and a batch API tolerates long
# TTFT and a trickle TDS (it reads the whole answer at the end).
DEFAULT_TENANTS = (
    TenantSpec("chat", share=0.6, qoe="reading", ttft=1.0),
    TenantSpec("voice", share=0.25, qoe="voice", ttft=1.5),
    TenantSpec("batch_api", share=0.15, qoe="fixed", ttft=10.0, tds=1.5,
               dataset="multiround"),
)


def _tenant_specs(t: TenantSpec, n: int, rng: np.random.Generator) -> List[QoESpec]:
    if t.qoe == "reading":
        return reading_qoe_trace(n, rng, ttft=t.ttft)
    if t.qoe == "voice":
        return voice_qoe_trace(n, rng, ttft=t.ttft)
    if t.qoe == "fixed":
        return [QoESpec(ttft=t.ttft, tds=t.tds)] * n
    raise ValueError(t.qoe)


def make_multitenant_workload(
    n: int,
    rate: float,
    *,
    tenants: Optional[Sequence[TenantSpec]] = None,
    seed: int = 0,
    arrival: str = "gamma",
    cv: float = 3.0,
) -> List[Request]:
    """n requests at aggregate `rate` req/s, tenant drawn per-request from
    the share mix; lengths and QoE specs follow each request's tenant."""
    tenants = list(tenants if tenants is not None else DEFAULT_TENANTS)
    rng = np.random.default_rng(seed)
    shares = np.array([t.share for t in tenants], np.float64)
    shares = shares / shares.sum()
    tenant_ids = rng.choice(len(tenants), size=n, p=shares)

    if arrival == "poisson":
        arrivals = poisson_arrivals(rate, n, rng)
    elif arrival == "gamma":
        arrivals = gamma_arrivals(rate, n, rng, cv=cv)
    else:
        raise ValueError(arrival)

    # draw lengths/specs per tenant (each from that tenant's distribution),
    # then scatter back into arrival order
    prompt = np.zeros(n, np.int64)
    out = np.zeros(n, np.int64)
    specs: List[Optional[QoESpec]] = [None] * n
    for tid, t in enumerate(tenants):
        idx = np.nonzero(tenant_ids == tid)[0]
        if idx.size == 0:
            continue
        p, o = sample_lengths(idx.size, rng, t.dataset)
        prompt[idx], out[idx] = p, o
        for j, s in zip(idx, _tenant_specs(t, idx.size, rng)):
            specs[j] = s

    return [
        Request(
            rid=i,
            arrival=float(arrivals[i]),
            prompt_len=int(prompt[i]),
            output_len=int(out[i]),
            spec=specs[i],
            tenant=int(tenant_ids[i]),
        )
        for i in range(n)
    ]

