"""Multi-tenant arrival traces with per-tenant QoE specs (cluster layer).

The paper's traces (Tables 1–2) draw every request's QoE spec from one
user-demographic mix. A fleet serves *tenants* — products with distinct
QoE contracts and traffic shapes: an interactive chat app (stringent TTFT,
reading-speed TDS), a voice assistant (speaking-speed TDS), a background
summarization API (lenient on both). Skewed tenant mixes are exactly the
scenario where QoE-aware routing and admission (repro.cluster, extending
paper §6.4 surge handling fleet-wide) diverge from load-only policies, so
the generator tags each Request with its tenant id for per-tenant
accounting.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.pricing import SLOContract
from repro.core.qoe import QoESpec
from repro.core.request import Request
from repro.workload.arrivals import gamma_arrivals, poisson_arrivals
from repro.workload.qoe_traces import EXPECTED_TTFT, reading_qoe_trace, voice_qoe_trace
from repro.workload.sharegpt import sample_lengths


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic contract."""
    name: str
    share: float                 # fraction of total request volume
    qoe: str = "reading"         # "reading" | "voice" | "fixed"
    ttft: float = EXPECTED_TTFT  # expected TTFT (s); also the fixed-mode TTFT
    tds: float = 4.8             # fixed-mode expected TDS (tokens/s)
    dataset: str = "sharegpt"    # length distribution ("sharegpt"|"multiround")
    weight: float = 1.0          # SLO contract weight (WSC fair share)
    qoe_floor: Optional[float] = None   # per-tenant contract QoE floor
    # access-link scenario this tenant's users sit behind (a key of
    # repro.core.network.NETWORK_SCENARIOS); None = ideal link, which keeps
    # pre-existing workloads byte-identical. Consumers (client buffers,
    # QoE-under-network evaluation) instantiate via `make_network(name)`.
    network: Optional[str] = None

    def contract(self) -> Optional[SLOContract]:
        """SLOContract carried by this tenant's requests — only when the
        tenant departs from the defaults, so pre-arena workloads are
        byte-identical (contract=None prices as weight 1.0 everywhere)."""
        if self.weight == 1.0 and self.qoe_floor is None:
            return None
        return SLOContract(weight=self.weight, qoe_floor=self.qoe_floor)


# A plausible production mix: latency-stringent chat dominates, a voice
# product needs slower-but-steady delivery, and a batch API tolerates long
# TTFT and a trickle TDS (it reads the whole answer at the end).
DEFAULT_TENANTS = (
    TenantSpec("chat", share=0.6, qoe="reading", ttft=1.0),
    TenantSpec("voice", share=0.25, qoe="voice", ttft=1.5),
    TenantSpec("batch_api", share=0.15, qoe="fixed", ttft=10.0, tds=1.5,
               dataset="multiround"),
)


def _tenant_specs(t: TenantSpec, n: int, rng: np.random.Generator) -> List[QoESpec]:
    if t.qoe == "reading":
        return reading_qoe_trace(n, rng, ttft=t.ttft)
    if t.qoe == "voice":
        return voice_qoe_trace(n, rng, ttft=t.ttft)
    if t.qoe == "fixed":
        return [QoESpec(ttft=t.ttft, tds=t.tds)] * n
    raise ValueError(t.qoe)


def make_multitenant_workload(
    n: int,
    rate: float,
    *,
    tenants: Optional[Sequence[TenantSpec]] = None,
    seed: int = 0,
    arrival: str = "gamma",
    cv: float = 3.0,
) -> List[Request]:
    """n requests at aggregate `rate` req/s, tenant drawn per-request from
    the share mix; lengths and QoE specs follow each request's tenant."""
    tenants = list(tenants if tenants is not None else DEFAULT_TENANTS)
    rng = np.random.default_rng(seed)
    shares = np.array([t.share for t in tenants], np.float64)
    shares = shares / shares.sum()
    tenant_ids = rng.choice(len(tenants), size=n, p=shares)

    if arrival == "poisson":
        arrivals = poisson_arrivals(rate, n, rng)
    elif arrival == "gamma":
        arrivals = gamma_arrivals(rate, n, rng, cv=cv)
    else:
        raise ValueError(arrival)

    # draw lengths/specs per tenant (each from that tenant's distribution),
    # then scatter back into arrival order
    prompt = np.zeros(n, np.int64)
    out = np.zeros(n, np.int64)
    specs: List[Optional[QoESpec]] = [None] * n
    for tid, t in enumerate(tenants):
        idx = np.nonzero(tenant_ids == tid)[0]
        if idx.size == 0:
            continue
        p, o = sample_lengths(idx.size, rng, t.dataset)
        prompt[idx], out[idx] = p, o
        for j, s in zip(idx, _tenant_specs(t, idx.size, rng)):
            specs[j] = s

    contracts = [t.contract() for t in tenants]
    return [
        Request(
            rid=i,
            arrival=float(arrivals[i]),
            prompt_len=int(prompt[i]),
            output_len=int(out[i]),
            spec=specs[i],
            tenant=int(tenant_ids[i]),
            contract=contracts[tenant_ids[i]],
        )
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Adversarial traces (the policy arena's referee workloads)
#
# Each generator builds the scenario a specific policy family is supposed
# to win (or lose) — TokenFlow's synchronized bursts stress preemption,
# heavy-tail prompt mixes stress memory packing, and a greedy tenant
# stresses fairness isolation. All are deterministic in `seed` (pinned by
# tests/test_workload.py) and return plain Request lists, so every backend
# and policy consumes them unchanged.
# ---------------------------------------------------------------------------

def _retag(reqs: List[Request]) -> List[Request]:
    """Re-id in arrival order (backends expect sorted submission)."""
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    return [dataclasses.replace(r, rid=i) for i, r in enumerate(reqs)]


def synchronized_burst_workload(
    n: int,
    rate: float,
    *,
    seed: int = 0,
    burst_every: float = 30.0,
    burst_frac: float = 0.5,
    burst_width: float = 0.5,
    tenants: Optional[Sequence[TenantSpec]] = None,
) -> List[Request]:
    """TokenFlow-style flash crowds: a `burst_frac` share of the traffic
    lands in near-simultaneous spikes every `burst_every` seconds (each
    spike `burst_width`s wide), on top of a smooth background. Buffer-
    aware preemption should absorb the spikes by pausing full-buffer
    requests; FCFS head-of-line blocks on them."""
    base = make_multitenant_workload(n, rate, tenants=tenants, seed=seed,
                                     arrival="poisson")
    rng = np.random.default_rng(seed + 1)
    n_burst = int(n * burst_frac)
    horizon = max(r.arrival for r in base) if base else n / rate
    n_spikes = max(int(horizon // burst_every), 1)
    for r in base[-n_burst:]:
        spike = (1 + int(rng.integers(n_spikes))) * burst_every
        r.arrival = min(spike + float(rng.uniform(0.0, burst_width)),
                        horizon)
    return _retag(base)


def heavy_tail_workload(
    n: int,
    rate: float,
    *,
    seed: int = 0,
    tail_frac: float = 0.1,
    tail_scale: float = 8.0,
    tenants: Optional[Sequence[TenantSpec]] = None,
) -> List[Request]:
    """Heavy-tail prompt mix: a `tail_frac` share of requests carries
    prompts ~`tail_scale`x the tenant's draw (Pareto-style elephants).
    Elephants monopolize KV memory, so packing quality and preemption
    policy dominate; token-counter fairness must not let one tenant's
    elephants starve everyone's mice."""
    base = make_multitenant_workload(n, rate, tenants=tenants, seed=seed)
    rng = np.random.default_rng(seed + 2)
    tail_idx = rng.choice(n, size=max(int(n * tail_frac), 1), replace=False)
    tail = set(int(i) for i in tail_idx)
    out = []
    for r in base:
        if r.rid in tail:
            factor = tail_scale * float(rng.pareto(2.0) + 1.0)
            r = dataclasses.replace(
                r, prompt_len=int(min(r.prompt_len * factor, 8192)))
        out.append(r)
    return _retag(out)


def greedy_tenant_workload(
    n: int,
    rate: float,
    *,
    seed: int = 0,
    greedy_share: float = 0.7,
    greedy_output: int = 512,
    victim_weight: float = 2.0,
    tenants: Optional[Sequence[TenantSpec]] = None,
) -> List[Request]:
    """One-greedy-tenant isolation test: tenant 0 ("greedy") floods
    `greedy_share` of the volume with long outputs at contract weight 1,
    while the well-behaved tenants keep the DEFAULT_TENANTS shapes but
    carry `victim_weight` SLO contracts (they are the paying traffic the
    flood is drowning). A fair policy caps the greedy tenant near its
    entitlement (Jain's index over weight-normalized service stays
    high) — and a *weighted* fair policy (WSC) should beat unweighted
    VTC here, since only it reads the contracts. Throughput-greedy
    policies let the flood starve everyone."""
    tenants = list(tenants if tenants is not None else DEFAULT_TENANTS)
    well_behaved = [dataclasses.replace(
        t, weight=victim_weight,
        share=t.share * (1.0 - greedy_share) / sum(
            x.share for x in tenants))
        for t in tenants]
    mix = [TenantSpec("greedy", share=greedy_share, qoe="fixed",
                      ttft=2.0, tds=6.0)] + well_behaved
    base = make_multitenant_workload(n, rate, tenants=mix, seed=seed)
    rng = np.random.default_rng(seed + 3)
    out = []
    for r in base:
        if r.tenant == 0:     # the greedy tenant demands long generations
            r = dataclasses.replace(
                r, output_len=int(rng.integers(greedy_output // 2,
                                               greedy_output + 1)))
        out.append(r)
    return _retag(out)


ADVERSARIAL_TRACES = {
    "burst": synchronized_burst_workload,
    "heavy_tail": heavy_tail_workload,
    "greedy_tenant": greedy_tenant_workload,
}


def make_adversarial_workload(name: str, n: int, rate: float,
                              **kw) -> List[Request]:
    """Build a named adversarial trace (see ADVERSARIAL_TRACES)."""
    return ADVERSARIAL_TRACES[name](n, rate, **kw)

