"""ShareGPT-like workload synthesis (paper §6.1, Fig. 9).

The real ShareGPT dump is not available offline; we match the published
shape of Fig. 9: input lengths roughly log-normal with median ≈ 160 tokens
(capped at 1k), outputs log-normal with median ≈ 200 tokens (capped at 1k),
and the Multi-Round variant concatenates rounds for ≈ 3× longer inputs with
the same output distribution.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.qoe import QoESpec
from repro.core.request import Request
from repro.workload.arrivals import gamma_arrivals, poisson_arrivals
from repro.workload.qoe_traces import reading_qoe_trace


def sample_lengths(
    n: int,
    rng: np.random.Generator,
    dataset: str = "sharegpt",
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (prompt_len, output_len) int arrays."""
    if dataset == "sharegpt":
        p = rng.lognormal(mean=5.0, sigma=0.9, size=n)        # median ~148
    elif dataset == "multiround":
        p = rng.lognormal(mean=6.1, sigma=0.7, size=n)        # ~3x longer
    else:
        raise ValueError(dataset)
    o = rng.lognormal(mean=5.3, sigma=0.8, size=n)            # median ~200
    prompt = np.clip(p, 4, 1024).astype(np.int64)
    out = np.clip(o, 4, 1024).astype(np.int64)
    return prompt, out


def make_workload(
    n: int,
    rate: float,
    *,
    seed: int = 0,
    dataset: str = "sharegpt",
    arrival: str = "poisson",
    qoe_trace: str = "reading",
    cv: float = 3.0,
) -> List[Request]:
    rng = np.random.default_rng(seed)
    prompt, out = sample_lengths(n, rng, dataset)
    if arrival == "poisson":
        arrivals = poisson_arrivals(rate, n, rng)
    elif arrival == "gamma":
        arrivals = gamma_arrivals(rate, n, rng, cv=cv)
    else:
        raise ValueError(arrival)
    if qoe_trace == "reading":
        specs = reading_qoe_trace(n, rng)
    else:
        from repro.workload.qoe_traces import voice_qoe_trace
        specs = voice_qoe_trace(n, rng)
    return [
        Request(
            rid=i,
            arrival=float(arrivals[i]),
            prompt_len=int(prompt[i]),
            output_len=int(out[i]),
            spec=specs[i],
        )
        for i in range(n)
    ]
