from repro.workload.arrivals import gamma_arrivals, poisson_arrivals
from repro.workload.multitenant import (
    ADVERSARIAL_TRACES,
    DEFAULT_TENANTS,
    TenantSpec,
    greedy_tenant_workload,
    heavy_tail_workload,
    make_adversarial_workload,
    make_multitenant_workload,
    synchronized_burst_workload,
)
from repro.workload.qoe_traces import reading_qoe_trace, voice_qoe_trace
from repro.workload.sharegpt import make_workload, sample_lengths

__all__ = [
    "poisson_arrivals",
    "gamma_arrivals",
    "reading_qoe_trace",
    "voice_qoe_trace",
    "sample_lengths",
    "make_workload",
    "TenantSpec",
    "DEFAULT_TENANTS",
    "make_multitenant_workload",
    "ADVERSARIAL_TRACES",
    "make_adversarial_workload",
    "synchronized_burst_workload",
    "heavy_tail_workload",
    "greedy_tenant_workload",
]
