"""QoE requirement traces (paper Tables 1–2, §6.1).

Expected TTFT is 1 s for all requests. Expected TDS is drawn from the
user-demographic mix: reading speeds by age group (text chat) or speaking
speeds by language (voice chat), converted words→tokens with the average
word-to-token ratio (~0.75 words/token ⇒ tokens/s = WPM / 60 / 0.75).
The paper's summary numbers: average reading 4.8 tok/s, speaking 3.3 tok/s.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.qoe import QoESpec

WORD_PER_TOKEN = 0.75
EXPECTED_TTFT = 1.0

# (share, words-per-minute)
READING_WPM = [
    (0.280, 236),   # 18-24
    (0.519, 200),   # 25-44
    (0.112, 192),   # 45-54
    (0.056, 185),   # 55-64
    (0.033, 175),   # 65+
]
SPEAKING_WPM = [
    (0.793, 150),   # English
    (0.070, 158),   # Chinese
    (0.069, 150),   # Korean
    (0.036, 195),   # French
    (0.032, 218),   # Spanish
]


def _wpm_to_tds(wpm: float) -> float:
    return wpm / 60.0 / WORD_PER_TOKEN


def _trace(mix, n: int, rng: np.random.Generator, ttft: float) -> List[QoESpec]:
    shares = np.array([s for s, _ in mix])
    shares = shares / shares.sum()
    wpms = np.array([w for _, w in mix])
    idx = rng.choice(len(mix), size=n, p=shares)
    return [QoESpec(ttft=ttft, tds=_wpm_to_tds(wpms[i])) for i in idx]


def reading_qoe_trace(n: int, rng: np.random.Generator,
                      ttft: float = EXPECTED_TTFT) -> List[QoESpec]:
    """Text-chat trace (Table 1): mean ≈ 4.5–4.8 tokens/s."""
    return _trace(READING_WPM, n, rng, ttft)


def voice_qoe_trace(n: int, rng: np.random.Generator,
                    ttft: float = EXPECTED_TTFT) -> List[QoESpec]:
    """Voice-chat trace (Table 2): mean ≈ 3.3–3.5 tokens/s."""
    return _trace(SPEAKING_WPM, n, rng, ttft)
