"""Request arrival processes (paper §6.1 / §6.4)."""
from __future__ import annotations

import numpy as np


def poisson_arrivals(rate: float, n: int, rng: np.random.Generator) -> np.ndarray:
    """n arrival timestamps with exponential inter-arrivals at `rate` req/s."""
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


def gamma_arrivals(
    rate: float, n: int, rng: np.random.Generator, cv: float = 3.0
) -> np.ndarray:
    """Bursty arrivals: Gamma inter-arrival with coefficient of variation cv
    and the same mean rate (paper §6.4 uses cv = 3)."""
    shape = 1.0 / (cv * cv)
    scale = 1.0 / (rate * shape)
    gaps = rng.gamma(shape, scale, size=n)
    return np.cumsum(gaps)
