"""Replica: one serving engine behind a uniform submit/step/drain API.

Extends the paper's single-engine scope (§4–§6 run ONE continuous-batching
instance) to the fleet: a `Replica` wraps a backend with its own scheduler,
KV capacity, and fluid QoE state, and exposes exactly what the cluster
layer needs — enqueue a routed request, advance the replica's clock, and
report load/QoE snapshots for routing decisions.

The default backend is the discrete-event `ServingSimulator`; anything
satisfying `SteppableBackend` (notably a stepped `ServingEngine` running a
real JAX model) plugs in unchanged, because the cluster layer only ever
talks through this protocol.
"""
from __future__ import annotations

from typing import List, Optional, Protocol, runtime_checkable

from repro.core.latency_model import LatencyModel
from repro.core.qoe import FluidQoE
from repro.core.request import Request
from repro.core.scheduler import Scheduler
from repro.serving.simulator import ServingSimulator, SimResult


@runtime_checkable
class SteppableBackend(Protocol):
    """Minimal engine surface the cluster layer depends on.

    Satisfied structurally by both `ServingSimulator` (discrete-event) and
    `ServingEngine` (real JAX model, virtual clock) — see
    `repro.cluster.backends` for the factories that build either per
    replica. runtime_checkable so tests can assert conformance with
    isinstance (presence-of-members check)."""
    sched: Scheduler
    fluid: FluidQoE
    live: List[Request]
    pending: List[Request]       # submitted, not yet admitted to the batch
    seen: List[Request]          # every request ever submitted
    now: float
    has_work: bool

    def submit(self, req: Request) -> None: ...
    def step(self, until: Optional[float] = None) -> bool: ...
    def result(self) -> SimResult: ...

    # Observability (repro.obs): assignable effective-observer slot. Both
    # shipped backends also expose `observer`/`event_sink` properties and
    # `attach_observer`; the cluster layer only *assigns* `observer`, so a
    # minimal third-party backend may accept it as a plain attribute and
    # simply never call the hooks (observability degrades to silence, not
    # to a crash).
    observer: object


class Replica:
    """One engine instance in the fleet."""

    def __init__(
        self,
        replica_id: int,
        backend: SteppableBackend,
        lat: LatencyModel,
        *,
        launched_at: float = 0.0,
    ):
        self.id = replica_id
        self.backend = backend
        self.lat = lat
        self.launched_at = launched_at
        self.draining = False
        self.drained_at: Optional[float] = None
        self.n_routed = 0

    # -------------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        if self.draining:
            raise RuntimeError(f"replica {self.id} is draining")
        self.n_routed += 1
        self.backend.submit(req)

    def step(self, until: Optional[float] = None) -> bool:
        return self.backend.step(until=until)

    def advance_to(self, t: float) -> None:
        """Run iterations until the replica's clock reaches t (or idle).
        Iterations are indivisible (continuous batching), so the clock may
        overshoot t — identical to how a single engine admits arrivals at
        the next iteration boundary. `t` is passed down as the backend's
        `until` bound so an engine's multi-step decode never fuses past
        the upcoming routed arrival: the crossing remains one indivisible
        iteration, keeping routed timelines bit-identical to
        submit-everything-upfront runs."""
        while self.backend.has_work and self.backend.now < t:
            if not self.step(until=t):
                break

    def drain(self) -> None:
        """Stop accepting new requests; in-flight requests finish."""
        self.draining = True

    @property
    def drained(self) -> bool:
        return self.draining and not self.backend.has_work

    # ------------------------------------------------------------------ views
    @property
    def clock(self) -> float:
        return self.backend.now

    @property
    def has_work(self) -> bool:
        return self.backend.has_work

    @property
    def live(self) -> List[Request]:
        return self.backend.live

    @property
    def pending(self) -> List[Request]:
        return self.backend.pending

    def committed(self) -> List[Request]:
        """Live + pending: every request this replica is on the hook for.
        Routing decisions during a burst happen faster than the replica
        steps, so load views must count work that was just routed here even
        though the engine has not admitted it yet (otherwise every policy
        herds the whole burst onto one replica)."""
        return self.backend.live + self.backend.pending

    @property
    def fluid(self) -> FluidQoE:
        return self.backend.fluid

    @property
    def kv_capacity(self) -> int:
        return self.backend.sched.M

    def kv_demand(self) -> int:
        st = self.backend.sched.cfg.state_equiv_tokens
        return sum(r.kv_tokens(st) for r in self.committed())

    def result(self) -> SimResult:
        return self.backend.result()

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        flag = " draining" if self.draining else ""
        return (f"Replica({self.id}, t={self.clock:.2f}, "
                f"live={len(self.live)}{flag})")
