"""ClusterSimulator: N replicas, one arrival trace, fleet-wide QoE.

The top of the cluster stack (see this package's __init__ for the map):
pops arrivals in time order, advances every replica's discrete-event clock
to the arrival (iterations are indivisible, exactly as in the single-node
simulator), lets the Autoscaler react, the Router place, and the
AdmissionController admit/defer/shed — then drains the fleet and reports
QoE over *all* requests, shed ones included (paper Eq. 1 gives an
unserved request QoE 0, which is what "degrade gracefully under surge",
§6.4, must be measured against).

A 1-replica cluster with admission and autoscaling off reproduces the
single-node `ServingSimulator` token timeline bit-for-bit — the cluster
layer only ever *adds* decisions around the engine, never changes it
(regression-tested in tests/test_cluster.py).

Like the simulator and engine, the cluster is *steppable*: `submit()`
enqueues arrivals for routing, `step()` executes one fleet event (route
the next queued arrival, or advance each busy replica one iteration once
the queue is empty), and `result()` snapshots a ClusterResult. `run()` is
a thin loop over them — which is what lets `repro.api.ServingClient`
front a whole cluster through the same submit/stream surface as a bare
backend (tests/test_api.py pins run() ≡ the pre-refactor monolithic loop
bit-for-bit via the 1-replica invariance).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional

import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.objectives import fleet_slo_attainment
from repro.core.pricing import weighted_attainment
from repro.core.request import Request
from repro.core.scheduler import SchedulerConfig, make_scheduler
from repro.serving.simulator import SimResult
from repro.cluster.admission import ADMIT, DEFER, AdmissionConfig, AdmissionController
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig, ScaleEvent
from repro.cluster.backends import BackendFactory, simulator_backend
from repro.cluster.replica import Replica
from repro.cluster.router import RouterConfig, make_router


@dataclasses.dataclass
class ClusterConfig:
    n_replicas: int = 2
    scheduler: str = "andes"
    router: str = "qoe"                 # round_robin | jsq | qoe
    kv_capacity_tokens: int = 65_000    # per replica
    preemption_mode: str = "swap"
    max_sim_time: float = 10_000.0
    sched_cfg: Optional[SchedulerConfig] = None
    router_cfg: Optional[RouterConfig] = None
    admission: Optional[AdmissionConfig] = None     # None -> admit all
    autoscaler: Optional[AutoscalerConfig] = None   # None -> fixed fleet
    # what runs inside each replica: (rid, scheduler, lat, cfg) -> backend.
    # None -> discrete-event simulator; see repro.cluster.backends for the
    # real-engine and mixed-fleet factories.
    backend_factory: Optional[BackendFactory] = None


@dataclasses.dataclass
class ClusterResult:
    admitted: List[Request]
    shed: List[Request]
    n_defer_events: int
    makespan: float
    replica_results: Dict[int, SimResult]
    scale_events: List[ScaleEvent]
    peak_replicas: int

    # ---- fleet metrics -----------------------------------------------------
    def qoes(self, include_shed: bool = True) -> np.ndarray:
        q = [r.final_qoe() for r in self.admitted]
        if include_shed:
            q += [0.0] * len(self.shed)
        return np.array(q)

    def avg_qoe(self, include_shed: bool = True) -> float:
        q = self.qoes(include_shed)
        return float(q.mean()) if q.size else 1.0

    def slo_attainment(self, threshold: float = 0.9,
                       include_shed: bool = True) -> float:
        per_rep = [np.array([r.final_qoe() for r in res.requests])
                   for res in self.replica_results.values()]
        return fleet_slo_attainment(
            per_rep, threshold,
            n_shed=len(self.shed) if include_shed else 0)

    def shed_rate(self) -> float:
        n = len(self.admitted) + len(self.shed)
        return len(self.shed) / max(n, 1)

    def ttfts(self) -> np.ndarray:
        return np.array([r.final_ttft() for r in self.admitted])

    def total_tokens(self) -> int:
        return sum(res.total_tokens for res in self.replica_results.values())

    def throughput(self) -> float:
        return self.total_tokens() / self.makespan if self.makespan > 0 else 0.0

    def preemptions(self) -> int:
        return sum(res.preemptions for res in self.replica_results.values())

    def per_tenant_avg_qoe(self) -> Dict[int, float]:
        acc: Dict[int, List[float]] = {}
        for r in self.admitted:
            acc.setdefault(r.tenant, []).append(r.final_qoe())
        for r in self.shed:
            acc.setdefault(r.tenant, []).append(0.0)
        return {k: float(np.mean(v)) for k, v in sorted(acc.items())}

    def contract_attainment(self, default_floor: float = 0.9,
                            include_shed: bool = True) -> float:
        """Contract-weighted SLO attainment over the whole trace
        (core.pricing.weighted_attainment; a shed request never emitted,
        so it fails its contract and its weight counts against the fleet).
        With no contracts this is the uniform QoE-floor attainment."""
        reqs = self.admitted + (self.shed if include_shed else [])
        return weighted_attainment(reqs, default_floor)

    def per_tenant_attainment(self, default_floor: float = 0.9
                              ) -> Dict[int, float]:
        acc: Dict[int, List[Request]] = {}
        for r in self.admitted + self.shed:
            acc.setdefault(r.tenant, []).append(r)
        return {k: weighted_attainment(v, default_floor)
                for k, v in sorted(acc.items())}


class ClusterSimulator:
    """`lat` may be a single LatencyModel (homogeneous fleet) or a sequence
    of them — replica i runs on lat[i % len(lat)], which models a
    heterogeneous fleet (e.g. the paper's 4xA100 and 4xA40 deployments side
    by side; DiSCo-style dispatching is where the QoE router's pricing of
    each replica's hardware pays off)."""

    def __init__(self, lat, cfg: Optional[ClusterConfig] = None):
        self.lats: List[LatencyModel] = (
            list(lat) if isinstance(lat, (list, tuple)) else [lat]
        )
        self.cfg = cfg or ClusterConfig()
        if self.cfg.n_replicas < 1:
            raise ValueError("ClusterConfig.n_replicas must be >= 1")
        if not self.lats:
            raise ValueError("at least one LatencyModel is required")
        self.router = make_router(self.cfg.router, self.cfg.router_cfg)
        self.admission = AdmissionController(
            self.cfg.admission or AdmissionConfig(),
            self.cfg.router_cfg,
        )
        self.autoscaler = (Autoscaler(self.cfg.autoscaler)
                           if self.cfg.autoscaler else None)
        self._rep_ids = itertools.count()
        # observability (repro.obs): `self.obs` is the effective observer
        # composed from an installed Observer and/or a legacy `event_sink`
        # callable (deprecated; wrapped in EventSinkAdapter). Propagated —
        # replica-scoped — to every replica backend, including ones the
        # autoscaler provisions later; the cluster itself emits fleet
        # events (route/admission/scale/shed/defer). Initialized before
        # the first replicas are built so they inherit it too.
        self._observer = None
        self._event_sink = None
        self.obs = None
        self.replicas: List[Replica] = [
            self._new_replica(0.0) for _ in range(self.cfg.n_replicas)
        ]
        self.retired: List[Replica] = []
        self.peak_replicas = len(self.replicas)
        # steppable state: routing queue of (route_at, tiebreak, request);
        # deferred requests re-enter with a later route_at but keep their
        # original arrival (their QoE clock started when the user hit enter)
        self._queue: List = []
        self._seq = itertools.count()
        self.now = 0.0                    # fleet clock (last event time)
        self.admitted: List[Request] = []
        self.shed: List[Request] = []
        self._finalized = False

    # ----------------------------------------------------------------- fleet
    def _new_replica(self, launched_at: float) -> Replica:
        cfg = self.cfg
        rid = next(self._rep_ids)
        lat = self.lats[rid % len(self.lats)]
        sched_cfg = dataclasses.replace(cfg.sched_cfg) if cfg.sched_cfg \
            else SchedulerConfig()
        sched = make_scheduler(cfg.scheduler, cfg.kv_capacity_tokens,
                               lat, sched_cfg)
        factory = cfg.backend_factory or simulator_backend
        backend = factory(rid, sched, lat, cfg)
        backend.now = launched_at    # replica is born at provision time
        # the factory may re-point the scheduler's latency model (e.g.
        # speculative_backend installs a SpeculativeLatencyModel); the
        # replica's routing/admission views must price with the same model
        # the backend does, so the QoE router sees a speculative replica's
        # true expected-burst token rate. For stock factories sched.lat IS
        # the lat picked above, so nothing changes.
        backend.observer = self._scoped_obs(rid)
        return Replica(rid, backend, sched.lat, launched_at=launched_at)

    # ------------------------------------------------------------ observers
    def _scoped_obs(self, rid: int):
        if self.obs is None:
            return None
        from repro.obs.observer import ScopedObserver
        return ScopedObserver(self.obs, rid)

    @property
    def observer(self):
        """Installed Observer (repro.obs); None = observability off. The
        cluster propagates it replica-scoped to every backend (current and
        future), so one observer sees the whole fleet with replica ids."""
        return self._observer

    @observer.setter
    def observer(self, obs) -> None:
        self._observer = obs
        self._rewire_obs()

    @property
    def event_sink(self):
        """Legacy lifecycle callable `sink(kind, req, t, k)` (deprecated;
        kept as an EventSinkAdapter shim — prefer `observer`)."""
        return self._event_sink

    @event_sink.setter
    def event_sink(self, sink) -> None:
        self._event_sink = sink
        self._rewire_obs()

    def set_observer(self, obs) -> None:
        self.observer = obs

    def attach_observer(self, obs) -> None:
        """Add `obs` alongside any already-installed observer."""
        from repro.obs.observer import compose
        self.observer = compose(self._observer, obs)

    def set_event_sink(self, sink) -> None:
        """Install a lifecycle-event sink on the fleet (deprecated shim —
        prefer `set_observer`/`attach_observer`): every replica backend
        (current and future) reports emit/preempt/finish events through
        it, and the cluster itself reports shed/defer decisions. This is
        how repro.api.ServingClient used to observe a whole cluster; it
        now rides the Observer protocol through an EventSinkAdapter."""
        self.event_sink = sink

    def _rewire_obs(self) -> None:
        from repro.obs.observer import EventSinkAdapter, compose
        sink_obs = (EventSinkAdapter(self._event_sink)
                    if self._event_sink is not None else None)
        self.obs = compose(self._observer, sink_obs)
        for rep in self.replicas + self.retired:
            rep.backend.observer = self._scoped_obs(rep.id)

    def _advance_all(self, t: float) -> None:
        for rep in self.replicas:
            rep.advance_to(t)

    def _reap_drained(self, t: float) -> None:
        """Retire fully drained replicas (they keep their results)."""
        still, gone = [], []
        for rep in self.replicas:
            (gone if rep.drained else still).append(rep)
        for rep in gone:
            self.autoscaler.record_reap(t, rep)
            if self.obs is not None:
                self.obs.scale(t, "reap", rep.id)
        self.replicas, self.retired = still, self.retired + gone

    def _autoscale(self, t: float) -> None:
        if self.autoscaler is None:
            return
        for _ in range(self.autoscaler.take_ready_provisions(t)):
            rep = self._new_replica(t)
            self.replicas.append(rep)
            if self.obs is not None:
                self.obs.scale(t, "provision_ready", rep.id)
        events = self.autoscaler.evaluate(t, self.replicas)
        if self.obs is not None:
            for ev in events:
                self.obs.scale(ev.t, ev.action, ev.replica_id,
                               signal=ev.signal)
        self._reap_drained(t)
        self.peak_replicas = max(self.peak_replicas, len(self.replicas))

    # ----------------------------------------------------- incremental API
    def submit(self, req: Request) -> None:
        """Enqueue an arrival for routing at its arrival time. Re-arms the
        end-of-trace cleanup so a second submit-then-drain round on the
        same cluster finalizes again (interactive client sessions)."""
        heapq.heappush(self._queue, (req.arrival, next(self._seq), req))
        if self.obs is not None:
            self.obs.submit(req, req.arrival)
        self._finalized = False

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(rep.has_work for rep in self.replicas)

    @property
    def seen(self) -> List[Request]:
        """Every request this cluster has decided on (admitted or shed)."""
        return self.admitted + self.shed

    def _route_next(self) -> None:
        """Pop the next queued arrival, advance the fleet to it, and let
        autoscaler → router → admission act (one routing event)."""
        route_at, _, req = heapq.heappop(self._queue)
        self.now = max(self.now, route_at)
        self._advance_all(route_at)
        self._autoscale(route_at)
        routable = [r for r in self.replicas if not r.draining]
        if not routable:
            # fleet drained to nothing (e.g. min_replicas=0 during a
            # lull): un-drain the newest replica, or provision a fresh
            # one, rather than dropping traffic on the floor
            if self.replicas:
                self.replicas[-1].draining = False
                routable = [self.replicas[-1]]
            else:
                rep = self._new_replica(route_at)
                self.replicas.append(rep)
                self.peak_replicas = max(self.peak_replicas,
                                         len(self.replicas))
                routable = [rep]
        decision = self.router.route(req, routable, route_at)
        obs = self.obs
        if obs is not None:
            obs.route(req, route_at, decision.replica.id, decision.gain,
                      decision.scores)
        action = self.admission.decide(req, decision, route_at)
        if obs is not None:
            obs.admission(req, route_at, action, decision.gain)
        if action == ADMIT:
            decision.replica.submit(req)
            self.admitted.append(req)
        elif action == DEFER:
            heapq.heappush(
                self._queue,
                (route_at + self.admission.cfg.defer_delay,
                 next(self._seq), req),
            )
            if obs is not None:
                obs.defer(req, route_at)
        else:
            self.shed.append(req)
            if obs is not None:
                obs.shed(req, route_at)

    def step(self, until: Optional[float] = None) -> bool:
        """One fleet event: route the next queued arrival, or — once the
        queue is empty — advance every busy replica by one iteration
        (replicas are independent after routing, so per-replica outcomes
        are identical to draining them one at a time). Returns False when
        fully drained; the first False triggers the end-of-trace
        autoscaler cleanup (cancel in-flight provisions, reap drained
        replicas) exactly as the monolithic run() loop did.

        `until`: arrivals already routed bound each replica's multi-step
        fast path through Replica.advance_to; pass `until` (forwarded to
        every replica stepped here) only for the future-submit pattern —
        a multi-wave session that will submit a request with an explicit
        later `arrival` after stepping past it — so drain-phase fused
        blocks stop at the same iteration boundary a baseline fleet
        driven by the identical call sequence would."""
        if self._queue:
            self._route_next()
            return True
        progressed = False
        for rep in self.replicas + self.retired:
            if rep.has_work and rep.step(until=until):
                progressed = True
        if progressed:
            self.now = max([self.now]
                           + [rep.clock for rep in self.replicas])
            return True
        self._finalize()
        return False

    def _finalize(self) -> None:
        if self._finalized:
            return
        self._finalized = True
        if self.autoscaler is not None:
            # no more arrivals: cancel in-flight provisions (a replica that
            # comes up after the last request would serve nothing and only
            # inflate peak_replicas), then reap whatever finished draining.
            # Deliberately NOT a full _autoscale: re-running evaluate here
            # would record phantom scale decisions after the trace ended.
            self.autoscaler.pending_provisions.clear()
            t_end = max((rep.clock for rep in self.replicas + self.retired),
                        default=0.0)
            self._reap_drained(t_end)

    def result(self) -> ClusterResult:
        all_reps = self.replicas + self.retired
        results = {rep.id: rep.result() for rep in all_reps}
        makespan = max(
            (res.makespan for res in results.values() if res.requests),
            default=0.0,
        )
        return ClusterResult(
            admitted=list(self.admitted),
            shed=list(self.shed),
            n_defer_events=self.admission.n_defer_events,
            makespan=makespan,
            replica_results=results,
            scale_events=list(self.autoscaler.events) if self.autoscaler else [],
            peak_replicas=self.peak_replicas,
        )

    # ------------------------------------------------------------------- run
    def run(self, workload: List[Request]) -> ClusterResult:
        """Serve the workload to completion: a thin loop over submit() +
        step(), preserving the pre-refactor monolithic loop's behavior
        (same pop order — the (arrival, submit-order) heap key is a total
        order — and the same post-trace autoscaler cleanup)."""
        for r in sorted(workload, key=lambda r: r.arrival):
            self.submit(r)
        while self.step():
            pass
        return self.result()
