"""Admission control: shed or defer requests the fleet cannot serve well.

Paper §6.4 shows single-engine Andes degrading gracefully under surge by
favoring salvageable requests; fleet-wide the same logic argues some
requests should not be admitted at all — admitting a request whose own
achievable QoE is lower than the QoE it destroys across the chosen
replica's batch makes the *fleet total* worse (TokenFlow, arXiv
2510.02758, makes the matching observation for burst preemption). The
controller prices admission through the one QoEPricer surface
(repro.core.pricing — the same implementation the scheduler knapsack and
the router consume), contract-weighted per tenant:

  gain = weight · Q̂_new − Σ degradation of live requests

  gain > min_gain           → admit
  gain ≤ min_gain, defer    → retry `defer_delay`s later (bounded retries;
                              the user keeps waiting, so their QoE clock —
                              Request.arrival — keeps running)
  gain ≤ min_gain, shed     → reject now (QoE 0, counted in fleet metrics)

`weight` is the request's SLOContract/priority pricing weight
(core.pricing.request_weight): a weight-2 tenant's achievable QoE counts
double against the harm its admission does, so under surge the fleet
sheds the low-weight tail first. Uncontracted traffic weighs 1.0, which
reproduces the PR 1 uniform `min_gain` threshold bit-for-bit
(tests/test_api.py pins the reduction).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.request import Request
from repro.cluster.router import RouteDecision, RouterConfig, marginal_qoe_gain

ADMIT, SHED, DEFER = "admit", "shed", "defer"


@dataclasses.dataclass
class AdmissionConfig:
    policy: str = "none"          # "none" | "shed" | "defer"
    min_gain: float = 0.0         # admit iff marginal fleet QoE gain > this
    defer_delay: float = 2.0      # seconds between retries
    max_defers: int = 3           # retries before a deferred request sheds


class AdmissionController:
    def __init__(self, cfg: Optional[AdmissionConfig] = None,
                 router_cfg: Optional[RouterConfig] = None):
        self.cfg = cfg or AdmissionConfig()
        self.router_cfg = router_cfg or RouterConfig()
        self._defers: Dict[int, int] = {}     # rid -> retry count
        self.n_shed = 0
        self.n_defer_events = 0

    def counters(self) -> Dict[str, int]:
        """Decision tallies for observability (metrics gauges / reports)."""
        return {
            "shed": self.n_shed,
            "defer_events": self.n_defer_events,
            "deferred_requests": len(self._defers),
        }

    def decide(self, req: Request, decision: RouteDecision,
               now: float) -> str:
        """ADMIT/SHED/DEFER for `req` given the router's chosen placement."""
        if self.cfg.policy == "none":
            return ADMIT
        gain = decision.gain
        if gain is None:   # router didn't price the placement (rr/jsq)
            gain = marginal_qoe_gain(decision.replica, req, now,
                                     self.router_cfg)
        if gain > self.cfg.min_gain:
            return ADMIT
        if (self.cfg.policy == "defer"
                and self._defers.get(req.rid, 0) < self.cfg.max_defers):
            self._defers[req.rid] = self._defers.get(req.rid, 0) + 1
            self.n_defer_events += 1
            return DEFER
        self.n_shed += 1
        return SHED
