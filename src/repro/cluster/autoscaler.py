"""QoE-SLO autoscaler: grow/shrink the replica fleet with demand.

The paper fixes the deployment (one engine, §6.1) and asks how much QoE a
scheduler can extract from it; the ROADMAP's production north star also
needs the converse knob — how much hardware does a QoE target cost? The
autoscaler closes that loop with the fleet-level SLO-attainment signal
(repro.core.objectives.fleet_slo_attainment, §6.1's capacity metric):

  * scale UP   when windowed SLO attainment drops below `slo_low` or the
    fleet KV overcommit exceeds `util_high` — new replicas come from a
    bounded capacity pool after `provision_delay` (model load, cache warm).
  * scale DOWN when attainment sits above `slo_high` with KV utilization
    under `util_low` — the chosen replica *drains*: the router stops
    sending it traffic, its in-flight requests finish, then it returns to
    the pool (no QoE is sacrificed to shrink).

Decisions are rate-limited by `cooldown` to avoid thrash on bursty
arrivals (gamma cv=3 traces whipsaw instantaneous signals).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.pricing import weighted_attainment
from repro.cluster.replica import Replica

SCALE_UP, SCALE_DOWN, REAP = "scale_up", "scale_down", "reap"


@dataclasses.dataclass
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 8
    slo_threshold: float = 0.9     # per-request QoE counted as "good" (§6.1)
    slo_low: float = 0.8           # attainment below this -> scale up
    slo_high: float = 0.98         # attainment above this (and idle) -> down
    util_high: float = 1.1         # fleet KV demand/capacity overcommit
    util_low: float = 0.45
    window: float = 30.0           # signal window (s)
    provision_delay: float = 15.0  # replica spin-up time (s)
    cooldown: float = 30.0         # min gap between scale decisions (s)


@dataclasses.dataclass
class ScaleEvent:
    t: float
    action: str                    # scale_up | scale_down | reap
    replica_id: int                # -1 for scale_up (id assigned on ready)
    signal: Optional[dict] = None  # attainment/utilization snapshot that
                                   # drove the decision (None for reaps —
                                   # those are consequences, not decisions)


class Autoscaler:
    """Emits scale decisions; the ClusterSimulator applies them."""

    def __init__(self, cfg: Optional[AutoscalerConfig] = None):
        self.cfg = cfg or AutoscalerConfig()
        self._last_decision = -np.inf
        self.events: List[ScaleEvent] = []
        self.pending_provisions: List[float] = []   # ready times

    # ---------------------------------------------------------------- signal
    def signal(self, now: float, replicas: Sequence[Replica]) -> dict:
        """Windowed fleet SLO attainment + instantaneous KV utilization.

        Attainment is the contract-weighted fraction of recently finished
        requests meeting their SLOContract (core.pricing.weighted_attainment
        — the same pricing surface the scheduler/router/admission use);
        `slo_threshold` is the QoE floor for uncontracted requests. With no
        contracts this is exactly the uniform §6.1 attainment signal."""
        lo = now - self.cfg.window
        finished = []
        for rep in replicas:
            for r in rep.backend.seen:
                if not r.is_live and lo <= r.finish_time <= now:
                    finished.append(r)
        attain = weighted_attainment(finished, self.cfg.slo_threshold)
        demand = sum(rep.kv_demand() for rep in replicas if not rep.draining)
        capacity = sum(rep.kv_capacity for rep in replicas if not rep.draining)
        return {
            "slo_attainment": attain,
            "kv_utilization": demand / max(capacity, 1),
            "n_finished": len(finished),
        }

    # -------------------------------------------------------------- decision
    def evaluate(self, now: float, replicas: Sequence[Replica]) -> List[ScaleEvent]:
        """Returns the scale actions to apply at `now` (may be empty)."""
        cfg = self.cfg
        out: List[ScaleEvent] = []

        active = [r for r in replicas if not r.draining]
        n_effective = len(active) + len(self.pending_provisions)
        if now - self._last_decision < cfg.cooldown:
            self.events.extend(out)
            return out

        sig = self.signal(now, replicas)
        overloaded = (sig["slo_attainment"] < cfg.slo_low
                      or sig["kv_utilization"] > cfg.util_high)
        idle = (sig["slo_attainment"] > cfg.slo_high
                and sig["kv_utilization"] < cfg.util_low)

        if overloaded and n_effective < cfg.max_replicas:
            self.pending_provisions.append(now + cfg.provision_delay)
            self._last_decision = now
            out.append(ScaleEvent(now, SCALE_UP, -1, signal=sig))
        elif idle and len(active) > cfg.min_replicas:
            # drain the least-loaded active replica (cheapest to finish)
            victim = min(active, key=lambda r: (r.kv_demand(), -r.id))
            victim.drain()
            self._last_decision = now
            out.append(ScaleEvent(now, SCALE_DOWN, victim.id, signal=sig))

        self.events.extend(out)
        return out

    def record_reap(self, now: float, replica: Replica) -> None:
        """A drained replica returns to the capacity pool (called by the
        ClusterSimulator at the moment of retirement — draining can finish
        at any point of the event loop, including inside the very decision
        that started it when the victim was already idle)."""
        replica.drained_at = now
        self.events.append(ScaleEvent(now, REAP, replica.id))

    def take_ready_provisions(self, now: float) -> int:
        """Number of provisioned replicas ready by `now` (consumed)."""
        ready = [t for t in self.pending_provisions if t <= now]
        self.pending_provisions = [t for t in self.pending_provisions
                                   if t > now]
        return len(ready)
