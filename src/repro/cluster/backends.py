"""Backend factories: what actually runs inside each cluster replica.

`ClusterSimulator` builds one `SteppableBackend` per replica through a
`BackendFactory` — a callable `(replica_id, scheduler, lat, cluster_cfg)
-> SteppableBackend`. The default (`simulator_backend`) wraps the
discrete-event `ServingSimulator`, which is what every paper-scale sweep
uses. `engine_backend(...)` returns a factory whose replicas run the real
JAX model through the (now steppable) `ServingEngine` — same scheduler,
same latency model, virtual clock — so a fleet can be validated against
actual token emission on CPU-sized configs (tests/test_cluster_engine.py).
`speculative_backend(...)` runs draft+verify speculative decoding inside
each replica (same token streams as `engine_backend`, fewer steps — see
serving/speculative.py). `mixed_backends(...)` round-robins factories over
replica ids, giving heterogeneous fleets where e.g. replica 0 is a real
model and the rest are simulated, or half the fleet speculates (the DiSCo
device/server-split and fast/slow-decode-path directions in ROADMAP.md).

Weights are shared across engine replicas (the factory closes over one
`(model, params)` pair); each replica gets its own KV cache and fluid
QoE state.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.core.latency_model import LatencyModel, SpeculativeLatencyModel
from repro.core.scheduler import Scheduler
from repro.cluster.replica import SteppableBackend
from repro.serving.simulator import ServingSimulator, SimConfig

BackendFactory = Callable[..., SteppableBackend]


def simulator_backend(replica_id: int, scheduler: Scheduler,
                      lat: LatencyModel, cluster_cfg) -> SteppableBackend:
    """Default: a discrete-event simulator per replica."""
    return ServingSimulator(scheduler, lat, SimConfig(
        kv_capacity_tokens=cluster_cfg.kv_capacity_tokens,
        preemption_mode=cluster_cfg.preemption_mode,
        max_sim_time=cluster_cfg.max_sim_time,
    ))


def engine_backend(
    model,
    params,
    *,
    num_slots: int = 8,
    max_seq: int = 128,
    capacity_tokens: Optional[int] = None,
    clock: str = "virtual",
    eos_id: int = -1,
    hotpath=None,
) -> BackendFactory:
    """Factory of real-model replicas: each one a `ServingEngine` over the
    shared `(model, params)`. `capacity_tokens` defaults to the cluster
    config's per-replica KV budget (clamped to what the slot cache can
    physically hold); the replica's scheduler is re-pointed at the same
    capacity so its knapsack, the router's pricing, and admission control
    never assume KV the engine does not physically have. `hotpath` is the
    engine's HotpathConfig (None = the lossless optimizations ON, the
    engine default; pass HotpathConfig.baseline() for the pre-PR-5
    loop)."""
    def factory(replica_id: int, scheduler: Scheduler,
                lat: LatencyModel, cluster_cfg) -> SteppableBackend:
        from repro.serving.engine import ServingEngine
        cap = capacity_tokens
        if cap is None:
            cap = min(cluster_cfg.kv_capacity_tokens, num_slots * max_seq)
        scheduler.M = min(scheduler.M, cap)
        return ServingEngine(
            model, params, scheduler, lat,
            num_slots=num_slots, max_seq=max_seq, capacity_tokens=cap,
            preemption_mode=cluster_cfg.preemption_mode,
            clock=clock, eos_id=eos_id, hotpath=hotpath,
        )
    return factory


def speculative_backend(
    model,
    params,
    draft_model,
    draft_params,
    *,
    spec_k: int = 3,
    num_slots: int = 8,
    max_seq: int = 128,
    capacity_tokens: Optional[int] = None,
    clock: str = "virtual",
    eos_id: int = -1,
    hotpath=None,
) -> BackendFactory:
    """Factory of speculative real-model replicas: each one a
    `ServingEngine` whose decode steps draft-propose `spec_k` tokens with
    the shared `(draft_model, draft_params)` and verify them against the
    shared target in one pass (lossless — the replica emits the identical
    token stream an `engine_backend` replica would, in fewer steps).

    The replica's scheduler is re-pointed at a `SpeculativeLatencyModel`
    built on its own hardware spec, so knapsack pricing, the router's
    marginal-gain queries, and admission control all see the expected
    1..k+1-token bursts rather than one-token steps. Combine with
    `engine_backend` via `mixed_backends` for spec/non-spec fleets
    (the ROADMAP's heterogeneous-decode-path direction, DiSCo-style)."""
    def factory(replica_id: int, scheduler: Scheduler,
                lat: LatencyModel, cluster_cfg) -> SteppableBackend:
        from repro.serving.engine import ServingEngine
        cap = capacity_tokens
        if cap is None:
            cap = min(cluster_cfg.kv_capacity_tokens, num_slots * max_seq)
        scheduler.M = min(scheduler.M, cap)
        spec_lat = SpeculativeLatencyModel(
            model.cfg, lat.hw, draft_model.cfg, k=spec_k,
            dtype_bytes=lat.dtype_bytes, avg_ctx=lat.avg_ctx,
        )
        scheduler.lat = spec_lat
        return ServingEngine(
            model, params, scheduler, spec_lat,
            num_slots=num_slots, max_seq=max_seq, capacity_tokens=cap,
            preemption_mode=cluster_cfg.preemption_mode,
            clock=clock, eos_id=eos_id, hotpath=hotpath,
            draft_model=draft_model, draft_params=draft_params,
            spec_k=spec_k,
        )
    return factory


def mixed_backends(factories: Sequence[BackendFactory]) -> BackendFactory:
    """Replica i gets factories[i % len(factories)] — e.g. one real engine
    cross-checking a fleet of simulators."""
    if not factories:
        raise ValueError("at least one backend factory is required")
    fs = list(factories)

    def factory(replica_id: int, scheduler: Scheduler,
                lat: LatencyModel, cluster_cfg) -> SteppableBackend:
        return fs[replica_id % len(fs)](replica_id, scheduler, lat,
                                        cluster_cfg)
    return factory


__all__ = ["BackendFactory", "simulator_backend", "engine_backend",
           "speculative_backend", "mixed_backends"]
