"""Fleet routers: which replica does a request land on?

Extends the paper's intra-engine QoE scheduling (§4, Eq. 2 gains) one
level up, in the spirit of DiSCo's dispatching across endpoints
(PAPERS.md, arXiv 2502.11417): the same fluid QoE machinery that prices a
*batch slot* inside one engine prices a *placement* across engines.

Policies:
  * round_robin — classic stateless spreading.
  * jsq         — join-shortest-queue on committed request count
                  (deterministic tie-break: lowest replica id).
  * qoe         — two-level decision. The *predicted marginal fleet QoE
                  gain* of the placement (marginal_qoe_gain: the
                  newcomer's own achievable QoE after KV-overcommit and
                  prefill-backlog delays, minus the fluid-predicted
                  degradation of the replica's live requests) decides
                  whenever replicas diverge by more than `gain_quantum` —
                  a saturated or memory-full replica loses here. Within a
                  gain tie, load balances on the *capability-normalized*
                  queue (committed count over the replica's roofline token
                  rate): on a heterogeneous fleet an A40 with the same
                  queue as an A100 is ~2.5x busier, which count-based JSQ
                  cannot see.

An empirical note that shaped this design (benchmarks/cluster_qoe.py):
with the QoE-aware Andes scheduler *inside* each replica absorbing
placement imperfections (preempting lenient requests under pressure), the
fleet's average QoE is remarkably insensitive to spatial routing among
equally-capable replicas — fancy open-loop placement models lose to plain
queue feedback. The router's edge comes from pricing what feedback cannot
see: replica capability (LatencyModel) and imminent saturation
(FluidQoE-predicted gains).

Every policy sees only `Replica` snapshots/state; none mutate replica
fluid state (the QoE policy queries a clone), preserving the 1-replica
bit-for-bit invariance with the single-node simulator.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core import pricing
from repro.core.pricing import request_weight, shared_token_rate  # noqa: F401
from repro.core.request import Request
from repro.cluster.replica import Replica

# `shared_token_rate` moved to repro.core.pricing (the one QoEPricer
# surface); re-exported above for existing callers.


@dataclasses.dataclass
class RouterConfig:
    horizon: float = 30.0           # prediction horizon Δt (s), fleet scale
    min_remaining_est: float = 64.0  # floor on l̂ − emitted (as scheduler)
    gain_quantum: float = 0.25      # gains within this are considered tied
                                    # and fall through to the normalized-
                                    # queue tiebreak. Gains are decisive
                                    # only for genuine saturation gaps; a
                                    # small quantum would let model noise
                                    # override load feedback (and below
                                    # saturation every replica predicts
                                    # gain 1.0, so with no tiebreak the
                                    # argmax herds onto one replica)


@dataclasses.dataclass
class RouteDecision:
    replica: Replica
    gain: Optional[float] = None    # predicted marginal fleet QoE gain
    scores: Optional[dict] = None   # replica id -> score (qoe policy)


def marginal_qoe_gain(
    replica: Replica,
    req: Request,
    now: float,
    cfg: RouterConfig,
) -> float:
    """Predicted fleet QoE change of placing `req` on `replica` now:

      gain = weight · Q̂_new  −  Σ_live (Q̂_without − Q̂_with)

    The math lives in repro.core.pricing.placement_gain — the same
    implementation the scheduler knapsack and admission controller price
    with. `weight` is the request's contract/priority pricing weight
    (1.0 for uncontracted traffic — the PR 1 gain, bit-for-bit). On an
    idle replica gain ≈ weight (full QoE, nobody hurt); on a saturated
    one it goes negative — the admission controller's shed signal.
    """
    return pricing.placement_gain(
        replica, req, now,
        horizon=cfg.horizon,
        min_remaining_est=cfg.min_remaining_est,
        weight=request_weight(req),
    )


class Router:
    """Base router. `route` never returns a draining replica."""

    name = "base"

    def __init__(self, cfg: Optional[RouterConfig] = None):
        self.cfg = cfg or RouterConfig()

    def route(self, req: Request, replicas: Sequence[Replica],
              now: float) -> RouteDecision:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self, cfg: Optional[RouterConfig] = None):
        super().__init__(cfg)
        self._next = 0

    def route(self, req, replicas, now):
        rep = replicas[self._next % len(replicas)]
        self._next += 1
        return RouteDecision(rep)


class JSQRouter(Router):
    """Join-shortest-queue on committed (live + pending) request count;
    ties go to the lowest replica id (deterministic)."""

    name = "jsq"

    def route(self, req, replicas, now):
        rep = min(replicas, key=lambda r: (len(r.committed()), r.id))
        # scores = the queue depths the decision was taken on, so route
        # trace events are explainable for every policy, not just "qoe".
        return RouteDecision(
            rep, scores={r.id: float(len(r.committed())) for r in replicas})


REFERENCE_BATCH = 16


def capability(replica: Replica) -> float:
    """Roofline token supply (tokens/s) of the replica's hardware at a
    fixed reference batch — a pure per-replica constant, independent of
    current load. Used to normalize queue depth across a heterogeneous
    fleet (4xA100 vs 4xA40 differ ~2.5x)."""
    return REFERENCE_BATCH * replica.lat.token_rate(REFERENCE_BATCH)


def normalized_queue(replica: Replica) -> float:
    """Committed request count over hardware capability: the queue depth
    in units of 'seconds of work per expected token', comparable across
    replicas of different speed."""
    return len(replica.committed()) / max(capability(replica), 1e-9)


class QoEAwareRouter(Router):
    name = "qoe"

    def route(self, req, replicas, now):
        gains = {r.id: marginal_qoe_gain(r, req, now, self.cfg)
                 for r in replicas}
        # lexicographic: quantized gain first; near-ties fall through to
        # the capability-normalized queue, then to the lowest replica id.
        # An additive load penalty would override genuine gain differences
        # under saturation — exactly when the gain signal matters most.
        quantum = max(self.cfg.gain_quantum, 1e-9)
        key = {
            r.id: (round(gains[r.id] / quantum),
                   -normalized_queue(r),
                   -r.id)
            for r in replicas
        }
        best = max(replicas, key=lambda r: key[r.id])
        return RouteDecision(best, gain=gains[best.id], scores=gains)


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "jsq": JSQRouter,
    "qoe": QoEAwareRouter,
}


def make_router(name: str, cfg: Optional[RouterConfig] = None) -> Router:
    return ROUTERS[name](cfg)
