"""Fleet routers: which replica does a request land on?

Extends the paper's intra-engine QoE scheduling (§4, Eq. 2 gains) one
level up, in the spirit of DiSCo's dispatching across endpoints
(PAPERS.md, arXiv 2502.11417): the same fluid QoE machinery that prices a
*batch slot* inside one engine prices a *placement* across engines.

Policies:
  * round_robin — classic stateless spreading.
  * jsq         — join-shortest-queue on committed request count
                  (deterministic tie-break: lowest replica id).
  * qoe         — two-level decision. The *predicted marginal fleet QoE
                  gain* of the placement (marginal_qoe_gain: the
                  newcomer's own achievable QoE after KV-overcommit and
                  prefill-backlog delays, minus the fluid-predicted
                  degradation of the replica's live requests) decides
                  whenever replicas diverge by more than `gain_quantum` —
                  a saturated or memory-full replica loses here. Within a
                  gain tie, load balances on the *capability-normalized*
                  queue (committed count over the replica's roofline token
                  rate): on a heterogeneous fleet an A40 with the same
                  queue as an A100 is ~2.5x busier, which count-based JSQ
                  cannot see.

An empirical note that shaped this design (benchmarks/cluster_qoe.py):
with the QoE-aware Andes scheduler *inside* each replica absorbing
placement imperfections (preempting lenient requests under pressure), the
fleet's average QoE is remarkably insensitive to spatial routing among
equally-capable replicas — fancy open-loop placement models lose to plain
queue feedback. The router's edge comes from pricing what feedback cannot
see: replica capability (LatencyModel) and imminent saturation
(FluidQoE-predicted gains).

Every policy sees only `Replica` snapshots/state; none mutate replica
fluid state (the QoE policy queries a clone), preserving the 1-replica
bit-for-bit invariance with the single-node simulator.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.qoe import predict_request_qoe
from repro.core.request import Request, ReqState
from repro.cluster.replica import Replica


@dataclasses.dataclass
class RouterConfig:
    horizon: float = 30.0           # prediction horizon Δt (s), fleet scale
    min_remaining_est: float = 64.0  # floor on l̂ − emitted (as scheduler)
    gain_quantum: float = 0.25      # gains within this are considered tied
                                    # and fall through to the normalized-
                                    # queue tiebreak. Gains are decisive
                                    # only for genuine saturation gaps; a
                                    # small quantum would let model noise
                                    # override load feedback (and below
                                    # saturation every replica predicts
                                    # gain 1.0, so with no tiebreak the
                                    # argmax herds onto one replica)


@dataclasses.dataclass
class RouteDecision:
    replica: Replica
    gain: Optional[float] = None    # predicted marginal fleet QoE gain
    scores: Optional[dict] = None   # replica id -> score (qoe policy)


def shared_token_rate(
    lat,
    n_live: int,
    total_ctx: float,
    kv_capacity: int,
    state_equiv_tokens: int = 0,
) -> float:
    """Memory-capped, time-shared per-request decode rate (tokens/s).

    A replica with more live requests than fit in KV memory cannot decode
    them concurrently — the scheduler time-shares. The sustainable batch is
    capped by memory (b_mem = M / avg KV weight); the aggregate token rate
    at that batch is then split across *all* live requests. This is what
    makes the marginal cost of one more request real on a saturated
    replica (naive rate(b) vs rate(b+1) is near-zero at large b, which
    would admit forever — the tragedy of the commons the admission
    controller exists to prevent).
    """
    if n_live <= 0:
        return 0.0
    avg_ctx = total_ctx / n_live
    avg_w = state_equiv_tokens if state_equiv_tokens else avg_ctx
    b_mem = max(int(kv_capacity / max(avg_w, 1.0)), 1)
    b_eff = min(n_live, b_mem)
    agg = b_eff / lat.iter_latency(b_eff, int(b_eff * avg_ctx))
    return agg / n_live


def marginal_qoe_gain(
    replica: Replica,
    req: Request,
    now: float,
    cfg: RouterConfig,
) -> float:
    """Predicted fleet QoE change of placing `req` on `replica` now.

    gain = Q̂_new  +  Σ_live (Q̂_with − Q̂_without)

    where Q̂_new is the newcomer's predicted fluid QoE (horizon Δt) and the
    second term is the degradation of the replica's live requests. Two
    harm channels are priced:

      * rate sharing — one more mouth shares the memory-capped token
        supply (shared_token_rate). Thanks to the paper's central slack
        (generation speed ≫ digest speed) this alone rarely hurts;
      * queueing — the newcomer's KV footprint pushes back the start time
        of every *waiting* request. Per-request the extra delay is tiny,
        but summed over a deep queue it outweighs the newcomer's own
        achievable QoE. This is the term that turns the gain negative
        under surge and makes admission control bite.

    On an idle replica gain ≈ 1 (full QoE, nobody hurt); on a saturated
    one it goes negative — the admission controller's shed signal.
    """
    lat = replica.lat
    live = replica.live
    committed = replica.committed()      # live + routed-but-not-yet-admitted
    b = len(committed)
    ctx = sum(r.context_len for r in committed)
    t = max(now, replica.clock)
    dt = cfg.horizon
    mean_out = replica.backend.sched.mean_output_len
    st = replica.backend.sched.cfg.state_equiv_tokens
    M = replica.kv_capacity

    exp_new = max(mean_out, cfg.min_remaining_est)
    demand = replica.kv_demand()
    footprint = req.kv_tokens(st) + (0 if st else int(exp_new))

    rate1 = shared_token_rate(lat, b + 1, ctx + req.prompt_len, M, st)
    # KV-overcommit queueing delay before a waiting request starts: excess
    # demand has to drain (at the aggregate token rate) before its KV fits
    wait1 = max(demand + footprint - M, 0) / max(rate1 * (b + 1), 1e-9)
    # prefill serialization: every committed-but-unprefilled request blocks
    # the engine for its prefill before the newcomer's can run (non-chunked
    # prefill, §2.2). During a burst this is the *leading* congestion
    # signal — KV and rate terms only move once damage is already done —
    # and it is hardware-aware (slow chips prefill slower).
    prefill_backlog = sum(
        lat.prefill_latency(r.context_len)
        for r in committed if not r.prefilled
    )

    # -- degradation of the replica's live requests -------------------------
    # (pending requests contribute to load above but have no fluid slot yet,
    # so only live requests enter the degradation sum)
    degradation = 0.0
    if live:
        rate0 = shared_token_rate(lat, b, ctx, M, st)
        wait0 = max(demand - M, 0) / max(rate0 * b, 1e-9)
        # compact copy of just the live slots (slots are grow-only; cloning
        # the full state would make routing O(total requests) per query)
        idx = np.array([r.fluid_idx for r in live])
        fluid = replica.fluid.clone_slots(idx)
        waiting = np.array([r.state != ReqState.RUNNING for r in live])
        exp_len = fluid.emitted + np.maximum(
            mean_out - fluid.emitted, cfg.min_remaining_est
        )
        d0 = np.where(waiting, wait0, 0.0)
        d1 = np.where(waiting, wait1, 0.0)
        q0 = fluid.predict_qoe(t, dt, rate0, delay=d0, exp_len=exp_len)
        q1 = fluid.predict_qoe(t, dt, rate1, delay=d1, exp_len=exp_len)
        degradation = float(np.sum(q0 - q1))

    # -- the newcomer's own predicted QoE -----------------------------------
    # The request's QoE clock runs from its *arrival* (Eq. 1), not from
    # this routing instant: a deferred request re-enters with dead time on
    # the clock, which must lower its achievable QoE here — otherwise every
    # retry would be re-scored as fresh and over-admitted. Shifting both
    # the delay and the horizon by `age` evaluates the same Eq. 1 window
    # [arrival, arrival + age + Δt] with delivery starting at age + delay.
    age = max(t - req.arrival, 0.0)
    delay = wait1 + prefill_backlog + lat.prefill_latency(req.prompt_len)
    q_new = predict_request_qoe(req.spec, age + delay, rate1, age + dt,
                                exp_new)

    return q_new - degradation


class Router:
    """Base router. `route` never returns a draining replica."""

    name = "base"

    def __init__(self, cfg: Optional[RouterConfig] = None):
        self.cfg = cfg or RouterConfig()

    def route(self, req: Request, replicas: Sequence[Replica],
              now: float) -> RouteDecision:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self, cfg: Optional[RouterConfig] = None):
        super().__init__(cfg)
        self._next = 0

    def route(self, req, replicas, now):
        rep = replicas[self._next % len(replicas)]
        self._next += 1
        return RouteDecision(rep)


class JSQRouter(Router):
    """Join-shortest-queue on committed (live + pending) request count;
    ties go to the lowest replica id (deterministic)."""

    name = "jsq"

    def route(self, req, replicas, now):
        rep = min(replicas, key=lambda r: (len(r.committed()), r.id))
        return RouteDecision(rep)


REFERENCE_BATCH = 16


def capability(replica: Replica) -> float:
    """Roofline token supply (tokens/s) of the replica's hardware at a
    fixed reference batch — a pure per-replica constant, independent of
    current load. Used to normalize queue depth across a heterogeneous
    fleet (4xA100 vs 4xA40 differ ~2.5x)."""
    return REFERENCE_BATCH * replica.lat.token_rate(REFERENCE_BATCH)


def normalized_queue(replica: Replica) -> float:
    """Committed request count over hardware capability: the queue depth
    in units of 'seconds of work per expected token', comparable across
    replicas of different speed."""
    return len(replica.committed()) / max(capability(replica), 1e-9)


class QoEAwareRouter(Router):
    name = "qoe"

    def route(self, req, replicas, now):
        gains = {r.id: marginal_qoe_gain(r, req, now, self.cfg)
                 for r in replicas}
        # lexicographic: quantized gain first; near-ties fall through to
        # the capability-normalized queue, then to the lowest replica id.
        # An additive load penalty would override genuine gain differences
        # under saturation — exactly when the gain signal matters most.
        quantum = max(self.cfg.gain_quantum, 1e-9)
        key = {
            r.id: (round(gains[r.id] / quantum),
                   -normalized_queue(r),
                   -r.id)
            for r in replicas
        }
        best = max(replicas, key=lambda r: key[r.id])
        return RouteDecision(best, gain=gains[best.id], scores=gains)


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "jsq": JSQRouter,
    "qoe": QoEAwareRouter,
}


def make_router(name: str, cfg: Optional[RouterConfig] = None) -> Router:
    return ROUTERS[name](cfg)
