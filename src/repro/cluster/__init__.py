"""QoE-aware multi-replica cluster serving over the Andes engine.

The paper (§4–§6) maximizes QoE *within one* continuous-batching engine;
this package adds the fleet layer a production deployment needs on top:

  replica.py      Replica — one engine behind submit/step/drain (any
                  SteppableBackend: the discrete-event ServingSimulator
                  or the stepped real ServingEngine).
  backends.py     Backend factories — simulator_backend (default),
                  engine_backend (real JAX model per replica, shared
                  weights), mixed_backends (sim + engine in one fleet);
                  selected via ClusterConfig.backend_factory.
  router.py       Round-robin, join-shortest-queue, and a QoE-aware policy
                  that places each request where its predicted marginal
                  fleet QoE gain — priced with the replica's FluidQoE +
                  LatencyModel — is largest (DiSCo-style dispatching).
  admission.py    Shed/defer requests whose admission would *lower* fleet
                  QoE (paper §6.4 graceful degradation, fleet-wide).
  autoscaler.py   Grow/drain the fleet on the §6.1 QoE-SLO attainment
                  signal; draining replicas finish in-flight requests.
  cluster_sim.py  ClusterSimulator — drives N replicas off one arrival
                  trace and reports fleet QoE (shed requests count as 0).
                  Steppable (submit/step/result) since PR 4, so
                  repro.api.ServingClient fronts a whole cluster through
                  the same surface as a bare backend.

All marginal-QoE-gain pricing (router placements, admission thresholds,
autoscaler attainment) flows through repro.core.pricing — one QoEPricer
surface shared with the in-replica scheduler knapsack; per-tenant
SLOContracts weight it (Request.contract / Request.priority).

A 1-replica cluster reproduces the single-node simulator bit-for-bit.
"""
from repro.cluster.admission import AdmissionConfig, AdmissionController
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig, ScaleEvent
from repro.cluster.backends import (
    BackendFactory,
    engine_backend,
    mixed_backends,
    simulator_backend,
    speculative_backend,
)
from repro.cluster.cluster_sim import ClusterConfig, ClusterResult, ClusterSimulator
from repro.cluster.replica import Replica, SteppableBackend
from repro.cluster.router import (
    ROUTERS,
    JSQRouter,
    QoEAwareRouter,
    RoundRobinRouter,
    RouteDecision,
    Router,
    RouterConfig,
    make_router,
    marginal_qoe_gain,
)

__all__ = [
    "Replica", "SteppableBackend",
    "BackendFactory", "simulator_backend", "engine_backend",
    "speculative_backend", "mixed_backends",
    "Router", "RouterConfig", "RouteDecision", "RoundRobinRouter",
    "JSQRouter", "QoEAwareRouter", "ROUTERS", "make_router",
    "marginal_qoe_gain",
    "AdmissionConfig", "AdmissionController",
    "Autoscaler", "AutoscalerConfig", "ScaleEvent",
    "ClusterConfig", "ClusterResult", "ClusterSimulator",
]
