"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first init,
and only the dry-run is allowed to fake 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Axes: "data" carries batch/FSDP, "model" carries tensor/expert
    parallelism; the "pod" axis extends data parallelism across the
    cross-pod (DCN) boundary.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, *, pod: int = 0):
    """Small mesh for tests (requires enough faked host devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The mesh axes that carry the batch (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
