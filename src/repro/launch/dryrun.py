import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this lowers the phase's step function against
ShapeDtypeStruct inputs (no allocation), compiles it for the production
mesh, and records:
  * memory_analysis()      — per-device bytes (arguments / output / temp)
  * cost_analysis()        — per-device HLO FLOPs and bytes accessed
  * collective bytes       — parsed from the compiled HLO (hlo_stats)
  * derived roofline terms — compute / memory / collective seconds on
                             TPU v5e constants (benchmarks/roofline.py
                             renders the table from these JSONs)

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

Shape/phase mapping: train_4k -> train_step, prefill_32k -> prefill,
decode_32k / long_500k -> serve_step (single token vs seq_len-deep cache).
long_500k uses the sliding-window decode variant for attention archs
(sub-quadratic; window from configs), full state for SSM/hybrid.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.configs.base import INPUT_SHAPES
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    make_shardings,
    param_specs,
)
from repro.launch.hlo_stats import collective_stats, flop_stats
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train import build_train_step

# TPU v5e constants (system prompt / DESIGN.md §Roofline)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per link


def _abstract(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
    )


def build_lowering(arch: str, shape_name: str, mesh, *,
                   window_long: bool = True, opt: int = 0,
                   microbatches: int | None = None):
    """Returns (lowered, meta) for the given combination.

    opt=0 is the paper-faithful baseline; opt=1 enables the beyond-paper
    optimizations from EXPERIMENTS.md §Perf (KV-head replication to the TP
    degree for serving shapes; reduced-microbatch FSDP for training).
    """
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    window = None
    if shape_name == "long_500k" and cfg.attn_layer_ids():
        window = cfg.sliding_window          # sub-quadratic decode variant
    kv_repeat = 1
    tp = mesh.shape["model"]
    if opt >= 1 and cfg.num_kv_heads and shape.phase in ("prefill", "decode"):
        if tp % cfg.num_kv_heads == 0 and tp > cfg.num_kv_heads:
            r = tp // cfg.num_kv_heads             # hillclimb #1
            chips = mesh.devices.size
            data = chips // tp
            kv_dev = (shape.seq_len * shape.global_batch
                      * cfg.kv_bytes_per_token() * r) / chips
            # guards (from the blanket-apply sweep, EXPERIMENTS.md §Perf):
            #  - batch must shard on data (B=1 long-context gains nothing),
            #  - replicated KV must stay comfortably in HBM — when KV is
            #    already the memory bound (405B), replication regresses.
            if shape.global_batch % data == 0 and kv_dev < 8e9:
                kv_repeat = r
    # chunked MoE pays at long-sequence *prefill* (hillclimb #3); in
    # training the global dispatch amortizes better (blanket-apply sweep
    # showed 0.64-0.89x regressions) — so prefill only
    moe_chunk = 2048 if (opt >= 1 and cfg.kind == "moe"
                         and shape.phase == "prefill") else 0
    # opt 2: explicit shard_map expert-parallel dispatch (distributed/moe_ep)
    moe_ep = mesh if (opt >= 2 and cfg.kind == "moe"
                      and shape.phase in ("prefill", "train")) else None
    if moe_ep is not None:
        moe_chunk = 0
    model = Model(cfg, impl="ref", window=window, param_dtype=jnp.bfloat16,
                  kv_repeat=kv_repeat, moe_seq_chunk=moe_chunk,
                  moe_ep_mesh=moe_ep)

    params_abs = model.abstract_params()
    p_specs = param_specs(mesh, params_abs)
    p_shard = make_shardings(mesh, p_specs)
    specs = model.input_specs(shape)

    if shape.phase == "train":
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        o_specs = jax.tree.map(lambda _: None, opt_abs)
        # mu/nu shard like params; step replicated
        from jax.sharding import PartitionSpec as P
        o_specs = type(opt_abs)(
            step=P(), mu=p_specs, nu=jax.tree.map(lambda s: s, p_specs)
        )
        o_shard = make_shardings(mesh, o_specs)
        b_specs = batch_specs(mesh, specs, cfg)
        b_shard = make_shardings(mesh, b_specs)
        if microbatches is not None:
            micro = microbatches
        else:
            # 16-sample microbatches; hillclimb #2 showed fewer microbatches
            # barely moves the (activation-dominated) traffic while tripling
            # per-device activation memory — so opt keeps the same default
            micro = max(1, shape.global_batch // 16)
        step = build_train_step(model, OptimizerConfig(), remat=True,
                                microbatches=micro)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        lowered = fn.lower(params_abs, opt_abs, specs)

    elif shape.phase == "prefill":
        b_specs = batch_specs(mesh, specs, cfg)
        b_shard = make_shardings(mesh, b_specs)
        enc_seq = shape.seq_len // 4 if cfg.kind in ("encdec", "audio") else 0
        cache_abs = model.init_cache(
            shape.global_batch, shape.seq_len, enc_seq=enc_seq,
            dtype=jnp.bfloat16, abstract=True,
        )
        c_specs = cache_specs(mesh, cache_abs, cfg)
        c_shard = make_shardings(mesh, c_specs)

        def prefill_fn(params, batch):
            cache = jax.tree.map(
                lambda l: jnp.zeros(l.shape, l.dtype), cache_abs
            )
            return model.prefill(params, batch, cache)

        fn = jax.jit(
            prefill_fn,
            in_shardings=(p_shard, b_shard),
            out_shardings=(None, c_shard),
        )
        lowered = fn.lower(params_abs, specs)

    else:  # decode — serve_step: ONE token against a seq_len cache
        cache_abs = specs["cache"]
        c_specs = cache_specs(mesh, cache_abs, cfg)
        c_shard = make_shardings(mesh, c_specs)
        t_specs = batch_specs(mesh, {"tokens": specs["tokens"]}, cfg)
        t_shard = make_shardings(mesh, t_specs)["tokens"]
        fn = jax.jit(
            model.decode_step,
            in_shardings=(p_shard, t_shard, c_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(2,),
        )
        lowered = fn.lower(params_abs, specs["tokens"], cache_abs)

    return lowered, {"cfg": cfg, "shape": shape, "window": window}


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            mesh=None, verbose: bool = True, opt: int = 0,
            microbatches: int | None = None) -> dict:
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    lowered, meta = build_lowering(arch, shape_name, mesh, opt=opt,
                                   microbatches=microbatches)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax<0.5 wraps the dict in a list
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    colls = collective_stats(hlo_text)
    fstats = flop_stats(hlo_text)
    # cost_analysis counts while (lax.scan) bodies ONCE — correct by the
    # trip-aware/naive dot-flop ratio from the HLO (hlo_stats docstring)
    corr = fstats.correction
    flops = float(cost.get("flops", 0.0)) * corr          # per device
    bytes_acc = float(cost.get("bytes accessed", 0.0)) * corr

    cfg = meta["cfg"]
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    shape = meta["shape"]
    if shape.phase == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.phase == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2.0 * n_active * tokens

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = colls.total_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": f"{'x'.join(str(s) for s in mesh.devices.shape)}"
                f" ({','.join(mesh.axis_names)})",
        "chips": int(chips),
        "phase": shape.phase,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "scan_trip_correction": corr,
        "trip_aware_dot_flops_per_device": fstats.trip_aware_dot_flops,
        "fused_bound_bytes_per_device": fstats.trip_aware_dot_bytes,
        "memory_s_fused_bound": fstats.trip_aware_dot_bytes / HBM_BW,
        "collective_bytes_per_device": colls.total_bytes,
        "collective_counts": colls.count_by_op,
        "collective_bytes_by_op": colls.bytes_by_op,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "roofline": {
            **{k: v for k, v in terms.items()},
            "dominant": dominant,
            "model_flops_global": model_flops,
            "useful_flops_ratio": model_flops / max(flops * chips, 1.0),
        },
        "window": meta["window"],
        "params": n_params,
        "active_params": n_active,
        "opt": opt,
    }
    if verbose:
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes) / 1e9
        print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
              f"compile {t_compile:.0f}s | "
              f"args+temp+out {peak:.2f} GB/dev | "
              f"flops/dev {flops:.3e} | bytes/dev {bytes_acc:.3e} | "
              f"coll {colls.total_bytes:.3e} B | dominant={dominant}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt", type=int, default=0,
                    help="0=paper-faithful baseline, 1=beyond-paper opts")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    combos = []
    if args.all:
        for a in ARCH_IDS:
            if a == "opt-66b":
                continue       # paper model is benchmark-only, not assigned
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in combos:
        tag = "multipod" if args.multi_pod else "pod"
        if args.opt:
            tag += f"_opt{args.opt}"
        path = os.path.join(args.out, f"{arch}_{shape}_{tag}.json")
        if os.path.exists(path):
            print(f"[dryrun] skip (exists): {path}")
            continue
        try:
            res = run_one(arch, shape, multi_pod=args.multi_pod, mesh=mesh,
                          opt=args.opt)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
        except Exception as e:  # noqa: BLE001 — report all failures at end
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"[dryrun] all {len(combos)} combinations lowered and compiled.")


if __name__ == "__main__":
    main()
