"""Parse compiled HLO text for roofline inputs.

``compiled.cost_analysis()`` reports FLOPs/bytes for each op counted ONCE —
`while` bodies (every `lax.scan`: layers, microbatches, CE chunks) are not
multiplied by their trip counts, which undercounts scanned models by orders
of magnitude. This module walks the HLO text instead:

  1. split into computations and build the call graph
     (while condition/body, fusion `calls=`, reduce `to_apply=`, ...);
  2. propagate execution multipliers from ENTRY: a while body executes
     `trip` times (recovered from the `constant(N)` bound in its condition),
     a fusion executes once per callsite execution; nesting multiplies;
  3. trip-aware dot FLOPs: every `dot` contributes
     2 x prod(result shape) x prod(contracted dims) x multiplier;
  4. trip-aware collective bytes: result-shape bytes x op factor x
     multiplier (all-reduce ~2x ring traffic, reduce-scatter ~input size).

The dry-run uses (3)/(naive count) as the correction factor for
cost_analysis FLOPs and bytes (dots dominate both, and loop structure is
shared), and (4) directly as the collective roofline term.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\]")
_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+dot\(%?([\w\.\-]+),\s*%?([\w\.\-]+)\)"
)
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d]


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in _dims(dims):
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comp_lines: Dict[str, List[str]] = {}
    cur = "__preamble__"
    for line in hlo_text.splitlines():
        s = line.rstrip()
        if s and not s.startswith(" ") and s.endswith("{"):
            if s.startswith("ENTRY"):
                cur = "ENTRY"
            else:
                m = re.match(r"^%?([\w\.\-]+)", s)
                cur = m.group(1) if m else s.split()[0]
        comp_lines.setdefault(cur, []).append(line)
    return comp_lines


def _multipliers(comp_lines: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution count of each computation, propagated from ENTRY."""
    # edges: caller -> [(callee, per_call_trip)]
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comp_lines}
    for comp, lines in comp_lines.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                bound = 1
                for cl in comp_lines.get(cond, []):
                    c = _CONST_RE.search(cl)
                    if c:
                        bound = max(bound, int(c.group(1)))
                edges[comp].append((body, float(bound)))
                edges[comp].append((cond, float(bound)))
                continue
            for callee in _CALLS_RE.findall(line):
                if callee in comp_lines:
                    edges[comp].append((callee, 1.0))

    # propagate from ENTRY (call graph is a DAG; iterate to fixpoint)
    mult: Dict[str, float] = {c: 0.0 for c in comp_lines}
    mult["ENTRY"] = 1.0
    for _ in range(32):
        new = {c: 0.0 for c in comp_lines}
        new["ENTRY"] = 1.0
        for comp, out in edges.items():
            m = mult.get(comp, 0.0)
            if m <= 0:
                continue
            for callee, trip in out:
                new[callee] = new.get(callee, 0.0) + m * trip
        new["ENTRY"] = 1.0
        if new == mult:
            break
        mult = new
    return mult


# ---------------------------------------------------------------------------
# Collectives
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    comp_lines = _split_computations(hlo_text)
    mult = _multipliers(comp_lines)
    bytes_by: Dict[str, float] = {}
    count_by: Dict[str, float] = {}
    for comp, lines in comp_lines.items():
        m = mult.get(comp, 0.0)
        if m <= 0:
            continue
        for line in lines:
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            dtype, dims, op = cm.group(1), cm.group(2), cm.group(3)
            b = float(_shape_bytes(dtype, dims))
            g = 2
            gm = _GROUPS_RE.search(line)
            if gm:
                g = max(int(gm.group(2)), 2)
            if op == "all-reduce":
                b *= 2.0 * (g - 1) / g
            elif op == "all-gather":
                b *= (g - 1) / g
            elif op == "reduce-scatter":
                b *= (g - 1)
            bytes_by[op] = bytes_by.get(op, 0.0) + b * m
            count_by[op] = count_by.get(op, 0.0) + m
    return CollectiveStats(bytes_by, count_by)


# ---------------------------------------------------------------------------
# Trip-aware dot FLOPs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FlopStats:
    naive_dot_flops: float       # every dot counted once (cost_analysis-like)
    trip_aware_dot_flops: float  # multiplied by execution counts
    trip_aware_dot_bytes: float = 0.0
    # dot operand+result bytes x execution count: a *fused-TPU lower bound*
    # on HBM traffic (elementwise chains fuse into the matmuls on TPU;
    # the unfused CPU HLO's "bytes accessed" is the upper bound)

    @property
    def correction(self) -> float:
        if self.naive_dot_flops <= 0:
            return 1.0
        return max(self.trip_aware_dot_flops / self.naive_dot_flops, 1.0)


def flop_stats(hlo_text: str) -> FlopStats:
    comp_lines = _split_computations(hlo_text)
    mult = _multipliers(comp_lines)
    naive = 0.0
    aware = 0.0
    dot_bytes = 0.0
    for comp, lines in comp_lines.items():
        m = mult.get(comp, 0.0)
        # symbol table: instruction name -> (dims, dtype)
        shapes: Dict[str, List[int]] = {}
        dtypes: Dict[str, str] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = _dims(dm.group(3))
                dtypes[dm.group(1)] = dm.group(2)
        for line in lines:
            dot = _DOT_RE.search(line)
            if not dot:
                continue
            out_dims = _dims(dot.group(2))
            lhs = shapes.get(dot.group(3))
            rhs = shapes.get(dot.group(4))
            contract = 1
            lc = _LHS_C_RE.search(line)
            if lhs is not None and lc:
                for d in _dims(lc.group(1)):
                    if d < len(lhs):
                        contract *= lhs[d]
            out = 1
            for d in out_dims:
                out *= d
            f = 2.0 * out * contract
            naive += f
            aware += f * max(m, 0.0)
            b = out * _DTYPE_BYTES.get(dot.group(1), 4)
            for opnd, nm in ((lhs, dot.group(3)), (rhs, dot.group(4))):
                if opnd is not None:
                    nel = 1
                    for d in opnd:
                        nel *= d
                    b += nel * _DTYPE_BYTES.get(dtypes.get(nm, "f32"), 4)
            dot_bytes += b * max(m, 0.0)
    return FlopStats(naive, aware, dot_bytes)
