"""Serving launcher: real engine (small models, CPU/TPU) or simulator
(paper-scale deployments).

  python -m repro.launch.serve --mode engine --arch llama3-8b --smoke \\
      --scheduler andes --requests 20
  python -m repro.launch.serve --mode sim --rate 3.6 --requests 1000 \\
      --scheduler andes
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import (
    A100_4X,
    TPU_V5E,
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    make_scheduler,
)
from repro.serving import Request, ServingEngine
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_workload


def run_sim(args) -> None:
    cfg = get_config(args.arch)
    lat = LatencyModel(cfg, A100_4X)
    wl = make_workload(args.requests, args.rate, seed=args.seed,
                       dataset=args.dataset)
    sched = make_scheduler(args.scheduler, args.kv_capacity, lat,
                           SchedulerConfig(objective=args.objective))
    res = ServingSimulator(sched, lat,
                           SimConfig(kv_capacity_tokens=args.kv_capacity)).run(wl)
    q = res.qoes()
    print(f"scheduler={args.scheduler} rate={args.rate} n={args.requests}")
    print(f"  avg QoE        {res.avg_qoe():.3f}  (p10 {np.percentile(q,10):.2f}"
          f" p50 {np.percentile(q,50):.2f})")
    print(f"  TTFT p50/p90   {np.percentile(res.ttfts(),50):.2f}s /"
          f" {np.percentile(res.ttfts(),90):.2f}s")
    print(f"  throughput     {res.throughput():.1f} tok/s")
    print(f"  preemptions    {res.preemption_freq():.2f} /request")


def run_engine(args) -> None:
    import jax

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    from repro.models import Model

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(args.seed)
    wl = []
    for i in range(args.requests):
        plen = int(rng.integers(8, 32))
        wl.append(Request(
            rid=i, arrival=i * 1.0 / args.rate, prompt_len=plen,
            output_len=int(rng.integers(8, 24)),
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    sched = make_scheduler(args.scheduler, args.kv_capacity, lat,
                           SchedulerConfig(objective=args.objective))
    eng = ServingEngine(model, params, sched, lat, num_slots=args.slots,
                        max_seq=args.max_seq,
                        capacity_tokens=args.kv_capacity)
    out = eng.run(wl)
    done = [r for r in out if r.generated >= r.output_len]
    print(f"engine: {len(done)}/{len(wl)} finished, "
          f"{eng.total_tokens} tokens, {eng.preemptions} preemptions, "
          f"avg QoE {np.mean([r.final_qoe() for r in done]):.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sim", "engine"), default="sim")
    ap.add_argument("--arch", default="opt-66b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--scheduler", default="andes",
                    choices=("fcfs", "round_robin", "andes", "andes_dp"))
    ap.add_argument("--objective", default="avg_qoe")
    ap.add_argument("--rate", type=float, default=3.3)
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--kv-capacity", type=int, default=65_000)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.mode == "sim":
        run_sim(args)
    else:
        if args.mode == "engine" and not args.smoke:
            print("note: full configs on CPU are slow; use --smoke")
        args.kv_capacity = min(args.kv_capacity, args.slots * args.max_seq)
        run_engine(args)


if __name__ == "__main__":
    main()
