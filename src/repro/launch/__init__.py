"""Launchers: mesh construction, multi-pod dry-run, serve, train.

NOTE: do not import repro.launch.dryrun from long-lived processes — its
first two lines fake 512 host devices (jax locks the device count on first
init). mesh/serve/train/hlo_stats are safe to import.
"""
from repro.launch.mesh import make_debug_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_debug_mesh"]
