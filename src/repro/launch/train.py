"""Training launcher (single host; the production mesh path is exercised by
launch/dryrun.py).

  python -m repro.launch.train --arch llama3-8b --smoke --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import Model
from repro.training import (
    OptimizerConfig,
    build_train_step,
    init_train_state,
    packed_batches,
    save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params, opt = init_train_state(model, jax.random.PRNGKey(args.seed))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                           total_steps=args.steps)
    step_fn = jax.jit(build_train_step(model, ocfg,
                                       microbatches=args.microbatches))
    data = packed_batches(cfg.vocab_size, args.batch, args.seq, seed=args.seed)

    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % args.log_every == 0 or step == 1:
            toks = args.batch * args.seq * step
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"tok/s {toks / (time.time() - t0):.0f}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, opt, step=args.steps)
        print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
