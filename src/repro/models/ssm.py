"""Mamba-1 and Mamba-2 blocks (train/prefill scan + single-step decode).

State carried per request (the SSM analogue of the KV cache — constant
size, which changes the Andes knapsack weight, see DESIGN.md §4):
  Mamba-1: conv buffer (d_conv-1, d_inner) + scan state (d_inner, N)
  Mamba-2: conv buffer (d_conv-1, d_inner + 2N) + scan state (NH, HD, N)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import normal, rms_norm


# ---------------------------------------------------------------------------
# Causal depthwise conv1d
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, prev=None):
    """x (B, S, C), w (K, C) depthwise causal conv.

    prev: optional (B, K-1, C) left context (for chunk/decode continuity).
    Returns (y (B, S, C), new_prev (B, K-1, C))."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)              # (B, S+K-1, C)
    # depthwise conv as sum of shifted scalings (K is tiny: 4)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    new_prev = xp[:, -(k - 1):, :] if k > 1 else prev
    return y, new_prev


def _conv_step(x_tok: jax.Array, w: jax.Array, prev: jax.Array):
    """One-token conv. x_tok (B, C), prev (B, K-1, C)."""
    k = w.shape[0]
    xp = jnp.concatenate([prev, x_tok[:, None, :]], axis=1)   # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", xp, w)
    return y, xp[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def init_mamba1(rng, cfg: ModelConfig, dtype):
    d, s = cfg.d_model, cfg.ssm
    di = cfg.d_inner
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": normal(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": normal(ks[1], (s.d_conv, di), std=0.1, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": normal(ks[2], (di, dt_rank + 2 * s.d_state), dtype=dtype),
        "dt_proj": normal(ks[3], (dt_rank, di), std=dt_rank ** -0.5, dtype=dtype),
        "dt_bias": jnp.full((di,), -2.0, dtype),   # softplus(-2)≈0.13
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, s.d_state))
        ).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": normal(ks[4], (di, d), dtype=dtype),
    }


def _mamba1_bcd(p, xc, cfg):
    """Project conv output to (dt, B, C)."""
    s = cfg.ssm
    dt_rank = max(cfg.d_model // 16, 1)
    dbc = xc @ p["x_proj"]
    dt_r = dbc[..., :dt_rank]
    B = dbc[..., dt_rank : dt_rank + s.d_state]
    C = dbc[..., dt_rank + s.d_state :]
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"])
    return dt, B, C


def mamba1_apply(p, x, cfg: ModelConfig, *, impl="chunked"):
    """Full-sequence Mamba-1 block. x (B, S, d) -> (B, S, d)."""
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(x_in, p["conv_w"])
    xc = jax.nn.silu(xc + p["conv_b"])
    dt, B, C = _mamba1_bcd(p, xc, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y = ops.selective_scan(
        xc, dt, A, B, C, p["D"].astype(jnp.float32),
        impl=impl, chunk=cfg.ssm.chunk,
    )
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba1_prefill(p, x, cfg: ModelConfig, lengths, *, impl="chunked"):
    """Like apply, but also returns decode state at position lengths-1.

    Right-padded prompts: state must be taken at each request's last valid
    token. We zero dt beyond `lengths` so padding is a no-op for the
    recurrence (exp(0*A)=1, 0*B*x=0) — then the final state is correct."""
    b, s, _ = x.shape
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(x_in, p["conv_w"])
    xc = jax.nn.silu(xc + p["conv_b"])
    dt, B, C = _mamba1_bcd(p, xc, cfg)
    eff_len = lengths if lengths is not None else jnp.full((b,), s)
    if lengths is not None:
        valid = (jnp.arange(s)[None] < lengths[:, None])[..., None]
        dt = jnp.where(valid, dt, 0.0)
    # conv buffer must hold the last K-1 *valid* inputs per request
    conv_prev = _gather_last(x_in, eff_len, p["conv_w"].shape[0] - 1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    # need the final h: rerun scan capturing last state via chunked impl
    y, h_last = _scan_with_state(xc, dt, A, B, C, p["D"], cfg, impl)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], {"h": h_last, "conv": conv_prev}


def _gather_last(x, lengths, k):
    """Last k valid rows of x (B, S, C) given per-request lengths."""
    b, s, c = x.shape
    idx = lengths[:, None] - k + jnp.arange(k)[None]          # (B, k)
    idx = jnp.clip(idx, 0, s - 1)
    gathered = jnp.take_along_axis(x, idx[..., None], axis=1)  # (B, k, C)
    valid = (lengths[:, None] - k + jnp.arange(k)[None]) >= 0
    return jnp.where(valid[..., None], gathered, 0.0).astype(x.dtype)


def _scan_with_state(xc, dt, A, B, C, D, cfg, impl):
    """Selective scan that also returns the final state (for prefill)."""
    bsz, s, d = xc.shape
    n = A.shape[1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * A[None])
        h = dA * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (xc, dt, B, C)
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xc.astype(jnp.float32) * D[None, None].astype(jnp.float32)
    return y.astype(xc.dtype), h_last


def mamba1_decode(p, x_tok, state, cfg: ModelConfig):
    """One-token decode. x_tok (B, d); state {"h": (B,di,N), "conv": (B,K-1,di)}."""
    xz = x_tok @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv = _conv_step(x_in, p["conv_w"], state["conv"])
    xc = jax.nn.silu(xc + p["conv_b"])
    dt, B, C = _mamba1_bcd(p, xc, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h, y = ops.selective_scan_step(
        state["h"], xc.astype(jnp.float32), dt.astype(jnp.float32), A,
        B.astype(jnp.float32), C.astype(jnp.float32), p["D"].astype(jnp.float32),
    )
    y = y.astype(x_tok.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], {"h": h, "conv": conv}


# ---------------------------------------------------------------------------
# Mamba-2
# ---------------------------------------------------------------------------

def init_mamba2(rng, cfg: ModelConfig, dtype):
    d, s = cfg.d_model, cfg.ssm
    di = cfg.d_inner
    nh = di // s.headdim
    conv_dim = di + 2 * s.d_state
    ks = jax.random.split(rng, 4)
    return {
        "in_proj": normal(ks[0], (d, 2 * di + 2 * s.d_state + nh), dtype=dtype),
        "conv_w": normal(ks[1], (s.d_conv, conv_dim), std=0.1, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.full((nh,), -2.0, dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": normal(ks[2], (di, d), dtype=dtype),
    }


def _mamba2_split(p, x, cfg):
    s = cfg.ssm
    di = cfg.d_inner
    nh = di // s.headdim
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * s.d_state]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt, nh


def mamba2_apply(p, x, cfg: ModelConfig, *, impl="chunked", lengths=None,
                 return_state=False):
    """Full-sequence Mamba-2 (SSD) block; optionally returns decode state."""
    s = cfg.ssm
    di = cfg.d_inner
    b, slen, _ = x.shape
    z, xbc, dt, nh = _mamba2_split(p, x, cfg)
    conv_prev = None
    if return_state:
        eff_len = lengths if lengths is not None else jnp.full((b,), slen)
        conv_prev = _gather_last(xbc, eff_len, p["conv_w"].shape[0] - 1)
    xbc_c, _ = _causal_conv(xbc, p["conv_w"])
    xbc_c = jax.nn.silu(xbc_c + p["conv_b"])
    x_in = xbc_c[..., :di].reshape(b, slen, nh, s.headdim)
    B = xbc_c[..., di : di + s.d_state]
    C = xbc_c[..., di + s.d_state :]
    dt = jax.nn.softplus(dt + p["dt_bias"])
    if lengths is not None:
        valid = jnp.arange(slen)[None] < lengths[:, None]
        dt = jnp.where(valid[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if return_state:
        y, h_last = _ssd_with_state(x_in, dt, A, B, C, p["D"])
    else:
        y = ops.ssd(
            x_in, dt, A, B, C, p["D"].astype(jnp.float32),
            impl=impl, chunk=s.chunk,
        )
        h_last = None
    y = y.reshape(b, slen, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"h": h_last, "conv": conv_prev}
    return out


def _ssd_with_state(x, dt, A, B, C, D):
    bsz, s, nh, hd = x.shape
    n = B.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t * A[None])
        h = da[..., None, None] * h + dt_t[..., None, None] * x_t[..., None] * b_t[:, None, None, :]
        y = jnp.einsum("bhdn,bn->bhd", h, c_t)
        return h, y

    h0 = jnp.zeros((bsz, nh, hd, n), jnp.float32)
    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (x, dt, B, C)
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_last


def mamba2_prefill(p, x, cfg: ModelConfig, lengths, *, impl="chunked"):
    return mamba2_apply(p, x, cfg, impl=impl, lengths=lengths, return_state=True)


def mamba2_decode(p, x_tok, state, cfg: ModelConfig):
    """One-token decode. x_tok (B, d)."""
    s = cfg.ssm
    di = cfg.d_inner
    b = x_tok.shape[0]
    z, xbc, dt, nh = _mamba2_split(p, x_tok[:, None, :], cfg)
    z, xbc, dt = z[:, 0], xbc[:, 0], dt[:, 0]
    xbc_c, conv = _conv_step(xbc, p["conv_w"], state["conv"])
    xbc_c = jax.nn.silu(xbc_c + p["conv_b"])
    x_in = xbc_c[..., :di].reshape(b, nh, s.headdim)
    B = xbc_c[..., di : di + s.d_state]
    C = xbc_c[..., di + s.d_state :]
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h, y = ops.ssd_step(
        state["h"], x_in.astype(jnp.float32), dt.astype(jnp.float32), A,
        B.astype(jnp.float32), C.astype(jnp.float32), p["D"].astype(jnp.float32),
    )
    y = y.reshape(b, di).astype(x_tok.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    return y @ p["out_proj"], {"h": h, "conv": conv}
