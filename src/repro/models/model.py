"""Unified model API: init / train / prefill / decode / input_specs.

This is the single surface the rest of the framework talks to:

  model = Model(cfg, impl="ref")
  params = model.init(rng)                      # or model.abstract_params()
  logits, aux = model.forward_train(params, batch)
  loss = model.loss(params, batch)
  cache = model.init_cache(batch=B, max_seq=S)
  logits, cache = model.prefill(params, batch, cache)
  logits, cache = model.decode_step(params, tokens, cache)   # "serve_step"

`input_specs(shape)` returns ShapeDtypeStruct stand-ins for every input of
the phase's step function — the dry-run lowers against these without
allocating anything.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import cache as cache_lib
from repro.models import transformer as tfm


class Model:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        impl: str = "ref",
        scan_impl: str = "chunked",
        window: Optional[int] = None,
        param_dtype=jnp.float32,
        remat: bool = False,
        kv_repeat: int = 1,
        moe_seq_chunk: int = 0,
        moe_ep_mesh=None,
    ):
        self.cfg = cfg
        self.impl = impl
        self.scan_impl = scan_impl
        self.window = window
        self.param_dtype = param_dtype
        self.remat = remat
        # KV-head replication to the TP degree (serving optimization,
        # EXPERIMENTS.md §Perf hillclimb #1); 1 = paper-faithful baseline
        self.kv_repeat = kv_repeat
        # sequence-chunked MoE dispatch (hillclimb #3); 0 = baseline
        self.moe_seq_chunk = moe_seq_chunk
        # shard_map expert-parallel dispatch (distributed/moe_ep.py); None =
        # GSPMD-compiled dispatch
        self.moe_ep_mesh = moe_ep_mesh

    # ------------------------------------------------------------------ init
    def init(self, rng, dtype=None):
        return tfm.init_params(rng, self.cfg, dtype or self.param_dtype)

    def abstract_params(self, dtype=None):
        dt = dtype or self.param_dtype
        return jax.eval_shape(
            lambda r: tfm.init_params(r, self.cfg, dt), jax.random.PRNGKey(0)
        )

    # ----------------------------------------------------------------- train
    def forward_train(self, params, batch):
        return tfm.forward(
            params, self.cfg, batch, impl=self.impl, scan_impl=self.scan_impl,
            window=self.window, remat=self.remat,
            moe_seq_chunk=self.moe_seq_chunk, moe_ep_mesh=self.moe_ep_mesh,
        )

    def loss(self, params, batch, *, ce_chunk: int = 1024):
        """Next-token cross-entropy (labels < 0 are masked) + MoE aux.

        The CE is computed *chunked over the sequence* with per-chunk remat:
        the (tokens, vocab) logits tensor — by far the largest activation at
        128k vocab x 1M tokens — never materializes beyond one chunk.
        """
        h, aux = tfm.forward(
            params, self.cfg, batch, impl=self.impl, scan_impl=self.scan_impl,
            window=self.window, remat=self.remat, return_hidden=True,
            moe_seq_chunk=self.moe_seq_chunk, moe_ep_mesh=self.moe_ep_mesh,
        )
        labels = batch["labels"]
        b, s, d = h.shape
        chunk = min(ce_chunk, s)
        while s % chunk:
            chunk //= 2
        n = s // chunk
        hs = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
        ls = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)

        def ce_chunk_fn(carry, xs):
            hc, lc = xs
            logits = tfm.unembed(params, hc).astype(jnp.float32)
            mask = lc >= 0
            lab = jnp.maximum(lc, 0)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
            nll = jnp.where(mask, nll, 0.0)
            return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mask)), None

        body = ce_chunk_fn if not self.remat else jax.checkpoint(
            ce_chunk_fn, policy=jax.checkpoint_policies.nothing_saveable
        )
        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
        return tot / jnp.maximum(cnt, 1) + aux

    # ----------------------------------------------------------------- serve
    def enc_seq(self, max_seq: int) -> int:
        """Encoder-memory depth a serving cache reserves next to a
        `max_seq`-token decoder context (0 for everything but
        encoder-decoder/audio). THE one copy of the ratio — the engine's
        cache construction, both its prefill paths, and input_specs all
        must agree or encoder frames pad/mask to mismatched shapes."""
        return max_seq // 4 if self.cfg.kind in ("encdec", "audio") else 0

    def init_cache(self, batch: int, max_seq: int, *, enc_seq: int = 0,
                   dtype=jnp.float32, abstract: bool = False):
        return cache_lib.init_cache(
            self.cfg, batch, max_seq, enc_seq=enc_seq, dtype=dtype,
            abstract=abstract, kv_repeat=self.kv_repeat,
        )

    def supports_physical_paging(self) -> bool:
        return cache_lib.supports_physical_paging(self.cfg)

    def init_paged_cache(self, batch: int, num_pages: int, page_size: int,
                         max_seq: int, *, dtype=jnp.float32,
                         abstract: bool = False):
        """Physically paged decode cache: a (num_pages, page_size)-shaped
        KV pool shared across slots plus per-slot block tables (see
        models/cache.py). decode_step / decode_multi / decode_persistent
        route through the paged attention path automatically — the cache
        pytree's structure is the dispatch."""
        return cache_lib.init_paged_cache(
            self.cfg, batch, num_pages, page_size, max_seq, dtype=dtype,
            abstract=abstract, kv_repeat=self.kv_repeat,
        )

    def prefill(self, params, batch, cache):
        """Run the prompt, fill the cache, return last-token logits.

        batch: {"tokens": (B, S) [, "lengths": (B,), "frames", "patch_embeds"]}
        cache: from init_cache (max_seq >= S). Returns (logits (B, V), cache').
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((b,), s, jnp.int32)

        n_patch = 0
        fwd_batch = dict(batch)
        if cfg.kind == "vlm" and "patch_embeds" in batch:
            n_patch = batch["patch_embeds"].shape[1]
        ctx_lengths = lengths + n_patch      # cache positions incl. patches

        if cfg.kind in ("encdec", "audio"):
            logits, _, parts = tfm.forward(
                params, cfg, fwd_batch, impl=self.impl, scan_impl=self.scan_impl,
                collect_cache=True, lengths=lengths,
            )
            cache = dict(cache, cross_k=parts["cross_k"], cross_v=parts["cross_v"],
                         enc_length=batch.get(
                             "enc_lengths",
                             jnp.full((b,), batch["frames"].shape[1], jnp.int32)))
        else:
            logits, _, parts = tfm.forward(
                params, cfg, fwd_batch, impl=self.impl, scan_impl=self.scan_impl,
                window=self.window, collect_cache=True, lengths=ctx_lengths,
                kv_repeat=self.kv_repeat, moe_seq_chunk=self.moe_seq_chunk,
                moe_ep_mesh=self.moe_ep_mesh,
            )

        # write collected per-layer tensors into the (max_seq-sized) cache
        if "k" in parts:
            cache = dict(
                cache,
                k=jax.lax.dynamic_update_slice(
                    cache["k"], parts["k"].astype(cache["k"].dtype), (0,) * cache["k"].ndim
                ),
                v=jax.lax.dynamic_update_slice(
                    cache["v"], parts["v"].astype(cache["v"].dtype), (0,) * cache["v"].ndim
                ),
            )
        if "ssm_h" in parts:
            cache = dict(cache, ssm_h=parts["ssm_h"],
                         ssm_conv=parts["ssm_conv"].astype(cache["ssm_conv"].dtype))

        cache = dict(cache, length=ctx_lengths.astype(jnp.int32))
        # last valid logit per request (logits cover text positions only)
        last = jnp.clip(lengths - 1, 0, logits.shape[1] - 1)
        logits_last = jnp.take_along_axis(
            logits, last[:, None, None], axis=1
        )[:, 0]
        return logits_last, cache

    def decode_step(self, params, tokens, cache):
        """tokens (B,) int32 -> (logits (B, V), cache')."""
        return tfm.decode_step(
            params, self.cfg, tokens, cache, impl=self.impl,
            window=self.window, kv_repeat=self.kv_repeat,
        )

    def decode_tokens(self, params, tokens, cache):
        """Fused greedy decode: tokens (B,) int32 -> (next_ids (B,), cache').

        Same forward as `decode_step` with the vocab-sized argmax taken
        on-device, so a jitted serving loop ships (B,) int32 to host instead
        of (B, V) float32 — the per-iteration host transfer shrinks by a
        factor of vocab_size. Greedy ties break identically to a host-side
        `jnp.argmax` over the `decode_step` logits (first max wins), which
        is the losslessness foundation tests/test_hotpath.py pins."""
        logits, cache = self.decode_step(params, tokens, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def decode_multi(self, params, tokens, cache, j: int):
        """j fused greedy decode iterations in one `lax.scan`:
        tokens (B,) int32 -> (ids (j, B) int32, cache').

        Step 0 consumes `tokens` (the last committed token per slot); every
        later step consumes its own argmax — exactly the serving engine's
        host-side feedback loop, minus j-1 host↔device round-trips. Static
        j (jit recompiles per value; the engine quantizes j to a small
        power-of-two grid to bound compile count). The scan form is
        bit-identical to j sequential `decode_step` calls on this stack —
        the same identity `verify_step` already relies on."""
        def body(carry, _):
            tok, c = carry
            logits, c = self.decode_step(params, tok, c)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (nxt, c), nxt

        (_, cache), toks = jax.lax.scan(
            body, (tokens, cache), None, length=j
        )
        return toks, cache

    def decode_persistent(self, params, tokens, cache, j, active,
                          *, j_cap: int, eos_id: int = -1):
        """Device-resident persistent decode loop (`lax.while_loop`).

        Decodes up to `j` greedy iterations without any host round-trip:
        tokens (B,) int32, j a *dynamic* i32 scalar bounded by the static
        `j_cap` (the out-buffer depth — one compiled loop serves every
        block size, where `decode_multi`'s static-j scan recompiles per
        value and forces the engine to quantize). `active` (B,) bool marks
        the slots whose progress matters; with eos_id >= 0 the loop ALSO
        stops as soon as every active slot has emitted EOS, so a block cut
        short by end-of-sequence costs only the iterations it commits
        instead of the full scan depth.

        The body is `decode_step` + argmax — the exact scan body of
        `decode_multi` — so the first `steps` rows of `ids` are
        bit-identical to the scan and to sequential single-step decode
        (tests/test_persistent_loop.py pins both identities). Iterations a
        slot's EOS invalidates are rolled back by the caller through
        `length` alone (models/cache.py rollback contract); rows of `ids`
        past `steps` are zeros and must not be read.

        Returns (ids (j_cap, B) int32, cache', steps i32)."""
        b = tokens.shape[0]

        def cond(carry):
            step, _tok, _c, _out, alive = carry
            return jnp.logical_and(step < j, jnp.any(alive))

        def body(carry):
            step, tok, c, out, alive = carry
            logits, c = self.decode_step(params, tok, c)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out = out.at[step].set(nxt)
            if eos_id >= 0:
                alive = jnp.logical_and(alive, nxt != eos_id)
            return step + 1, nxt, c, out, alive

        carry0 = (
            jnp.asarray(0, jnp.int32),
            tokens,
            cache,
            jnp.zeros((j_cap, b), jnp.int32),
            jnp.asarray(active, bool),
        )
        steps, _, cache, ids, _ = jax.lax.while_loop(cond, body, carry0)
        return ids, cache, steps

    def verify_step(self, params, tokens, cache):
        """Speculative-decoding verify: tokens (B, T) int32 ->
        (logits (B, T, V), cache'). One jitted call covering the whole
        proposal window, bit-identical to T sequential decode_step calls
        (see transformer.verify_step for why that identity is the point)."""
        return tfm.verify_step(
            params, self.cfg, tokens, cache, impl=self.impl,
            window=self.window, kv_repeat=self.kv_repeat,
        )

    def propose_step(self, params, tokens, cache, k: int):
        """Draft-side greedy proposal: tokens (B,) int32 ->
        (proposals (B, k+1), cache'). Static k (jit recompiles per k)."""
        return tfm.propose_step(
            params, self.cfg, tokens, cache, k, impl=self.impl,
            window=self.window, kv_repeat=self.kv_repeat,
        )

    # ------------------------------------------------------------- dry-run IO
    def input_specs(self, shape: ShapeConfig, *, act_dtype=jnp.bfloat16):
        """ShapeDtypeStruct stand-ins for the phase's step function inputs."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32

        def tok(bb, ss):
            return jax.ShapeDtypeStruct((bb, ss), i32)

        if shape.phase == "train":
            if cfg.kind in ("encdec", "audio"):
                dec = s // 4
                return {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), act_dtype),
                    "tokens": tok(b, dec),
                    "labels": tok(b, dec),
                }
            if cfg.kind == "vlm":
                p = min(1024, s // 4)
                return {
                    "tokens": tok(b, s - p),
                    "patch_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model), act_dtype),
                    "labels": tok(b, s - p),
                }
            return {"tokens": tok(b, s), "labels": tok(b, s)}

        if shape.phase == "prefill":
            if cfg.kind in ("encdec", "audio"):
                return {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), act_dtype),
                    "tokens": tok(b, 1),
                }
            if cfg.kind == "vlm":
                p = min(1024, s // 4)
                return {
                    "tokens": tok(b, s - p),
                    "patch_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model), act_dtype),
                }
            return {"tokens": tok(b, s)}

        # decode: one token against a seq_len-deep cache
        enc_seq = self.enc_seq(s)
        return {
            "tokens": jax.ShapeDtypeStruct((b,), i32),
            "cache": self.init_cache(
                b, s, enc_seq=enc_seq, dtype=act_dtype, abstract=True
            ),
        }
