"""Architecture assembly: init + forward/prefill/decode for every family.

Layers are *stacked* (leading axis = layer) and traversed with `lax.scan`,
MaxText-style, so the 126-layer Llama-3-405B lowers to a compact HLO while
the per-layer math stays identical to an unrolled loop. The hybrid
(Zamba2-style) arch scans over "rounds": (attn_every − 1) Mamba-2 layers
followed by one *weight-shared* attention+MLP block.

All functions are pure; ``impl`` picks the attention/scan implementation
("ref" XLA for dry-run/CPU, "pallas" for TPU kernels, "chunked" for XLA
scan forms).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    embed_apply,
    init_embed,
    init_mlp,
    mlp_apply,
    normal,
    rms_norm,
    unembed,
)

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(rng, 8)
    params = {
        "embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": {"scale": jnp.ones((cfg.d_model,), dtype)},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "kernel": normal(ks[1], (cfg.d_model, cfg.vocab_size), dtype=dtype)
        }

    def stack(init_fn, n, rng_):
        return jax.vmap(lambda r: init_fn(r))(jax.random.split(rng_, n))

    L, d = cfg.num_layers, cfg.d_model

    if cfg.kind in ("dense", "vlm", "moe"):
        blocks = {
            "attn_norm_scale": jnp.ones((L, d), dtype),
            "attn": stack(lambda r: attn.init_attn(r, cfg, dtype), L, ks[2]),
            "mlp_norm_scale": jnp.ones((L, d), dtype),
        }
        if cfg.kind == "moe":
            blocks["moe"] = stack(lambda r: moe_lib.init_moe(r, cfg, dtype), L, ks[3])
        else:
            blocks["mlp"] = stack(
                lambda r: init_mlp(r, d, cfg.d_ff, cfg.gated_mlp, dtype), L, ks[3]
            )
        params["blocks"] = blocks
        if cfg.kind == "vlm":
            params["vision_proj"] = {"kernel": normal(ks[4], (d, d), dtype=dtype)}

    elif cfg.kind == "ssm":
        params["blocks"] = {
            "norm_scale": jnp.ones((L, d), dtype),
            "mamba": stack(lambda r: ssm_lib.init_mamba1(r, cfg, dtype), L, ks[2]),
        }

    elif cfg.kind == "hybrid":
        every = cfg.hybrid_attn_every
        assert L % every == 0, (L, every)
        rounds, per_round = L // every, every - 1

        def round_mamba(r):
            return jax.vmap(lambda rr: ssm_lib.init_mamba2(rr, cfg, dtype))(
                jax.random.split(r, per_round)
            )

        params["rounds"] = {
            "norm_scale": jnp.ones((rounds, per_round, d), dtype),
            "mamba": stack(round_mamba, rounds, ks[2]),
        }
        params["shared"] = {
            "attn_norm_scale": jnp.ones((d,), dtype),
            "attn": attn.init_attn(ks[3], cfg, dtype),
            "mlp_norm_scale": jnp.ones((d,), dtype),
            "mlp": init_mlp(ks[4], d, cfg.d_ff, cfg.gated_mlp, dtype),
        }

    elif cfg.kind in ("encdec", "audio"):
        Le = cfg.num_encoder_layers
        params["enc_blocks"] = {
            "attn_norm_scale": jnp.ones((Le, d), dtype),
            "attn": stack(lambda r: attn.init_attn(r, cfg, dtype), Le, ks[2]),
            "mlp_norm_scale": jnp.ones((Le, d), dtype),
            "mlp": stack(
                lambda r: init_mlp(r, d, cfg.d_ff, cfg.gated_mlp, dtype), Le, ks[3]
            ),
        }
        params["enc_norm"] = {"scale": jnp.ones((d,), dtype)}
        params["dec_blocks"] = {
            "self_norm_scale": jnp.ones((L, d), dtype),
            "self_attn": stack(lambda r: attn.init_attn(r, cfg, dtype), L, ks[4]),
            "cross_norm_scale": jnp.ones((L, d), dtype),
            "cross_attn": stack(lambda r: attn.init_attn(r, cfg, dtype), L, ks[5]),
            "mlp_norm_scale": jnp.ones((L, d), dtype),
            "mlp": stack(
                lambda r: init_mlp(r, d, cfg.d_ff, cfg.gated_mlp, dtype), L, ks[6]
            ),
        }
    else:
        raise ValueError(cfg.kind)
    return params


# ---------------------------------------------------------------------------
# Decoder-only (dense / vlm / moe / ssm / hybrid) full-sequence forward
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch):
    """Token (+ modality) embedding. Returns h (B, S, d)."""
    h = embed_apply(params["embed"], batch["tokens"])
    if cfg.kind == "vlm" and "patch_embeds" in batch:
        vis = batch["patch_embeds"] @ params["vision_proj"]["kernel"]
        h = jnp.concatenate([vis.astype(h.dtype), h], axis=1)
    return h


def _maybe_remat(fn, remat: bool):
    """Per-layer rematerialization: inside the layer scan, save only the
    residual-stream carry; recompute everything else on the backward pass.
    This is the policy that lets train_4k on the big archs lower with sane
    per-device activation memory (EXPERIMENTS.md §Dry-run)."""
    if not remat:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def forward(
    params,
    cfg: ModelConfig,
    batch,
    *,
    impl: str = "ref",
    scan_impl: str = "chunked",
    window: Optional[int] = None,
    collect_cache: bool = False,
    lengths=None,
    remat: bool = False,
    return_hidden: bool = False,
    kv_repeat: int = 1,
    moe_seq_chunk: int = 0,
    moe_ep_mesh=None,
):
    """Full-sequence forward for decoder-only archs.

    Returns (logits, aux_loss) or, with collect_cache, (logits, aux, cache_kv)
    where cache_kv holds per-application (k, v [, ssm states]). With
    return_hidden, logits are NOT computed: returns (h, aux) so the caller
    can do chunked cross-entropy (Model.loss).
    """
    if cfg.kind in ("encdec", "audio"):
        return _forward_encdec(
            params, cfg, batch, impl=impl, collect_cache=collect_cache,
            lengths=lengths, remat=remat, return_hidden=return_hidden,
        )

    h = _embed_inputs(params, cfg, batch)
    b, s, d = h.shape
    valid = None
    if lengths is not None:
        valid = jnp.arange(s)[None] < lengths[:, None]

    if cfg.kind in ("dense", "vlm", "moe"):
        def block(h, bp):
            x = rms_norm(h, bp["attn_norm_scale"], cfg.norm_eps)
            if collect_cache:
                a, k, v = attn.attn_prefill(
                    bp["attn"], x, cfg, window=window, lengths=lengths,
                    impl=impl, kv_repeat=kv_repeat,
                )
            else:
                a = attn.attn_train(
                    bp["attn"], x, cfg, window=window, lengths=lengths, impl=impl
                )
                k = v = jnp.zeros((), h.dtype)
            h = h + a
            x = rms_norm(h, bp["mlp_norm_scale"], cfg.norm_eps)
            if cfg.kind == "moe":
                if moe_ep_mesh is not None:
                    from repro.distributed.moe_ep import moe_apply_ep
                    y, aux = moe_apply_ep(
                        bp["moe"], x, cfg, moe_ep_mesh, valid=valid
                    )
                elif moe_seq_chunk:
                    y, aux = moe_lib.moe_apply_chunked(
                        bp["moe"], x, cfg, valid=valid, seq_chunk=moe_seq_chunk
                    )
                else:
                    y, aux = moe_lib.moe_apply(bp["moe"], x, cfg, valid=valid)
            else:
                y, aux = mlp_apply(bp["mlp"], x), jnp.zeros((), jnp.float32)
            return h + y, (aux, k, v)

        h, (auxs, ks, vs) = jax.lax.scan(
            _maybe_remat(block, remat), h, params["blocks"]
        )
        cache_parts = {"k": ks, "v": vs}

    elif cfg.kind == "ssm":
        def block(h, bp):
            x = rms_norm(h, bp["norm_scale"], cfg.norm_eps)
            if collect_cache:
                y, st = ssm_lib.mamba1_prefill(
                    bp["mamba"], x, cfg, lengths, impl=scan_impl
                )
            else:
                y = ssm_lib.mamba1_apply(bp["mamba"], x, cfg, impl=scan_impl)
                st = {"h": jnp.zeros((), jnp.float32), "conv": jnp.zeros((), h.dtype)}
            return h + y, (jnp.zeros((), jnp.float32), st)

        h, (auxs, states) = jax.lax.scan(
            _maybe_remat(block, remat), h, params["blocks"]
        )
        cache_parts = {"ssm_h": states["h"], "ssm_conv": states["conv"]}

    elif cfg.kind == "hybrid":
        shared = params["shared"]

        def apply_shared(h):
            x = rms_norm(h, shared["attn_norm_scale"], cfg.norm_eps)
            if collect_cache:
                a, k, v = attn.attn_prefill(
                    shared["attn"], x, cfg, window=window, lengths=lengths,
                    impl=impl, kv_repeat=kv_repeat,
                )
            else:
                a = attn.attn_train(
                    shared["attn"], x, cfg, window=window, lengths=lengths, impl=impl
                )
                k = v = jnp.zeros((), h.dtype)
            h = h + a
            x = rms_norm(h, shared["mlp_norm_scale"], cfg.norm_eps)
            return h + mlp_apply(shared["mlp"], x), k, v

        def mamba_layer(h, lp):
            x = rms_norm(h, lp["norm_scale"], cfg.norm_eps)
            if collect_cache:
                y, st = ssm_lib.mamba2_prefill(lp["mamba"], x, cfg, lengths, impl=scan_impl)
            else:
                y = ssm_lib.mamba2_apply(lp["mamba"], x, cfg, impl=scan_impl)
                st = {"h": jnp.zeros((), jnp.float32), "conv": jnp.zeros((), h.dtype)}
            return h + y, st

        def round_fn(h, rp):
            h, states = jax.lax.scan(mamba_layer, h, rp)
            h, k, v = apply_shared(h)
            return h, (states, k, v)

        h, (states, ks, vs) = jax.lax.scan(
            _maybe_remat(round_fn, remat), h, params["rounds"]
        )
        auxs = jnp.zeros((1,), jnp.float32)
        if collect_cache:
            # (R, per_round, ...) -> (R*per_round, ...)
            flat = jax.tree.map(
                lambda t: t.reshape((-1,) + t.shape[2:]), states
            )
            cache_parts = {
                "ssm_h": flat["h"], "ssm_conv": flat["conv"], "k": ks, "v": vs
            }
        else:
            cache_parts = {}
    else:
        raise ValueError(cfg.kind)

    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.kind == "vlm" and "patch_embeds" in batch:
        h = h[:, batch["patch_embeds"].shape[1]:]   # logits over text positions
    aux = jnp.sum(auxs)
    if return_hidden:
        return h, aux
    logits = unembed(params, h)
    if collect_cache:
        return logits, aux, cache_parts
    return logits, aux


# ---------------------------------------------------------------------------
# Encoder-decoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, enc_inputs, enc_lengths=None, *, impl="ref",
           remat=False):
    """Encoder stack over frame embeddings (audio stub) — bidirectional."""
    h = enc_inputs

    def block(h, bp):
        x = rms_norm(h, bp["attn_norm_scale"], cfg.norm_eps)
        h = h + attn.attn_train(
            bp["attn"], x, cfg, causal=False, lengths=enc_lengths, impl=impl
        )
        x = rms_norm(h, bp["mlp_norm_scale"], cfg.norm_eps)
        return h + mlp_apply(bp["mlp"], x), None

    h, _ = jax.lax.scan(_maybe_remat(block, remat), h, params["enc_blocks"])
    return rms_norm(h, params["enc_norm"]["scale"], cfg.norm_eps)


def _forward_encdec(
    params, cfg: ModelConfig, batch, *, impl="ref", collect_cache=False,
    lengths=None, remat=False, return_hidden=False,
):
    enc_out = encode(
        params, cfg, batch["frames"], batch.get("enc_lengths"), impl=impl,
        remat=remat,
    )
    h = embed_apply(params["embed"], batch["tokens"])
    enc_lengths = batch.get("enc_lengths")

    def block(h, bp):
        x = rms_norm(h, bp["self_norm_scale"], cfg.norm_eps)
        if collect_cache:
            a, k, v = attn.attn_prefill(
                bp["self_attn"], x, cfg, lengths=lengths, impl=impl
            )
        else:
            a = attn.attn_train(bp["self_attn"], x, cfg, lengths=lengths, impl=impl)
            k = v = jnp.zeros((), h.dtype)
        h = h + a
        x = rms_norm(h, bp["cross_norm_scale"], cfg.norm_eps)
        ck, cv = attn.cross_attn_kv(bp["cross_attn"], enc_out, cfg)
        h = h + attn.cross_attn_apply(
            bp["cross_attn"], x, ck, cv, enc_lengths, cfg, impl=impl
        )
        x = rms_norm(h, bp["mlp_norm_scale"], cfg.norm_eps)
        return h + mlp_apply(bp["mlp"], x), (k, v, ck, cv)

    h, (ks, vs, cks, cvs) = jax.lax.scan(
        _maybe_remat(block, remat), h, params["dec_blocks"]
    )
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if return_hidden:
        return h, aux
    logits = unembed(params, h)
    if collect_cache:
        return logits, aux, {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}
    return logits, aux


# ---------------------------------------------------------------------------
# Decode step (single new token against the cache)
# ---------------------------------------------------------------------------

def decode_step(
    params,
    cfg: ModelConfig,
    tokens,            # (B,) int32 — current input token per slot
    cache,
    *,
    impl: str = "ref",
    window: Optional[int] = None,
    kv_repeat: int = 1,
):
    """One decode iteration. Returns (logits (B, V), cache')."""
    lengths = cache["length"]
    h = embed_apply(params["embed"], tokens)            # (B, d)

    if cfg.kind in ("dense", "vlm", "moe"):
        # static layout branch: a physically paged cache (block_tables in
        # the pytree) routes through the paged pool; the table itself is
        # layer-invariant, so it rides in as a scan closure, not an xs
        paged = "block_tables" in cache

        def block(h, xs):
            bp, kc, vc = xs
            x = rms_norm(h, bp["attn_norm_scale"], cfg.norm_eps)
            if paged:
                a, kc, vc = attn.attn_decode_paged(
                    bp["attn"], x, kc, vc, cache["block_tables"], lengths,
                    cfg, window=window, impl=impl, kv_repeat=kv_repeat,
                )
            else:
                a, kc, vc = attn.attn_decode(
                    bp["attn"], x, kc, vc, lengths, cfg, window=window,
                    impl=impl, kv_repeat=kv_repeat,
                )
            h = h + a
            x = rms_norm(h, bp["mlp_norm_scale"], cfg.norm_eps)
            if cfg.kind == "moe":
                y, _ = moe_lib.moe_apply(bp["moe"], x[:, None, :], cfg)
                y = y[:, 0]
            else:
                y = mlp_apply(bp["mlp"], x)
            return h + y, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            block, h, (params["blocks"], cache["k"], cache["v"])
        )
        cache = dict(cache, k=ks, v=vs)

    elif cfg.kind == "ssm":
        def block(h, xs):
            bp, hh, cv = xs
            x = rms_norm(h, bp["norm_scale"], cfg.norm_eps)
            y, st = ssm_lib.mamba1_decode(bp["mamba"], x, {"h": hh, "conv": cv}, cfg)
            return h + y, (st["h"], st["conv"])

        h, (hs, convs) = jax.lax.scan(
            block, h, (params["blocks"], cache["ssm_h"], cache["ssm_conv"])
        )
        cache = dict(cache, ssm_h=hs, ssm_conv=convs)

    elif cfg.kind == "hybrid":
        shared = params["shared"]
        rounds = params["rounds"]["mamba"]["in_proj"].shape[0]
        per_round = params["rounds"]["mamba"]["in_proj"].shape[1]
        ssm_h = cache["ssm_h"].reshape((rounds, per_round) + cache["ssm_h"].shape[1:])
        ssm_conv = cache["ssm_conv"].reshape(
            (rounds, per_round) + cache["ssm_conv"].shape[1:]
        )

        def mamba_layer(h, xs):
            lp_norm, lp, hh, cv = xs
            x = rms_norm(h, lp_norm, cfg.norm_eps)
            y, st = ssm_lib.mamba2_decode(lp, x, {"h": hh, "conv": cv}, cfg)
            return h + y, (st["h"], st["conv"])

        def round_fn(h, xs):
            rp_norm, rp, hh_r, cv_r, kc, vc = xs
            h, (hs, convs) = jax.lax.scan(
                mamba_layer, h, (rp_norm, rp, hh_r, cv_r)
            )
            x = rms_norm(h, shared["attn_norm_scale"], cfg.norm_eps)
            a, kc, vc = attn.attn_decode(
                shared["attn"], x, kc, vc, lengths, cfg, window=window,
                impl=impl, kv_repeat=kv_repeat,
            )
            h = h + a
            x = rms_norm(h, shared["mlp_norm_scale"], cfg.norm_eps)
            h = h + mlp_apply(shared["mlp"], x)
            return h, (hs, convs, kc, vc)

        h, (hs, convs, ks, vs) = jax.lax.scan(
            round_fn,
            h,
            (
                params["rounds"]["norm_scale"],
                params["rounds"]["mamba"],
                ssm_h,
                ssm_conv,
                cache["k"],
                cache["v"],
            ),
        )
        cache = dict(
            cache,
            ssm_h=hs.reshape(cache["ssm_h"].shape),
            ssm_conv=convs.reshape(cache["ssm_conv"].shape),
            k=ks,
            v=vs,
        )

    elif cfg.kind in ("encdec", "audio"):
        enc_lengths = cache["enc_length"]

        def block(h, xs):
            bp, kc, vc, ck, cv = xs
            x = rms_norm(h, bp["self_norm_scale"], cfg.norm_eps)
            a, kc, vc = attn.attn_decode(
                bp["self_attn"], x, kc, vc, lengths, cfg, window=window, impl=impl
            )
            h = h + a
            x = rms_norm(h, bp["cross_norm_scale"], cfg.norm_eps)
            c = attn.cross_attn_apply(
                bp["cross_attn"], x[:, None, :], ck, cv, enc_lengths, cfg, impl=impl
            )
            h = h + c[:, 0]
            x = rms_norm(h, bp["mlp_norm_scale"], cfg.norm_eps)
            return h + mlp_apply(bp["mlp"], x), (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            block,
            h,
            (
                params["dec_blocks"],
                cache["k"],
                cache["v"],
                cache["cross_k"],
                cache["cross_v"],
            ),
        )
        cache = dict(cache, k=ks, v=vs)
    else:
        raise ValueError(cfg.kind)

    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params, h)
    cache = dict(cache, length=lengths + 1)
    return logits, cache


# ---------------------------------------------------------------------------
# Multi-position verify step (speculative decoding)
# ---------------------------------------------------------------------------

def verify_step(
    params,
    cfg: ModelConfig,
    tokens,            # (B, T) int32 — T = k+1 proposal window per slot
    cache,
    *,
    impl: str = "ref",
    window: Optional[int] = None,
    kv_repeat: int = 1,
):
    """Verify a T-token proposal window in one jitted call.

    Scans `decode_step` over the T positions: position j consumes
    tokens[:, j] against the cache as grown by positions < j, exactly as T
    sequential decode iterations would. This form is deliberate — the
    speculative engine's losslessness gate demands logits *bit-identical*
    to the non-speculative step-by-step decode (argmax ties must break the
    same way), which a parallel multi-position attention with a different
    reduction order could not guarantee. tests/test_speculative.py pins
    verify_step ≡ sequential decode_step bit-for-bit; the hardware *cost*
    of the fused window (one weight pass for T tokens) is modeled by
    LatencyModel.verify_latency, which is what makes speculation pay.

    Returns (logits (B, T, V), cache') with cache length advanced by T.
    Rejected positions leave stale KV beyond the accepted length; the
    serving layer rolls that back by length alone (models/cache.py
    docstring: length-gated attention never reads past `length`, and the
    next write lands on the first stale position).
    """
    def body(c, tok):
        logits, c = decode_step(
            params, cfg, tok, c, impl=impl, window=window,
            kv_repeat=kv_repeat,
        )
        return c, logits

    cache, logits = jax.lax.scan(body, cache, jnp.moveaxis(tokens, 0, 1))
    return jnp.moveaxis(logits, 0, 1), cache


def propose_step(
    params,
    cfg: ModelConfig,
    tokens,            # (B,) int32 — last committed token per slot
    cache,
    k: int,
    *,
    impl: str = "ref",
    window: Optional[int] = None,
    kv_repeat: int = 1,
):
    """Greedy-autoregress k+1 tokens in one jitted call (the draft side of
    speculative decoding): step 0 consumes `tokens`, every later step
    consumes its own argmax. The extra (k+1)-th step exists to keep the
    draft cache invariant uniform — after a fully-accepted proposal the
    draft must already have consumed its own k-th token so that the next
    round's single catch-up input is always exactly the last committed
    token (see serving/speculative.py). Returns (proposals (B, k+1), cache').
    """
    def body(carry, _):
        tok, c = carry
        logits, c = decode_step(
            params, cfg, tok, c, impl=impl, window=window,
            kv_repeat=kv_repeat,
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, c), nxt

    (_, cache), toks = jax.lax.scan(
        body, (tokens, cache), None, length=k + 1
    )
    return jnp.moveaxis(toks, 0, 1), cache
