"""Attention blocks (GQA, optional QKV bias, RoPE, sliding window).

Weight layout per (stacked) layer:
  wq (d, H*hd), wk (d, KV*hd), wv (d, KV*hd), wo (H*hd, d)
  [bq (H*hd,), bk, bv when qkv_bias]

Three entry points:
  - ``attn_train``:   full-sequence self-attention (causal or not)
  - ``attn_prefill``: same math, also returns the k/v planes for the cache
  - ``attn_decode``:  one token against a static-slot cache (+ cache write)
KV heads are replicated up to the model-parallel degree at *sharding* time,
not here (see distributed/sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import normal, rope


def init_attn(rng, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": normal(ks[0], (d, h * hd), dtype=dtype),
        "wk": normal(ks[1], (d, kv * hd), dtype=dtype),
        "wv": normal(ks[2], (d, kv * hd), dtype=dtype),
        "wo": normal(ks[3], (h * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _qkv(p, x, cfg: ModelConfig, positions, *, apply_rope=True, kv_repeat=1):
    b = x.shape[0]
    s = x.shape[1]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if kv_repeat > 1:
        # KV-head replication up to the TP degree (hillclimb #1,
        # EXPERIMENTS.md §Perf): keeps the cache write and the attention
        # reads fully local to each model shard at the cost of
        # kv_repeat x KV memory. GQA semantics unchanged: q head h maps to
        # repeated head (h // (H/kv))*r + j for any j, same values.
        k = jnp.repeat(k, kv_repeat, axis=2)
        v = jnp.repeat(v, kv_repeat, axis=2)
    if apply_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(
    p, x, cfg: ModelConfig, *,
    positions=None,
    causal: bool = True,
    window: Optional[int] = None,
    lengths=None,
    impl: str = "ref",
    kv_repeat: int = 1,
):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _qkv(p, x, cfg, positions, apply_rope=(cfg.kind != "audio"),
                   kv_repeat=kv_repeat)
    o = ops.attention(
        q, k, v, causal=causal, window=window, lengths=lengths, impl=impl
    )
    return o.reshape(b, s, -1) @ p["wo"]


def attn_prefill(
    p, x, cfg: ModelConfig, *,
    positions=None,
    window: Optional[int] = None,
    lengths=None,
    impl: str = "ref",
    kv_repeat: int = 1,
):
    """Causal self-attention that also returns k/v for cache insertion."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _qkv(p, x, cfg, positions, apply_rope=(cfg.kind != "audio"),
                   kv_repeat=kv_repeat)
    o = ops.attention(
        q, k, v, causal=True, window=window, lengths=lengths, impl=impl
    )
    return o.reshape(b, s, -1) @ p["wo"], k, v


def attn_decode(
    p, x_tok, k_cache, v_cache, lengths, cfg: ModelConfig, *,
    window: Optional[int] = None,
    impl: str = "ref",
    kv_repeat: int = 1,
):
    """One-token decode.

    x_tok (B, d); k_cache/v_cache (B, S, KV, hd) hold `lengths` (B,) valid
    tokens. Writes the new k/v at position `lengths`, attends over
    lengths+1 tokens. Returns (out (B, d), k_cache', v_cache')."""
    b, d = x_tok.shape
    x = x_tok[:, None, :]
    pos = lengths[:, None]                                     # (B, 1)
    q, k_new, v_new = _qkv(p, x, cfg, pos, apply_rope=(cfg.kind != "audio"),
                           kv_repeat=kv_repeat)

    def write(cache, new):
        # cache (B, S, KV, hd), new (B, 1, KV, hd) at per-request position
        def upd(c, n, i):
            return jax.lax.dynamic_update_slice(c, n, (i, 0, 0))
        return jax.vmap(upd)(cache, new, lengths)

    k_cache = write(k_cache, k_new.astype(k_cache.dtype))
    v_cache = write(v_cache, v_new.astype(v_cache.dtype))
    o = ops.decode_attention(
        q[:, 0], k_cache, v_cache, lengths + 1, window=window, impl=impl
    )
    return o.reshape(b, -1) @ p["wo"], k_cache, v_cache


def attn_decode_paged(
    p, x_tok, k_pool, v_pool, block_tables, lengths, cfg: ModelConfig, *,
    window: Optional[int] = None,
    impl: str = "ref",
    kv_repeat: int = 1,
):
    """One-token decode against a physically paged KV pool.

    x_tok (B, d); k_pool/v_pool (P, page, KV, hd) shared across slots;
    block_tables (B, max_pages) int32 names each slot's pages in order
    (entries >= P are sentinels). Writes the new k/v at page
    block_tables[b, lengths[b] // page], offset lengths[b] % page — the
    paged image of `attn_decode`'s row write — then attends through the
    table. Sentinel-targeted writes drop (a slot never touches pages it
    does not own) and a clamped page index past the table width resolves
    to the slot's own last entry, mirroring the dynamic_update_slice
    clamp of the contiguous path. Returns (out (B, d), k_pool', v_pool')."""
    b, d = x_tok.shape
    x = x_tok[:, None, :]
    pos = lengths[:, None]                                     # (B, 1)
    q, k_new, v_new = _qkv(p, x, cfg, pos, apply_rope=(cfg.kind != "audio"),
                           kv_repeat=kv_repeat)

    p_total, page = k_pool.shape[0], k_pool.shape[1]
    max_pages = block_tables.shape[1]
    pg_idx = jnp.minimum(lengths // page, max_pages - 1)
    pid = jnp.take_along_axis(block_tables, pg_idx[:, None], axis=1)[:, 0]
    off = lengths % page
    k_pool = k_pool.at[pid, off].set(
        k_new[:, 0].astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[pid, off].set(
        v_new[:, 0].astype(v_pool.dtype), mode="drop")
    o = ops.paged_decode_attention(
        q[:, 0], k_pool, v_pool, block_tables, lengths + 1,
        window=window, impl=impl,
    )
    return o.reshape(b, -1) @ p["wo"], k_pool, v_pool


# ---------------------------------------------------------------------------
# Cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_attn_kv(p, enc_out, cfg: ModelConfig):
    """Precompute cross-attention k/v from encoder output (no RoPE)."""
    b, s, _ = enc_out.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(b, s, kv, hd)
    v = (enc_out @ p["wv"]).reshape(b, s, kv, hd)
    return k, v


def cross_attn_apply(p, x, k, v, enc_lengths, cfg: ModelConfig, *, impl="ref"):
    """x (B, Sq, d) attends over encoder memory k/v (B, Se, KV, hd)."""
    b, sq, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, sq, h, hd)
    o = ops.attention(
        q, k, v, causal=False, lengths=enc_lengths, impl=impl
    )
    return o.reshape(b, sq, -1) @ p["wo"]
