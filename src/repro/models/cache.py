"""Decode caches: static-slot KV cache + SSM recurrent state.

A single pytree carries everything the decode step needs:

  length    (B,)                         valid context tokens per slot
  k, v      (L_attn, B, S_max, KV, hd)   attention archs (L_attn = number of
                                         attention *applications*: for the
                                         shared-block hybrid this is rounds)
  ssm_h     (L_ssm, B, ...)              Mamba scan state (f32)
  ssm_conv  (L_ssm, B, K-1, conv_dim)    Mamba conv lookback
  cross_k/v (L_dec, B, S_enc, KV, hd)    enc-dec cross-attention memory
  enc_length (B,)                        valid encoder positions

Static shapes are deliberate (TPU/XLA); token-granular *accounting* for the
scheduler happens in serving/kv_manager.py, not here. See DESIGN.md §3.

Paged KV (PR 8) does not change this layout: pages and block tables are
HOST-SIDE accounting constructs. The device cache stays one fixed-depth
row per slot — a request's tokens are physically contiguous in its row —
while `KVSlotManager` tracks which logical pages of the shared capacity
budget each resident's context occupies (`block_table`), charges
admission/growth in page granularity, and frees tail pages on partial
eviction. That split keeps every jitted shape static (no gather over a
physical page pool on the hot path) yet gives the scheduler the paged
capacity arithmetic that lets equal token capacity back 4x the resident
slots. `length` stays the single validity gate either way: chunked
prefill commits a growing prefix into the same row and re-pins `length`
at each chunk, so a partially-prefilled slot is always a valid context
prefix to attention.

Speculative-decoding rollback contract (`with_lengths`): for attention
caches, `length` alone defines validity — attention never reads past it,
and decode/verify writes always land at the current `length`, so entries a
rejected proposal left beyond the accepted frontier are first overwritten
before they could ever be attended. Rolling back a speculation is therefore
just re-pinning `length` to the committed context; no KV movement. (SSM
recurrent state has no such positional gate — state at the accepted
position would need checkpointing — which is why the speculative engine is
restricted to attention-only architectures, see serving/speculative.py.)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    *,
    enc_seq: int = 0,
    dtype=jnp.bfloat16,
    abstract: bool = False,
    kv_repeat: int = 1,
):
    """Build (or shape-describe, if abstract=True) a decode cache."""

    def arr(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    cache = {"length": arr((batch,), jnp.int32)}
    kv, hd = cfg.num_kv_heads * kv_repeat, cfg.head_dim

    n_attn = _num_attn_applications(cfg)
    if n_attn:
        cache["k"] = arr((n_attn, batch, max_seq, kv, hd), dtype)
        cache["v"] = arr((n_attn, batch, max_seq, kv, hd), dtype)

    n_ssm = len(cfg.ssm_layer_ids())
    if n_ssm:
        s = cfg.ssm
        di = cfg.d_inner
        if s.version == 2:
            nh = di // s.headdim
            cache["ssm_h"] = arr((n_ssm, batch, nh, s.headdim, s.d_state), jnp.float32)
            conv_dim = di + 2 * s.d_state
        else:
            cache["ssm_h"] = arr((n_ssm, batch, di, s.d_state), jnp.float32)
            conv_dim = di
        cache["ssm_conv"] = arr((n_ssm, batch, s.d_conv - 1, conv_dim), dtype)

    if cfg.kind in ("encdec", "audio"):
        cache["cross_k"] = arr((cfg.num_layers, batch, enc_seq, kv, hd), dtype)
        cache["cross_v"] = arr((cfg.num_layers, batch, enc_seq, kv, hd), dtype)
        cache["enc_length"] = arr((batch,), jnp.int32)

    return cache


def with_lengths(cache, lengths):
    """Re-pin the per-slot valid-context lengths (pure: returns a new dict).

    The serving engine calls this before every decode/verify iteration with
    each slot's committed context length — which is also the whole
    speculative-decoding rollback path (see module docstring)."""
    return dict(cache, length=jnp.asarray(lengths, jnp.int32))


def supports_length_rollback(cfg: ModelConfig) -> bool:
    """True when `length` alone defines cache validity, so decoding PAST a
    point and then re-pinning `length` is a complete rollback (module
    docstring: attention never reads beyond `length`, and the next write
    lands on the first stale position).

    This predicate gates every speculative execution strategy in the
    serving layer: the spec-decoding verify window (serving/speculative.py)
    and the engine's multi-step decode overshoot under EOS (the scan may
    compute iterations past an end-of-sequence token; committing stops at
    the EOS and `with_lengths` discards the rest). SSM/recurrent state has
    no positional gate — state at the rollback point would need per-position
    checkpointing — so those archs must never overshoot."""
    return cfg.kind not in ("ssm", "hybrid")


def _num_attn_applications(cfg: ModelConfig) -> int:
    if cfg.kind == "ssm":
        return 0
    if cfg.hybrid_attn_every:
        return cfg.num_layers // cfg.hybrid_attn_every
    return cfg.num_layers


def cache_bytes(cfg: ModelConfig, batch: int, max_seq: int, dtype_bytes=2) -> int:
    """Host-side size estimate (used by the KV manager and roofline)."""
    total = 0
    n_attn = _num_attn_applications(cfg)
    total += 2 * n_attn * batch * max_seq * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
    total += batch * cfg.ssm_state_bytes()
    return total
