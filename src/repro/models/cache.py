"""Decode caches: static-slot KV cache + SSM recurrent state.

A single pytree carries everything the decode step needs:

  length    (B,)                         valid context tokens per slot
  k, v      (L_attn, B, S_max, KV, hd)   attention archs (L_attn = number of
                                         attention *applications*: for the
                                         shared-block hybrid this is rounds)
  ssm_h     (L_ssm, B, ...)              Mamba scan state (f32)
  ssm_conv  (L_ssm, B, K-1, conv_dim)    Mamba conv lookback
  cross_k/v (L_dec, B, S_enc, KV, hd)    enc-dec cross-attention memory
  enc_length (B,)                        valid encoder positions

Static shapes are deliberate (TPU/XLA); token-granular *accounting* for the
scheduler happens in serving/kv_manager.py, not here. See DESIGN.md §3.

Paged KV comes in two depths. PR 8's *accounting-only* paging keeps the
contiguous layout above: pages and block tables are host-side constructs
in `KVSlotManager` that give the scheduler page-granular capacity
arithmetic, while each request still owns one fixed-depth device row.
*Physical* paging (`init_paged_cache`) makes the device see pages too:
`k`/`v` become a shared pool of fixed-size pages,

  k, v          (L_attn, P, page, KV, hd)   P = physical pool size
  block_tables  (B, max_pages)  i32         page ids per slot, ordered;
                                            entries >= P are sentinels

and a slot's context lives scattered across the pages its block-table
row names (entry ``i`` covers absolute positions [i*page, (i+1)*page)).
Decode writes land at (block_tables[b, length//page], length % page) via
`paged_write_tokens`; attention gathers through the table (the pallas
paged kernel resolves it at DMA-issue time). Now `evict_tail` and
release free real HBM rows and admission capacity IS the physical pool —
token-granular preemption moves memory, not just ledger entries. Every
jitted shape stays static: the pool, the table width, and `length` are
fixed; only table *values* change, uploaded by the engine when the
manager's tables move. `length` stays the single validity gate in both
layouts: chunked prefill commits a growing prefix (page by page when
physical) and re-pins `length` at each chunk, so a partially-prefilled
slot is always a valid context prefix to attention, and positions beyond
`length` — including whole sentinel-mapped pages — are never attended.

Speculative-decoding rollback contract (`with_lengths`): for attention
caches, `length` alone defines validity — attention never reads past it,
and decode/verify writes always land at the current `length`, so entries a
rejected proposal left beyond the accepted frontier are first overwritten
before they could ever be attended. Rolling back a speculation is therefore
just re-pinning `length` to the committed context; no KV movement. (SSM
recurrent state has no such positional gate — state at the accepted
position would need checkpointing — which is why the speculative engine is
restricted to attention-only architectures, see serving/speculative.py.)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    *,
    enc_seq: int = 0,
    dtype=jnp.bfloat16,
    abstract: bool = False,
    kv_repeat: int = 1,
):
    """Build (or shape-describe, if abstract=True) a decode cache."""

    def arr(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    cache = {"length": arr((batch,), jnp.int32)}
    kv, hd = cfg.num_kv_heads * kv_repeat, cfg.head_dim

    n_attn = _num_attn_applications(cfg)
    if n_attn:
        cache["k"] = arr((n_attn, batch, max_seq, kv, hd), dtype)
        cache["v"] = arr((n_attn, batch, max_seq, kv, hd), dtype)

    n_ssm = len(cfg.ssm_layer_ids())
    if n_ssm:
        s = cfg.ssm
        di = cfg.d_inner
        if s.version == 2:
            nh = di // s.headdim
            cache["ssm_h"] = arr((n_ssm, batch, nh, s.headdim, s.d_state), jnp.float32)
            conv_dim = di + 2 * s.d_state
        else:
            cache["ssm_h"] = arr((n_ssm, batch, di, s.d_state), jnp.float32)
            conv_dim = di
        cache["ssm_conv"] = arr((n_ssm, batch, s.d_conv - 1, conv_dim), dtype)

    if cfg.kind in ("encdec", "audio"):
        cache["cross_k"] = arr((cfg.num_layers, batch, enc_seq, kv, hd), dtype)
        cache["cross_v"] = arr((cfg.num_layers, batch, enc_seq, kv, hd), dtype)
        cache["enc_length"] = arr((batch,), jnp.int32)

    return cache


def supports_physical_paging(cfg: ModelConfig) -> bool:
    """Physical paging covers archs whose decode state is pure
    length-gated self-attention KV: recurrent state (ssm/hybrid) has no
    positional gate to page against, and encoder memory (encdec/audio)
    is a second, un-paged cache. Those run accounting-only paging."""
    return cfg.kind in ("dense", "vlm", "moe")


def init_paged_cache(
    cfg: ModelConfig,
    batch: int,
    num_pages: int,
    page_size: int,
    max_seq: int,
    *,
    dtype=jnp.bfloat16,
    abstract: bool = False,
    kv_repeat: int = 1,
):
    """Build a physically paged decode cache (module docstring layout).

    `num_pages` is the physical pool size (admission capacity); sentinel
    table entries equal `num_pages` so unallocated writes drop and
    unallocated gathers clamp into masked territory."""
    assert supports_physical_paging(cfg), cfg.kind
    assert 0 < page_size, page_size

    def arr(shape, dt, fill=0):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        if fill:
            return jnp.full(shape, fill, dt)
        return jnp.zeros(shape, dt)

    kv, hd = cfg.num_kv_heads * kv_repeat, cfg.head_dim
    n_attn = _num_attn_applications(cfg)
    max_pages = -(-max_seq // page_size)
    return {
        "length": arr((batch,), jnp.int32),
        "k": arr((n_attn, num_pages, page_size, kv, hd), dtype),
        "v": arr((n_attn, num_pages, page_size, kv, hd), dtype),
        "block_tables": arr((batch, max_pages), jnp.int32, num_pages),
    }


def is_paged(cache) -> bool:
    """Static layout predicate: pytree structure, not data, decides the
    decode routing (a jitted step traces one branch per cache layout)."""
    return "block_tables" in cache


def paged_write_tokens(pool, block_tables, starts, seg, counts):
    """Scatter a contiguous token segment into the page pool.

    pool (n_attn, P, page, KV, hd); block_tables (B, max_pages);
    seg (n_attn, B, n, KV, hd) holds `counts[b]` valid tokens per slot,
    landing at absolute positions starts[b] .. starts[b]+counts[b].
    Positions beyond `counts` or past the table width are routed to the
    sentinel id and dropped by the scatter — a slot can never write a
    page it does not own. Returns the updated pool."""
    p_total, page = pool.shape[1], pool.shape[2]
    n = seg.shape[2]
    max_pages = block_tables.shape[1]
    pos = starts[:, None] + jnp.arange(n)[None]              # (B, n)
    pg_idx = pos // page
    pid = jnp.take_along_axis(
        block_tables, jnp.minimum(pg_idx, max_pages - 1), axis=1)
    valid = (jnp.arange(n)[None] < counts[:, None]) & (pg_idx < max_pages)
    pid = jnp.where(valid, pid, p_total)                     # -> dropped
    off = pos % page
    return pool.at[:, pid, off].set(seg.astype(pool.dtype), mode="drop")


def paged_gather_rows(pool, table_rows, max_seq):
    """Rebuild contiguous cache rows from the pool.

    pool (n_attn, P, page, KV, hd); table_rows (B, max_pages) ->
    (n_attn, B, max_seq, KV, hd). Sentinels clamp to an arbitrary pool
    page; callers only read positions < length (swap-out stores whole
    rows, but restore + attention re-mask by length, same as the stale
    tail of a contiguous row)."""
    p_total, page = pool.shape[1], pool.shape[2]
    rows = pool[:, jnp.minimum(table_rows, p_total - 1)]
    # (n_attn, B, max_pages, page, KV, hd) -> (n_attn, B, S', KV, hd)
    flat = rows.reshape(rows.shape[0], rows.shape[1], -1, *rows.shape[4:])
    return flat[:, :, :max_seq]


def with_block_tables(cache, tables):
    """Re-pin the device block tables (pure). The engine calls this when
    the KV manager's tables moved (allocate/grow/evict/release) — table
    VALUES are data, so no recompilation."""
    return dict(cache, block_tables=jnp.asarray(tables, jnp.int32))


def with_lengths(cache, lengths):
    """Re-pin the per-slot valid-context lengths (pure: returns a new dict).

    The serving engine calls this before every decode/verify iteration with
    each slot's committed context length — which is also the whole
    speculative-decoding rollback path (see module docstring)."""
    return dict(cache, length=jnp.asarray(lengths, jnp.int32))


def supports_length_rollback(cfg: ModelConfig) -> bool:
    """True when `length` alone defines cache validity, so decoding PAST a
    point and then re-pinning `length` is a complete rollback (module
    docstring: attention never reads beyond `length`, and the next write
    lands on the first stale position).

    This predicate gates every speculative execution strategy in the
    serving layer: the spec-decoding verify window (serving/speculative.py)
    and the engine's multi-step decode overshoot under EOS (the scan may
    compute iterations past an end-of-sequence token; committing stops at
    the EOS and `with_lengths` discards the rest). SSM/recurrent state has
    no positional gate — state at the rollback point would need per-position
    checkpointing — so those archs must never overshoot."""
    return cfg.kind not in ("ssm", "hybrid")


def _num_attn_applications(cfg: ModelConfig) -> int:
    if cfg.kind == "ssm":
        return 0
    if cfg.hybrid_attn_every:
        return cfg.num_layers // cfg.hybrid_attn_every
    return cfg.num_layers


def cache_bytes(cfg: ModelConfig, batch: int, max_seq: int, dtype_bytes=2) -> int:
    """Host-side size estimate (used by the KV manager and roofline)."""
    total = 0
    n_attn = _num_attn_applications(cfg)
    total += 2 * n_attn * batch * max_seq * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
    total += batch * cfg.ssm_state_bytes()
    return total
