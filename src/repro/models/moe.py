"""Mixture-of-Experts layer: shared experts + routed top-k experts.

Dispatch is capacity-based (scatter into an (E, C, d) buffer, batched
expert matmuls, gather-combine) — the standard XLA/TPU-friendly form:
the expert matmul is a single `ecd,edf->ecf` einsum whose E axis shards
over the "model" mesh axis (expert parallelism); XLA inserts the
all-to-alls at the dispatch/combine boundaries. Over-capacity tokens are
dropped (they fall back to the shared experts / residual path), matching
standard practice.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_mlp, mlp_apply, normal

CAPACITY_FACTOR = 1.25


def init_moe(rng, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 5)
    p = {
        "router": normal(ks[0], (d, m.num_experts), dtype=dtype),
        "experts": {
            "gate": normal(ks[1], (m.num_experts, d, m.d_expert), dtype=dtype),
            "up": normal(ks[2], (m.num_experts, d, m.d_expert), dtype=dtype),
            "down": normal(ks[3], (m.num_experts, m.d_expert, d), dtype=dtype),
        },
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(
            ks[4], d, m.num_shared_experts * m.d_expert, gated=True, dtype=dtype
        )
    return p


def moe_apply_chunked(p, x, cfg: ModelConfig, valid=None, seq_chunk: int = 2048):
    """MoE scanned over sequence chunks (hillclimb #3, EXPERIMENTS.md §Perf).

    The routing one-hot/cumsum tensors and the (E, C, d) dispatch buffer
    scale with the token count; chunking bounds them to one chunk's worth
    (peak activation memory / n_chunks) while the expert weights are
    re-read once per chunk (they are small next to the buffers at long
    prefill). Capacity becomes per-chunk, which is *more* faithful to how
    serving systems bound skew. Baseline (paper-faithful global capacity)
    is moe_apply.
    """
    b, slen, d = x.shape
    chunk = min(seq_chunk, slen)
    while slen % chunk:
        chunk //= 2
    n = slen // chunk
    if n <= 1:
        return moe_apply(p, x, cfg, valid=valid)
    xs = jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0)
    vs = (jnp.moveaxis(valid.reshape(b, n, chunk), 1, 0)
          if valid is not None else None)

    def body(_, inp):
        if vs is None:
            xc = inp
            y, aux = moe_apply(p, xc, cfg)
        else:
            xc, vc = inp
            y, aux = moe_apply(p, xc, cfg, valid=vc)
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(body, None, xs if vs is None else (xs, vs))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, slen, d)
    return y, jnp.mean(auxs)


def moe_apply(p, x: jax.Array, cfg: ModelConfig, valid=None) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    valid: optional (B, S) bool — padding tokens are excluded from routing so
    they neither consume expert capacity nor contribute to the aux loss.
    (Like any capacity-based MoE, outputs are weakly batch-dependent: which
    tokens drop depends on what else is in the batch.)
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ p["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)          # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    if valid is not None:
        vt = valid.reshape(t)
        top_w = top_w * vt[:, None]
        top_e = jnp.where(vt[:, None], top_e, m.num_experts)  # off-range -> no expert
        probs = probs * vt[:, None]

    # ---- load-balance auxiliary loss (Switch-style) ----------------------
    me = jnp.mean(probs, axis=0)                                   # (E,)
    onehot_top = jax.nn.one_hot(top_e, m.num_experts)              # (T,k,E)
    ce = jnp.mean(jnp.sum(onehot_top, axis=1), axis=0) / m.top_k   # (E,)
    aux = m.num_experts * jnp.sum(me * ce) * m.router_aux_loss_coef

    # ---- capacity-based dispatch ------------------------------------------
    cap = int(CAPACITY_FACTOR * t * m.top_k / m.num_experts) + 1
    cap = min(cap, t)
    flat_e = top_e.reshape(t * m.top_k)                            # slot -> expert
    flat_w = top_w.reshape(t * m.top_k)
    flat_oh = onehot_top.reshape(t * m.top_k, m.num_experts)
    # position of each slot within its expert's queue
    pos_in_e = (jnp.cumsum(flat_oh, axis=0) - 1.0)                 # (T*k, E)
    slot_pos = jnp.sum(pos_in_e * flat_oh, axis=-1).astype(jnp.int32)
    keep = slot_pos < cap
    slot_pos = jnp.where(keep, slot_pos, cap)  # dropped -> scatter to waste row

    token_idx = jnp.repeat(jnp.arange(t), m.top_k)
    buf = jnp.zeros((m.num_experts, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, slot_pos].add(xt[token_idx])
    buf = buf[:, :cap]                                             # (E, C, d)

    # ---- expert FFN (batched over experts; E shards over "model") --------
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, p["experts"]["gate"])
    ) * jnp.einsum("ecd,edf->ecf", buf, p["experts"]["up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["experts"]["down"])      # (E, C, d)

    # ---- combine ----------------------------------------------------------
    gathered = out[flat_e, jnp.minimum(slot_pos, cap - 1)]         # (T*k, d)
    gathered = gathered * (flat_w * keep)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[token_idx].add(gathered)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], xt)
    return y.reshape(b, s, d), aux
