"""Shared neural-net building blocks (pure functional JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal(rng, shape, std=0.02, dtype=jnp.float32):
    return (jax.random.normal(rng, shape) * std).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x (..., S, H, hd), positions broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(rng, d_model: int, d_ff: int, gated: bool, dtype):
    ks = jax.random.split(rng, 3)
    p = {
        "up": normal(ks[0], (d_model, d_ff), dtype=dtype),
        "down": normal(ks[1], (d_ff, d_model), dtype=dtype),
    }
    if gated:
        p["gate"] = normal(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp_apply(p, x: jax.Array) -> jax.Array:
    if "gate" in p:
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    else:
        h = jax.nn.gelu(x @ p["up"])
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(rng, vocab: int, d_model: int, dtype):
    return {"table": normal(rng, (vocab, d_model), std=1.0, dtype=dtype)}


def embed_apply(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(params, h: jax.Array) -> jax.Array:
    """h (..., d) -> logits (..., V). Uses tied table if no lm_head."""
    if "lm_head" in params:
        return h @ params["lm_head"]["kernel"]
    return h @ params["embed"]["table"].T
