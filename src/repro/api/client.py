"""ServingClient: one submit/stream surface over ANY Andes backend.

The paper's QoE machinery is defined on the user's interaction timeline,
but until this module every consumer hand-drove a backend through its own
low-level loop (submit-all + step-to-drain for the simulator, the same
again for the engine, a third variant for the cluster). `ServingClient`
is the single user-facing surface:

    client = ServingClient(backend)          # sim | engine | spec engine
                                             # | whole ClusterSimulator
    h = client.submit(prompt_or_len, SubmitOptions(spec=..., contract=...))
    for ev in h:                             # drives the backend on demand
        ...                                  # ev.visible_time is §5-paced
    h.qoe(), h.ttft()                        # Eq. 1 on the user timeline

Anything exposing the steppable protocol (`submit/step/result/now` —
`ServingSimulator`, `ServingEngine` with or without speculation, and the
steppable `ClusterSimulator`) plugs in unchanged; the client attaches one
`repro.obs.Observer` to the backend and fans lifecycle events out to
per-request `StreamHandle`s (backends predating the observer protocol get
the legacy `event_sink` callable instead). Attaching — rather than
setting — means client streaming composes with any tracing/metrics
observers the caller installed: PR 4's private sink plumbing is gone.
Driving a backend through the client is bit-identical to driving it
directly (tests/test_api.py: emit timestamps, preemptions, and final QoE
per request) — the client adds an API, never a behavior.

`SubmitOptions` carries the request's identity in the serving economy:
its QoE expectation (`spec`), tenant, priority class, and `SLOContract`
(core.pricing) — the contract's weight is what the admission controller
and autoscaler price with, replacing the PR 1 uniform threshold.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.pricing import SLOContract
from repro.core.qoe import QoESpec
from repro.core.request import Request
from repro.api.stream import StreamHandle
from repro.obs import Observer

# reading-speed default: 1 s to first token, 4.8 tokens/s thereafter
# (the paper's expected human reading pace, Table 1)
DEFAULT_SPEC = QoESpec(ttft=1.0, tds=4.8)


@dataclasses.dataclass(frozen=True)
class SubmitOptions:
    """Per-request serving options.

    spec      — expected delivery timeline (TTFT + TDS), the Eq. 1 QoE
                reference curve.
    max_tokens— response length bound (ground-truth length in simulation).
    tenant    — tenant id for per-tenant accounting (cluster layer).
    priority  — priority class; class p prices (1+p)x in the scheduler
                knapsack, router, and admission (core.pricing).
    contract  — per-tenant SLOContract (attainment targets + pricing
                weight). None = uniform PR 1 behavior.
    arrival   — absolute submission time; None = the backend's clock now
                (trace replays pass explicit arrivals).
    """
    spec: QoESpec = DEFAULT_SPEC
    max_tokens: int = 64
    tenant: int = 0
    priority: int = 0
    contract: Optional[SLOContract] = None
    arrival: Optional[float] = None


class _ClientObserver(Observer):
    """Fans backend lifecycle hooks out to the client's StreamHandles.

    Only the stream-visible kinds are forwarded; every other hook
    inherits the null base. Handles are looked up by object identity, so
    a backend shared with other submitters never cross-talks."""

    def __init__(self, client: "ServingClient"):
        self._client = client

    def _fwd(self, kind: str, req: Request, t: float, k: int = 0) -> None:
        h = self._client._handles.get(id(req))
        if h is not None:
            h._event(kind, t, k)

    def emit(self, req, t, k=1, *, replica=-1):
        self._fwd("emit", req, t, k)

    def preempt(self, req, t, mode="swap", *, replica=-1):
        self._fwd("preempt", req, t)

    def finish(self, req, t, *, replica=-1):
        self._fwd("finish", req, t)

    def shed(self, req, t, *, replica=-1):
        self._fwd("shed", req, t)

    def defer(self, req, t, *, replica=-1):
        self._fwd("defer", req, t)

    def cancel(self, req, t, *, replica=-1):
        self._fwd("cancel", req, t)


class ServingClient:
    """Client sessions over one backend (see module docstring)."""

    def __init__(self, backend):
        self.backend = backend
        self._handles: Dict[int, StreamHandle] = {}     # id(request) -> h
        self._rids: set = set()                         # every rid in use
        self._next_rid = 0
        # one observer for the whole backend; the cluster propagates it to
        # every replica backend, including autoscaler-provisioned ones.
        self._observer = _ClientObserver(self)
        if hasattr(backend, "attach_observer"):
            backend.attach_observer(self._observer)
        else:  # foreign backend predating repro.obs: legacy callable sink
            backend.event_sink = self._on_event

    # ------------------------------------------------------------- plumbing
    def _on_event(self, kind: str, req: Request, t: float, k: int) -> None:
        h = self._handles.get(id(req))
        if h is not None:
            h._event(kind, t, k)

    # --------------------------------------------------------------- submit
    def submit(
        self,
        prompt_or_len: Union[int, "np.ndarray", List[int]],
        options: Optional[SubmitOptions] = None,
        *,
        on_first_token=None,
        on_emit=None,
        on_preempt=None,
        on_finish=None,
    ) -> StreamHandle:
        """Submit a prompt (token ids for real engines, or just a length
        for simulation) and get back its live token stream."""
        opts = options if options is not None else SubmitOptions()
        if isinstance(prompt_or_len, (int, np.integer)):
            prompt_len, prompt_tokens = int(prompt_or_len), None
        else:
            prompt_tokens = np.asarray(prompt_or_len, np.int32)
            prompt_len = int(prompt_tokens.size)
        arrival = (float(opts.arrival) if opts.arrival is not None
                   else float(self.backend.now))
        while self._next_rid in self._rids:    # skip rids trace replays took
            self._next_rid += 1
        req = Request(
            rid=self._next_rid,
            arrival=arrival,
            prompt_len=prompt_len,
            output_len=int(opts.max_tokens),
            spec=opts.spec,
            prompt_tokens=prompt_tokens,
            tenant=opts.tenant,
            priority=opts.priority,
            contract=opts.contract,
        )
        self._next_rid += 1
        return self.submit_request(
            req, on_first_token=on_first_token, on_emit=on_emit,
            on_preempt=on_preempt, on_finish=on_finish,
        )

    def submit_request(
        self,
        req: Request,
        *,
        on_first_token=None,
        on_emit=None,
        on_preempt=None,
        on_finish=None,
    ) -> StreamHandle:
        """Submit a pre-built Request (e.g. from the repro.workload trace
        generators) — the migration path for benchmark/trace replays."""
        if req.rid in self._rids:
            # per-rid reporting (and admission's defer bookkeeping) keys on
            # rid; a silent duplicate would conflate two live requests
            raise ValueError(f"rid {req.rid} is already in use on this "
                             "client session")
        h = StreamHandle(self, req)
        h.on_first_token = on_first_token
        h.on_emit = on_emit
        h.on_preempt = on_preempt
        h.on_finish = on_finish
        self._handles[id(req)] = h
        self._rids.add(req.rid)
        self.backend.submit(req)
        return h

    def cancel(self, handle_or_rid) -> bool:
        """Abort a submitted stream (a StreamHandle or its rid). Only
        meaningful on backends exposing `cancel(rid)` (ServingSimulator /
        ServingEngine); returns False when unsupported, unknown, or the
        request already finished."""
        rid = getattr(handle_or_rid, "rid", handle_or_rid)
        backend_cancel = getattr(self.backend, "cancel", None)
        if backend_cancel is None:
            return False
        return bool(backend_cancel(int(rid)))

    # -------------------------------------------------------------- driving
    def step(self, until: Optional[float] = None) -> bool:
        """Advance the backend by one event/iteration (False = drained).

        `until`: forwarded to backends that support it (the hot-path
        engine bounds its multi-step decode block so the clock crosses
        `until` at a single indivisible iteration — see
        ServingEngine.step). Requests already submitted need no bound:
        the engine stops fused blocks at its own pending queue. Pass it
        only when you plan to submit a request with an explicit future
        `arrival` AFTER stepping past it — without the bound, a fused
        block commits several iterations per call, so the clock (and the
        admission boundary of that later submit) can land further along
        than a baseline engine driven by the same call sequence."""
        if until is None:
            return self.backend.step()
        return self.backend.step(until=until)

    def drain(self) -> List[StreamHandle]:
        """Serve everything submitted so far to completion."""
        while self.backend.step():
            pass
        return self.handles()

    def serve(self, workload: List[Request]):
        """Trace replay as a one-liner: submit a pre-built workload (in
        arrival order, matching the backends' own run() semantics), drain,
        and return the backend's native result. What the benchmarks and
        cluster examples drive with."""
        for r in sorted(workload, key=lambda r: r.arrival):
            self.submit_request(r)
        self.drain()
        return self.result()

    # ------------------------------------------------------------ reporting
    def handles(self) -> List[StreamHandle]:
        return list(self._handles.values())

    @property
    def now(self) -> float:
        return float(self.backend.now)

    def result(self):
        """The backend's native result snapshot (SimResult for single
        backends, ClusterResult for a cluster)."""
        return self.backend.result()

    def avg_qoe(self) -> float:
        """Mean Eq. 1 QoE across every stream (shed streams count 0)."""
        hs = self.handles()
        return float(np.mean([h.qoe() for h in hs])) if hs else 1.0


__all__ = ["ServingClient", "SubmitOptions", "DEFAULT_SPEC"]
