"""repro.api — the unified client-facing serving surface.

One submit/stream API over every backend the repo can serve with
(discrete-event simulator, real-model engine, speculative engine, or a
whole multi-replica cluster), built on the user-timeline abstraction the
paper defines QoE over:

  ServingClient  — client sessions: submit(prompt, SubmitOptions) over
                   any steppable backend.
  StreamHandle   — a response as the user sees it: an iterator of
                   TokenEvents re-smoothed by the §5 client pacing
                   buffer, with lifecycle callbacks.
  SubmitOptions  — tenant, priority class, QoE expectation, and the
                   per-tenant SLOContract that admission/autoscaling
                   price with (repro.core.pricing).
"""
from repro.core.pricing import SLOContract
from repro.core.qoe import QoESpec
from repro.api.client import DEFAULT_SPEC, ServingClient, SubmitOptions
from repro.api.stream import StreamHandle, TokenEvent

__all__ = [
    "ServingClient", "SubmitOptions", "StreamHandle", "TokenEvent",
    "SLOContract", "QoESpec", "DEFAULT_SPEC",
]
