"""Token streams: the client-visible side of a served request.

The paper defines QoE on the *user's* timeline (§4): first token promptly,
then tokens at a digestible pace, with the client-side buffer (§5)
re-smoothing whatever burstiness the server produced. `StreamHandle` is
that timeline as an object: an iterator of timestamped `TokenEvent`s whose
`visible_time` is the §5 buffer-paced display instant (TokenBuffer — the
incremental form of core.qoe.pace_delivery), plus the lifecycle callbacks
a real streaming client would register (first token, emission bursts,
preemptions, completion).

Iterating a handle *drives the backend*: `__next__` steps the underlying
engine/simulator/cluster until this request's next token exists. Because
every backend is virtual-clocked and deterministic, pulling streams in any
order yields the same token timeline as draining the backend wholesale —
the differential guarantee tests/test_api.py pins.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core.qoe import pace_delivery
from repro.core.request import Request, ReqState
from repro.core.token_buffer import TokenBuffer


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One user-visible token of a streamed response."""
    index: int                 # 0-based position in the response
    emit_time: float           # server emission timestamp (absolute, s)
    visible_time: float        # §5 buffer-paced display timestamp
    token: Optional[int]       # token id (real engines; None in simulation)


Callback = Callable[["StreamHandle", float], None]
EmitCallback = Callable[["StreamHandle", float, int], None]


class StreamHandle:
    """A live token stream for one submitted request.

    Iteration yields `TokenEvent`s, stepping the backend on demand; the
    handle is also the per-request reporting surface (qoe/ttft/tds and the
    raw/paced timelines) once the stream ends. Lifecycle callbacks:

      on_first_token(handle, t)   first server emission (TTFT instant)
      on_emit(handle, t, k)       every server emission (k tokens — k > 1
                                  is a speculative verify burst)
      on_preempt(handle, t)       the request lost its batch slot
      on_finish(handle, t)        the response completed

    A request the cluster admission layer rejected never emits: `shed`
    flips True, iteration ends, and final_qoe() is 0 — exactly how fleet
    metrics account for it (§6.4 degrade-gracefully).
    """

    def __init__(self, client, request: Request):
        self._client = client
        self.request = request
        self._buf = TokenBuffer(request.spec.tds)
        self._cursor = 0
        self._emitted_seen = 0
        self.shed = False
        self.deferrals = 0
        self.on_first_token: Optional[Callback] = None
        self.on_emit: Optional[EmitCallback] = None
        self.on_preempt: Optional[Callback] = None
        self.on_finish: Optional[Callback] = None
        self.on_cancel: Optional[Callback] = None

    # ------------------------------------------------------------ identity
    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def finished(self) -> bool:
        return self.request.state == ReqState.FINISHED

    @property
    def cancelled(self) -> bool:
        """Aborted by the client (disconnect / ServingClient.cancel)."""
        return self.request.cancelled

    @property
    def done(self) -> bool:
        """No more tokens will ever arrive (finished or shed)."""
        return self.finished or self.shed

    # ------------------------------------------------------- event plumbing
    def _event(self, kind: str, t: float, k: int) -> None:
        """Dispatched by the ServingClient's backend event sink."""
        if kind == "emit":
            if self._emitted_seen == 0 and self.on_first_token is not None:
                self.on_first_token(self, t)
            self._emitted_seen += k
            if self.on_emit is not None:
                self.on_emit(self, t, k)
        elif kind == "preempt":
            if self.on_preempt is not None:
                self.on_preempt(self, t)
        elif kind == "finish":
            if self.on_finish is not None:
                self.on_finish(self, t)
        elif kind == "shed":
            self.shed = True
        elif kind == "defer":
            self.deferrals += 1
        elif kind == "cancel":
            if self.on_cancel is not None:
                self.on_cancel(self, t)

    # ------------------------------------------------------------ iteration
    def __iter__(self) -> "StreamHandle":
        return self

    def __next__(self) -> TokenEvent:
        r = self.request
        while self._cursor >= len(r.emit_times):
            if self.done or not self._client.step():
                raise StopIteration
        i = self._cursor
        self._cursor += 1
        e = float(r.emit_times[i])
        v = self._buf.push(e)
        tok = r.output_tokens[i] if i < len(r.output_tokens) else None
        return TokenEvent(index=i, emit_time=e, visible_time=v, token=tok)

    def read(self) -> List[TokenEvent]:
        """Drain this stream to completion and return every event."""
        return list(self)

    # ------------------------------------------------------------ reporting
    def emit_times(self) -> np.ndarray:
        return np.asarray(self.request.emit_times, np.float64)

    def visible_times(self) -> np.ndarray:
        """The §5 buffer-paced delivery timeline (absolute timestamps)."""
        return pace_delivery(self.emit_times(), self.request.spec.tds)

    def tokens(self) -> List[int]:
        return list(self.request.output_tokens)

    def qoe(self) -> float:
        return self.request.final_qoe()

    def ttft(self) -> float:
        return self.request.final_ttft()

    def tds(self) -> float:
        return self.request.final_tds()

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        state = ("shed" if self.shed
                 else self.request.state.value)
        return (f"StreamHandle(rid={self.rid}, {state}, "
                f"{len(self.request.emit_times)} tokens)")
