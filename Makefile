PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-slow bench bench-api bench-arena \
        bench-arena-smoke bench-cluster bench-cluster-engine \
        bench-hotpath bench-obs bench-physical bench-physical-smoke \
        bench-scale bench-scale-smoke bench-spec \
        bench-server bench-server-smoke serve server-smoke \
        example-quickstart example-cluster example-cluster-engine \
        example-serve-http

# ---- test tiers -----------------------------------------------------------
# tier-1  (make test-fast): everything NOT marked `slow` — the ROADMAP.md
#         verify command and the per-PR CI gate; ~6 min on CPU.
# slow    (make test-slow): kernel sweeps, small-mesh compile checks, long
#         e2e paper-claim runs and engine differential suites; run on main
#         pushes (see .github/workflows/test.yml) or locally before merge.
# full    (make test): both tiers in one run (no -x: a known slow-tier
#         failure is documented in ROADMAP.md and must not mask the rest).
test:
	$(PYTHON) -m pytest -q

# tier-1 verify (same command as ROADMAP.md)
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

test-slow:
	$(PYTHON) -m pytest -q -m slow

# all paper figures/tables (quick CI profile)
bench:
	$(PYTHON) -m benchmarks.run

# cluster serving sweep: router policy x fleet size x burst cv (+ admission)
bench-cluster:
	$(PYTHON) -m benchmarks.cluster_qoe --out cluster_qoe.json

# the same sweep through the unified serving API (repro.api.ServingClient
# drives every backend; bit-identical to direct driving per tests/test_api.py)
bench-api: bench-cluster

# engine-backed mode: real-model replicas cross-checked against the sim fleet
bench-cluster-engine:
	$(PYTHON) -m benchmarks.cluster_qoe --engine

# speculative decoding: lossless token-identity gate + decode-step reduction
# vs the baseline engine on one trace
bench-spec:
	$(PYTHON) -m benchmarks.cluster_qoe --speculative

# engine hot path (PR 5): legacy-vs-optimized tokens/s, prefill compile
# count, host syncs — lossless-gated; writes BENCH_hotpath.json (exits
# nonzero if any gate fails, which is what the CI job relies on)
bench-hotpath:
	$(PYTHON) -m benchmarks.engine_hotpath

# observability overhead/correctness only (PR 6): instrumented engine must
# be bit-identical, trace must reconcile to reported QoE, throughput
# overhead <= the gate; validates without rewriting BENCH_hotpath.json
bench-obs:
	$(PYTHON) -m benchmarks.engine_hotpath --obs

# 100x-scale section (PR 8): 1000-request heavy-tail trace, fixed-slot vs
# paged+chunked at equal KV capacity; gates paged tokens/s >= fixed-slot
# and strictly lower worst-case TTFT, then read-modify-writes the `scale`
# key of BENCH_hotpath.json (nightly slow tier uploads the artifact)
bench-scale:
	$(PYTHON) -m benchmarks.engine_hotpath --scale

# CI-sized scale run (<= 200 requests): same gates, no artifact rewrite
bench-scale-smoke:
	$(PYTHON) -m benchmarks.engine_hotpath --scale --smoke

# physical paging + persistent loop (PR 10): page x chunk sweep, physically
# paged pool vs accounting-only layout (bit-identical + tokens/s gates) and
# persistent while_loop syncs strictly below the static-scan engine's;
# read-modify-writes the `physical_paging` key of BENCH_hotpath.json
bench-physical:
	$(PYTHON) -m benchmarks.engine_hotpath --physical

# CI-sized physical run: same gates, no artifact rewrite
bench-physical-smoke:
	$(PYTHON) -m benchmarks.engine_hotpath --physical --smoke

# scheduling-policy arena (PR 7): policy x adversarial-trace x load sweep;
# validates the checked-in BENCH_policy_arena.json scoreboard WITHOUT
# rewriting it and exits nonzero on any gate failure (Andes must top avg
# QoE, vtc/wsc must top Jain fairness). Regenerate with --write.
bench-arena:
	$(PYTHON) -m benchmarks.policy_arena

# CI-sized arena: 2 policies x 1 trace x 1 rate, gates only, no artifact I/O
bench-arena-smoke:
	$(PYTHON) -m benchmarks.policy_arena --smoke

# wire-serving benchmark (PR 9): wall-clock HTTP/SSE frontend under
# concurrent streams; gates wire==engine frame fidelity and the
# wall-vs-virtual tolerance differential; writes BENCH_server.json
bench-server:
	$(PYTHON) -m benchmarks.server_bench

# CI-sized wire bench: one 8-stream wave, gates only, no artifact write
bench-server-smoke:
	$(PYTHON) -m benchmarks.server_bench --smoke

# run the HTTP/SSE frontend standalone (prints "LISTENING <port>";
# SIGTERM/ctrl-C drains live streams before exiting)
serve:
	$(PYTHON) -m repro.server --port 8080

# the CI server smoke: boots `python -m repro.server` as a subprocess and
# asserts SSE framing, token identity + tolerance gates vs a
# virtual-clock reference, /metrics, and SIGTERM graceful drain
server-smoke:
	$(PYTHON) scripts/server_smoke.py

example-quickstart:
	$(PYTHON) examples/quickstart.py

example-serve-http:
	$(PYTHON) examples/serve_http.py

example-cluster:
	$(PYTHON) examples/serve_cluster.py

example-cluster-engine:
	$(PYTHON) examples/serve_cluster_engine.py
