PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-cluster example-cluster

# tier-1 verify (same command as ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# skip the long paper-claim tests
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

# all paper figures/tables (quick CI profile)
bench:
	$(PYTHON) -m benchmarks.run

# cluster serving sweep: router policy x fleet size x burst cv (+ admission)
bench-cluster:
	$(PYTHON) -m benchmarks.cluster_qoe --out cluster_qoe.json

example-cluster:
	$(PYTHON) examples/serve_cluster.py
