"""Engine-as-oracle differential tests for the steppable ServingEngine.

PR 1 made the discrete-event simulator steppable so the cluster layer
could drive it; this suite guards the same refactor applied to the real
engine. ``legacy_run`` below is a faithful transcription of the
pre-refactor monolithic ``ServingEngine.run()`` loop (the PR 0 seed),
driving the engine's private helpers directly with loop-local
pending/live lists. The steppable engine — whether driven by the thin
``run()`` wrapper, by manual ``submit()``+``step()``, or cluster-style
(submit each request only once the clock reaches its arrival) — must
reproduce it *bit-for-bit*: identical token ids, identical emission
timestamps (exact float equality: same operations in the same order),
identical preemption events, identical final QoE.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, SchedulerConfig, TPU_V5E, make_scheduler
from repro.cluster import SteppableBackend
from repro.models import Model
from repro.serving import Request, ReqState, ServingEngine
from repro.serving.simulator import ServingSimulator, SimConfig, SimResult


_LLAMA_CACHE = {}


def _llama():
    # module-level cache rather than a fixture: the hypothesis-compat
    # @given wrapper cannot take pytest fixtures as arguments
    if "v" not in _LLAMA_CACHE:
        cfg = get_smoke_config("llama3-8b")
        m = Model(cfg)
        _LLAMA_CACHE["v"] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _LLAMA_CACHE["v"]


@pytest.fixture(scope="module")
def llama():
    return _llama()


def mk_wl(cfg, rng, n=8, out_len=10, stagger=0.2, plo=8, phi=24):
    wl = []
    for i in range(n):
        plen = int(rng.integers(plo, phi))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    return wl


def clone(wl):
    return [r.clone() for r in wl]


def mk_engine(m, params, lat, *, sched_name="andes", cap=8 * 64,
              num_slots=8, max_seq=64, mode="swap",
              sched_cfg=None):
    sched = make_scheduler(sched_name, cap, lat,
                           sched_cfg or SchedulerConfig())
    return ServingEngine(m, params, sched, lat, num_slots=num_slots,
                         max_seq=max_seq, capacity_tokens=cap,
                         preemption_mode=mode)


# ---------------------------------------------------------------------------
# the oracle: the pre-refactor monolithic run() loop, verbatim
# ---------------------------------------------------------------------------

def legacy_run(eng: ServingEngine, workload, max_iterations=100_000):
    """Transcription of ServingEngine.run() before the steppable refactor.
    Uses loop-local pending/live exactly as the seed code did; the private
    helpers (_prefill_request/_emit/_preempt/_swap_in/_tick) are shared
    with the refactored engine, so any drift in the step decomposition
    shows up as a diff against this."""
    pending = sorted(workload, key=lambda r: r.arrival)
    live = []

    def admit_arrivals():
        while pending and pending[0].arrival <= eng.now:
            r = pending.pop(0)
            r.fluid_idx = eng.fluid.add(r.arrival, r.spec)
            r.state = ReqState.WAITING
            live.append(r)
            eng.sched.on_request_arrival(r)

    while (pending or live) and eng.iterations < max_iterations:
        if not live and pending:
            eng.now = max(eng.now, pending[0].arrival)
        admit_arrivals()
        if not live:
            continue

        target = eng.sched.schedule(eng.now, live, eng.fluid)
        target_ids = {id(r) for r in target}

        for r in list(eng.slot_req.values()):
            if id(r) not in target_ids and r.state == ReqState.RUNNING:
                eng._preempt(r)
        for r in target:
            if r.state == ReqState.SWAPPED and eng.kv.can_allocate(r):
                eng._swap_in(r)
            elif r.state == ReqState.WAITING and eng.kv.can_allocate(r):
                r.state = ReqState.RUNNING
                r.prefilled = True
                eng._prefill_request(r)

        active = {s: r for s, r in eng.slot_req.items()
                  if r.state == ReqState.RUNNING}
        if active:
            lengths = np.zeros(eng.kv.num_slots, np.int32)
            tokens = np.zeros(eng.kv.num_slots, np.int32)
            for s, r in active.items():
                lengths[s] = r.context_len
                tokens[s] = r.output_tokens[-1] if r.output_tokens else 0
            eng.cache["length"] = jnp.asarray(lengths)
            logits, eng.cache = eng._decode(
                eng.params, jnp.asarray(tokens), eng.cache
            )
            total_ctx = int(lengths.sum())
            eng._tick(eng.lat.iter_latency(len(active), total_ctx))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s, r in list(active.items()):
                eng._emit(r, int(nxt[s]))
        else:
            eng._tick(eng.lat.hw.overhead)

        eng.iterations += 1
        live = [r for r in live if r.is_live]
        admit_arrivals()

    return workload


def assert_bitforbit(out_a, out_b):
    """Token ids, emission timestamps, preemptions, and final QoE must be
    *identical* — not merely close."""
    assert len(out_a) == len(out_b)
    for a, b in zip(out_a, out_b):
        assert a.rid == b.rid
        assert a.output_tokens == b.output_tokens, a.rid
        assert a.emit_times == b.emit_times, a.rid        # exact floats
        assert a.preemptions == b.preemptions, a.rid
        assert a.generated == b.generated, a.rid
        assert a.final_qoe() == b.final_qoe(), a.rid
        assert (np.isnan(a.finish_time) and np.isnan(b.finish_time)) \
            or a.finish_time == b.finish_time, a.rid


# ---------------------------------------------------------------------------
# protocol conformance
# ---------------------------------------------------------------------------

def test_engine_satisfies_steppable_backend(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    eng = mk_engine(m, params, lat)
    assert isinstance(eng, SteppableBackend)
    sim = ServingSimulator(make_scheduler("andes", 512, lat), lat,
                           SimConfig(kv_capacity_tokens=512))
    assert isinstance(sim, SteppableBackend)
    # the protocol members the cluster layer actually calls
    for member in ("submit", "step", "result", "has_work",
                   "pending", "live", "seen", "now", "sched", "fluid"):
        assert hasattr(eng, member), member
    assert isinstance(eng.result(), SimResult)


# ---------------------------------------------------------------------------
# stepped ≡ legacy, all drive styles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched_name", ["fcfs", "andes"])
def test_run_equals_legacy_uncontended(llama, sched_name):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(0)
    wl = mk_wl(cfg, rng)

    ref = mk_engine(m, params, lat, sched_name=sched_name)
    out_ref = legacy_run(ref, clone(wl), max_iterations=2000)

    new = mk_engine(m, params, lat, sched_name=sched_name)
    out_new = new.run(clone(wl), max_iterations=2000)

    assert_bitforbit(out_new, out_ref)
    assert new.now == ref.now
    assert new.iterations == ref.iterations
    assert new.preemptions == ref.preemptions


@pytest.mark.parametrize("mode", [
    "swap",
    pytest.param("recompute", marks=pytest.mark.slow),
])
def test_run_equals_legacy_under_contention(llama, mode):
    """Tight KV budget + 2 slots forces preemption/swap-in traffic; the
    stepped engine must replay the exact same event sequence."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(1)
    wl = mk_wl(cfg, rng, n=8, out_len=15, stagger=0.01, plo=5, phi=20)
    kw = dict(sched_name="andes", cap=100, num_slots=2, mode=mode,
              sched_cfg=SchedulerConfig(delta_t=5.0))

    ref = mk_engine(m, params, lat, **kw)
    out_ref = legacy_run(ref, clone(wl), max_iterations=2000)
    assert ref.preemptions > 0, "test requires contention"

    new = mk_engine(m, params, lat, **kw)
    out_new = new.run(clone(wl), max_iterations=2000)

    assert_bitforbit(out_new, out_ref)
    assert new.preemptions == ref.preemptions
    assert new.kv.swap_bytes_total == ref.kv.swap_bytes_total


def test_manual_stepping_equals_run(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(2)
    wl = mk_wl(cfg, rng)

    a = mk_engine(m, params, lat)
    out_a = a.run(clone(wl), max_iterations=2000)

    b = mk_engine(m, params, lat)
    wl_b = clone(wl)
    for r in wl_b:
        b.submit(r)
    while b.step():
        pass
    assert_bitforbit(wl_b, out_a)
    assert not b.has_work
    assert not b.step()                      # idempotent once drained


def test_incremental_submit_equals_upfront(llama):
    """Cluster-style drive: step to each arrival, submit, continue. The
    request is admitted at the same iteration boundary as the all-upfront
    run, so the timelines are identical (this is the invariant that makes
    a routed engine replica ≡ a bare engine). Like Replica.advance_to,
    the driver passes `until` so the engine's multi-step fast path — which
    the upfront run bounds by its visible pending queue — never fuses
    past an arrival this driver has not submitted yet."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(3)
    wl = mk_wl(cfg, rng)

    a = mk_engine(m, params, lat)
    out_a = a.run(clone(wl), max_iterations=2000)

    b = mk_engine(m, params, lat)
    wl_b = clone(wl)
    for r in wl_b:
        # replica.advance_to(r.arrival): run iterations until the clock
        # reaches the arrival (may overshoot — iterations are indivisible)
        while b.has_work and b.now < r.arrival:
            if not b.step(until=r.arrival):
                break
        b.submit(r)
    while b.step():
        pass
    assert_bitforbit(wl_b, out_a)


def test_result_snapshot(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(4)
    wl = mk_wl(cfg, rng, n=5, out_len=8)
    eng = mk_engine(m, params, lat)
    eng.run(clone(wl), max_iterations=1000)
    res = eng.result()
    assert res.makespan == eng.now
    assert res.total_tokens == sum(r.generated for r in res.requests)
    assert res.iterations == eng.iterations
    assert len(res.batch_sizes) == res.iterations
    assert res.preemptions == eng.preemptions
    assert len(res.requests) == 5
    assert res.avg_qoe() > 0.0


def test_reset_gives_fresh_engine(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(5)
    wl = mk_wl(cfg, rng, n=4, out_len=6)

    eng = mk_engine(m, params, lat)
    first = eng.run(clone(wl), max_iterations=1000)
    eng.reset()
    assert eng.now == 0.0 and not eng.seen and not eng.has_work
    second = eng.run(clone(wl), max_iterations=1000)
    assert_bitforbit(second, first)
    # run() itself resets (same batch semantics as ServingSimulator.run),
    # so back-to-back runs need no manual reset
    third = eng.run(clone(wl), max_iterations=1000)
    assert_bitforbit(third, first)
    assert len(eng.result().requests) == len(wl)


def test_stuck_engine_halts_instead_of_spinning(llama):
    """A prompt larger than the KV capacity can never be scheduled; the
    steppable engine must detect the deadlock and stop returning True
    (the legacy loop spun on overhead ticks until max_iterations)."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    big = Request(rid=0, arrival=0.0, prompt_len=50, output_len=4,
                  spec=QoESpec(ttft=1.0, tds=4.8),
                  prompt_tokens=np.zeros(50, np.int64))
    eng = mk_engine(m, params, lat, cap=20, num_slots=2, max_seq=64)
    eng.submit(big)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 50, "engine failed to detect deadlock"
    assert eng.stuck
    assert big.generated == 0
    # a feasible later submit clears the flag and serves normally
    ok = Request(rid=1, arrival=eng.now, prompt_len=5, output_len=4,
                 spec=QoESpec(ttft=1.0, tds=4.8),
                 prompt_tokens=np.zeros(5, np.int64))
    eng.submit(ok)
    assert not eng.stuck
    while eng.step():
        pass
    assert ok.generated >= ok.output_len


def test_pending_arrival_unsticks_idle_engine(llama):
    """An unschedulable request idles the batch, but a *pending* feasible
    arrival must still be admitted when the overhead ticks reach its
    arrival time — the deadlock guard may only halt when no admission,
    decode, preemption, or new arrival happened. The served request's
    timeline must match the legacy loop exactly."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)

    def wl():
        return [
            Request(rid=0, arrival=0.0, prompt_len=50, output_len=4,
                    spec=QoESpec(ttft=1.0, tds=4.8),
                    prompt_tokens=np.zeros(50, np.int64)),
            Request(rid=1, arrival=0.05, prompt_len=5, output_len=4,
                    spec=QoESpec(ttft=1.0, tds=4.8),
                    prompt_tokens=np.arange(5, dtype=np.int64)),
        ]

    ref = mk_engine(m, params, lat, cap=20, num_slots=2)
    out_ref = legacy_run(ref, wl(), max_iterations=300)

    eng = mk_engine(m, params, lat, cap=20, num_slots=2)
    out = wl()
    for r in out:
        eng.submit(r)
    while eng.step():
        pass
    assert eng.stuck
    assert out[0].generated == 0
    assert out[1].generated >= out[1].output_len
    # the request that did get served matches the legacy loop exactly
    assert out[1].output_tokens == out_ref[1].output_tokens
    assert out[1].emit_times == out_ref[1].emit_times


# ---------------------------------------------------------------------------
# property test: randomized traces and QoE specs
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(3, 7),
       st.floats(0.3, 2.0), st.floats(2.0, 10.0))
@settings(max_examples=5, deadline=None)
@pytest.mark.slow
def test_property_stepped_equals_legacy(seed, n, ttft, tds):
    """Random arrival traces and QoE specs, tight capacity (so contention
    and preemption paths are exercised): stepped ≡ legacy bit-for-bit."""
    cfg, m, params = _llama()
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(seed)
    wl = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(0.15))
        plen = int(rng.integers(4, 16))
        wl.append(Request(
            rid=i, arrival=t, prompt_len=plen,
            output_len=int(rng.integers(4, 12)),
            spec=QoESpec(ttft=ttft, tds=tds),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    kw = dict(sched_name="andes", cap=70, num_slots=2, max_seq=64,
              sched_cfg=SchedulerConfig(delta_t=5.0))

    ref = mk_engine(m, params, lat, **kw)
    out_ref = legacy_run(ref, clone(wl), max_iterations=1500)
    new = mk_engine(m, params, lat, **kw)
    out_new = new.run(clone(wl), max_iterations=1500)
    assert_bitforbit(out_new, out_ref)
