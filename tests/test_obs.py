"""Observability layer (repro.obs): differential + reconciliation suite.

The layer's contract has two halves, both pinned here:

1. **Zero behavioral footprint.** Attaching any observer stack (trace +
   metrics + profiling) to any backend — discrete-event simulator, real-
   model engine, speculative engine, 1-replica cluster — produces output
   BIT-FOR-BIT identical to the uninstrumented run: token ids, emission
   timestamps, preemption counts, final QoE. Observation never perturbs.

2. **Faithful record.** The trace is complete enough to *recompute* the
   QoE story from scratch: `qoe_from_trace` (pure function of recorded
   events) must equal every engine-reported `Request.final_qoe()`
   exactly, the metrics registry must agree with the engine's private
   hot-path counters, and every export (JSONL, Chrome-trace/Perfetto,
   Prometheus text, JSON) must round-trip losslessly.

Plus the plumbing: PR 4's legacy `event_sink` callable keeps working
through EventSinkAdapter and composes with observers; the cluster stamps
every event with its replica id via ScopedObserver; scheduler decisions
carry their pricing payloads (gains, victim sets); autoscale events carry
the attainment signal that drove them.
"""
import copy
import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    A100_4X,
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    make_scheduler,
)
from repro.core.request import Request
from repro.cluster import (
    AutoscalerConfig,
    ClusterConfig,
    ClusterSimulator,
)
from repro.obs import (
    EventSinkAdapter,
    MetricsObserver,
    MetricsRegistry,
    MultiObserver,
    Observer,
    ProfilingObserver,
    ScopedObserver,
    TraceRecorder,
    compose,
    parse_prometheus,
    qoe_from_trace,
    register_backend_gauges,
)
from repro.obs.metrics import registry_samples_dict
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_workload

CFG = get_config("opt-66b")
LAT = LatencyModel(CFG, A100_4X)
M = 65_000


def make_sim(scheduler="andes", kv=M):
    sched = make_scheduler(scheduler, kv, LAT, SchedulerConfig())
    return ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=kv))


def full_stack(registry=None, **trace_kw):
    """The complete observer stack: trace + metrics + profiling."""
    reg = registry if registry is not None else MetricsRegistry()
    tr = TraceRecorder(**trace_kw)
    return tr, reg, compose(tr, MetricsObserver(reg), ProfilingObserver(reg))


def fingerprint(reqs):
    """Everything the zero-footprint contract promises, per request."""
    return [(r.rid, tuple(r.output_tokens), tuple(r.emit_times),
             r.preemptions, r.final_qoe())
            for r in sorted(reqs, key=lambda r: r.rid)]


def assert_trace_reconciles(events, reqs):
    """QoE recomputed purely from the trace == engine-reported, exactly."""
    traced = qoe_from_trace(events)
    for r in reqs:
        assert traced.get(r.rid, 0.0) == r.final_qoe(), r.rid


# ---------------------------------------------------------------------------
# Zero footprint: instrumented == uninstrumented, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["andes", "fcfs"])
def test_simulator_instrumented_bit_identical(scheduler):
    # tight KV so preemption/swap-in events are exercised too
    wl = make_workload(80, 8.0, seed=3, arrival="gamma", cv=3.0)
    base = make_sim(scheduler, kv=12_000).run(copy.deepcopy(wl))

    sim = make_sim(scheduler, kv=12_000)
    trace, reg, stack = full_stack()
    sim.observer = stack
    inst = sim.run(copy.deepcopy(wl))

    assert fingerprint(base.requests) == fingerprint(inst.requests)
    if scheduler == "andes":
        assert any(r.preemptions > 0 for r in inst.requests)
    assert_trace_reconciles(trace.events, inst.requests)
    # metrics agree with the result snapshot
    n = len(inst.requests)
    assert reg.value("requests_finished_total") == n
    assert reg.value("tokens_emitted_total") == sum(
        r.generated for r in inst.requests)
    total_preempts = sum(v for _, _, v
                         in reg.get("preemptions_total").samples())
    assert total_preempts == sum(r.preemptions for r in inst.requests)
    assert reg.get("ttft_seconds").count() == n
    assert reg.value("live_requests") == 0


def test_one_replica_cluster_instrumented_bit_identical():
    wl = make_workload(100, 4.0, seed=13, arrival="gamma", cv=3.0)
    base = ClusterSimulator(
        LAT, ClusterConfig(n_replicas=1, kv_capacity_tokens=M)
    ).run(copy.deepcopy(wl))

    cs = ClusterSimulator(
        LAT, ClusterConfig(n_replicas=1, kv_capacity_tokens=M))
    trace, reg, stack = full_stack()
    cs.observer = stack
    inst = cs.run(copy.deepcopy(wl))

    assert fingerprint(base.admitted) == fingerprint(inst.admitted)
    assert_trace_reconciles(trace.events, inst.admitted)
    # every request-lifecycle event is stamped with the serving replica
    for ev in trace.events:
        if ev.kind in ("emit", "prefill", "finish"):
            assert ev.replica == 0
    # fleet-level routing/admission events exist for every request
    assert sum(e.kind == "route" for e in trace.events) == len(wl)
    assert sum(e.kind == "admission" for e in trace.events) == len(wl)


# ---------------------------------------------------------------------------
# Real-model engine (incl. speculative): bit-for-bit + counter agreement
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine_workload(cfg, n=8, seed=5):
    rng = np.random.default_rng(seed)
    wl = []
    for i in range(n):
        plen = int(rng.integers(8, 24))
        wl.append(Request(
            rid=i, arrival=i * 0.02, prompt_len=plen,
            output_len=int(rng.integers(8, 16)),
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    return wl


def _build_engine(cfg, model, params, spec_k=0):
    from repro.core import SpeculativeLatencyModel, TPU_V5E
    from repro.serving import ServingEngine

    if spec_k:
        lat = SpeculativeLatencyModel(cfg, TPU_V5E, cfg, k=spec_k)
        extra = dict(draft_model=model, draft_params=params, spec_k=spec_k)
    else:
        lat = LatencyModel(cfg, TPU_V5E)
        extra = {}
    return ServingEngine(
        model, params, make_scheduler("andes", 160, lat), lat,
        num_slots=3, max_seq=64, capacity_tokens=160, **extra)


@pytest.mark.parametrize("spec_k", [0, 2])
def test_engine_instrumented_bit_identical(engine_setup, spec_k):
    cfg, model, params = engine_setup
    wl = _engine_workload(cfg)

    base_wl = [r.clone() for r in wl]
    _build_engine(cfg, model, params, spec_k).run(base_wl)

    eng = _build_engine(cfg, model, params, spec_k)
    trace, reg, stack = full_stack()
    eng.observer = stack
    register_backend_gauges(reg, eng)
    inst_wl = [r.clone() for r in wl]
    eng.run(inst_wl)

    assert fingerprint(base_wl) == fingerprint(inst_wl)
    assert_trace_reconciles(trace.events, inst_wl)

    # registry counters == the engine's private hot-path counters
    hs = eng.hotpath_stats()
    assert reg.value("engine_host_syncs_total") == hs["host_syncs"]
    dispatches = sum(v for _, _, v
                     in reg.get("engine_dispatches_total").samples())
    assert dispatches == hs["dispatches"]
    # jit_compiles counts compile EVENTS (one per jit cache x shape: the
    # speculative engine's draft cache recompiles the same signatures);
    # hotpath_stats reports unique shape signatures across the caches
    n_caches = 2 if spec_k else 1
    assert reg.value("engine_jit_compiles_total") == \
        n_caches * hs["prefill_compiles"]
    assert reg.value("engine_multi_step_blocks_total") == \
        hs["multi_step_blocks"]
    if spec_k:
        proposed = reg.value("engine_spec_proposed_total")
        accepted = reg.value("engine_spec_accepted_total")
        assert proposed > 0 and 0 < accepted <= proposed
        assert reg.value("spec_acceptance_rate") == accepted / proposed

    # KV gauges read live state and survive reset() (same manager object)
    assert reg.value("kv_tokens_peak") == eng.kv.peak_tokens_used > 0
    kv_obj = eng.kv
    eng.reset()
    assert eng.kv is kv_obj
    assert reg.value("kv_tokens_peak") == 0
    assert reg.value("kv_tokens_used") == 0
    assert reg.value("kv_slots_in_use") == 0


def test_kv_manager_reset_clears_all_occupancy():
    from repro.serving.kv_manager import KVSlotManager

    kv = KVSlotManager(num_slots=4, max_seq=32, capacity_tokens=100)
    r = Request(rid=0, arrival=0.0, prompt_len=10, output_len=5,
                spec=QoESpec(ttft=1.0, tds=4.8))
    kv.allocate(r)
    kv.grow(r, 3)
    assert kv.tokens_used == 13 and kv.peak_tokens_used == 13
    assert kv.slots_in_use == 1
    occ = kv.occupancy()
    assert occ["utilization"] == 13 / 100 and occ["slots_in_use"] == 1
    kv.reset()
    assert kv.tokens_used == 0 and kv.peak_tokens_used == 0
    assert kv.slots_in_use == 0 and not kv.host_store and not kv.draft_store
    assert kv.swap_bytes_total == 0


# ---------------------------------------------------------------------------
# Trace exports: JSONL, Chrome-trace/Perfetto
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run():
    wl = make_workload(60, 8.0, seed=7, arrival="gamma", cv=3.0)
    sim = make_sim(kv=12_000)
    trace = TraceRecorder()
    reg = MetricsRegistry()
    sim.observer = compose(trace, MetricsObserver(reg, snapshot_every=5.0))
    res = sim.run(wl)
    return trace, reg, res


def test_jsonl_round_trip(traced_run, tmp_path):
    trace, _, _ = traced_run
    evs = TraceRecorder.from_jsonl(trace.to_jsonl())
    assert [e.to_json() for e in evs] == [e.to_json() for e in trace.events]
    # and through a file
    p = tmp_path / "trace.jsonl"
    trace.save_jsonl(p)
    evs2 = TraceRecorder.load_jsonl(p)
    assert [e.to_json() for e in evs2] == [e.to_json() for e in trace.events]
    # timestamps round-trip exactly (repr floats), so a reloaded trace
    # still reconciles bit-for-bit
    assert qoe_from_trace(evs2) == qoe_from_trace(trace.events)


def test_qoe_from_trace_tolerates_out_of_order_events(traced_run):
    """Regression (ISSUE 9 sat. 1): wall-clock runs interleave replicas and
    server connections, so a merged trace can deliver a request's events in
    any file order. Pre-fix, qoe_from_trace fed emit timestamps to
    pace_delivery in file order (order-sensitive: an unsorted timeline
    yields a different delivery curve) and took the first-seen arrival
    event rather than the earliest — both silently wrong on shuffled
    input. Now the reconstruction must be permutation-invariant and still
    reconcile exactly with the backend-reported QoE."""
    trace, _, res = traced_run
    ref = qoe_from_trace(trace.events)
    rng = np.random.default_rng(0)
    for _ in range(3):
        shuffled = list(trace.events)
        rng.shuffle(shuffled)
        assert qoe_from_trace(shuffled) == ref
    # still reconciles with the ground truth after shuffling
    shuffled = list(trace.events)[::-1]
    traced = qoe_from_trace(shuffled)
    for r in res.requests:
        assert traced.get(r.rid, 0.0) == r.final_qoe()


def test_qoe_from_trace_earliest_arrival_wins():
    """A fleet hand-off records two arrival events for one rid (fleet-level
    then replica-level); writer interleaving can put the later one first in
    the file. The earliest timestamp is the user's true arrival."""
    from repro.obs.trace import TraceEvent
    contract = dict(ttft=1.0, tds=4.8)
    evs = [
        # later (replica) arrival appears FIRST in file order
        TraceEvent("arrival", 5.0, 1, 0, dict(contract)),
        TraceEvent("arrival", 2.0, 1, -1, dict(contract)),
        TraceEvent("emit", 6.0, 1, 0, {"k": 1, "total": 1}),
        TraceEvent("emit", 7.0, 1, 0, {"k": 1, "total": 2}),
    ]
    from repro.core import QoESpec
    from repro.core.qoe import qoe_exact
    want = float(qoe_exact(np.array([6.0, 7.0]), 2.0,
                           QoESpec(ttft=1.0, tds=4.8), response_len=2))
    assert qoe_from_trace(evs) == {1: want}
    assert qoe_from_trace(evs[::-1]) == {1: want}


def test_merge_traces_sorted_and_stable(traced_run):
    from repro.obs.trace import merge_traces
    trace, _, _ = traced_run
    evs = trace.events
    a, b = evs[::2], evs[1::2]
    merged = merge_traces(a, b)
    assert len(merged) == len(evs)
    assert all(x.t <= y.t for x, y in zip(merged, merged[1:]))
    assert qoe_from_trace(merged) == qoe_from_trace(evs)


def test_chrome_trace_export_valid_and_monotone(traced_run, tmp_path):
    trace, _, res = traced_run
    ct = trace.to_chrome_trace()
    # valid JSON, the format Perfetto/chrome://tracing loads
    p = tmp_path / "trace.json"
    trace.save_chrome_trace(p)
    loaded = json.loads(p.read_text())
    assert loaded == json.loads(json.dumps(ct))
    assert loaded["displayTimeUnit"] == "ms"

    events = loaded["traceEvents"]
    phases = {e["ph"] for e in events}
    assert "i" in phases and "X" in phases and "M" in phases
    # per-track instants must be time-ordered (Perfetto requirement)
    last = {}
    for e in events:
        if e["ph"] == "M":
            continue
        key = (e["pid"], e["tid"])
        assert e["ts"] >= last.get(key, -1), key
        last[key] = e["ts"]
    # one span per finished/shed request, covering arrival -> end
    spans = [e for e in events if e["ph"] == "X"]
    assert len(spans) == len(res.requests)
    assert all(s["dur"] >= 0 for s in spans)


# ---------------------------------------------------------------------------
# Metrics registry: Prometheus + JSON round-trips, snapshots, histograms
# ---------------------------------------------------------------------------

def test_prometheus_export_round_trip(traced_run):
    _, reg, _ = traced_run
    text = reg.to_prometheus()
    assert "# TYPE requests_finished_total counter" in text
    assert "# TYPE ttft_seconds histogram" in text
    assert parse_prometheus(text) == registry_samples_dict(reg)


def test_registry_json_round_trip(traced_run):
    _, reg, _ = traced_run
    clone = MetricsRegistry.from_json(reg.to_json())
    assert registry_samples_dict(clone) == registry_samples_dict(reg)


def test_snapshots_on_virtual_clock(traced_run):
    _, reg, res = traced_run
    assert reg.snapshots, "periodic snapshots never fired"
    ts = [s["t"] for s in reg.snapshots]
    assert ts == sorted(ts)
    # snapshots ride the virtual clock, so they are bounded by the run
    assert ts[-1] <= max(r.finish_time for r in res.requests)
    # each snapshot carries full samples (find the finished counter)
    names = {s[0] for s in reg.snapshots[-1]["samples"]}
    assert "requests_finished_total" in names


def test_histogram_buckets_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.7, 3.0, 100.0):
        h.observe(v)
    samples = {(name, tuple(sorted(labels.items()))): v
               for name, labels, v in h.samples()}
    assert samples[("lat_bucket", (("le", "1.0"),))] == 1
    assert samples[("lat_bucket", (("le", "2.0"),))] == 3
    assert samples[("lat_bucket", (("le", "4.0"),))] == 4
    assert samples[("lat_bucket", (("le", "+Inf"),))] == 5
    assert samples[("lat_count", ())] == 5
    assert samples[("lat_sum", ())] == pytest.approx(106.7)


# ---------------------------------------------------------------------------
# Scheduler decision + fleet event payloads
# ---------------------------------------------------------------------------

def test_scheduler_decision_events_carry_pricing_payload():
    wl = make_workload(80, 8.0, seed=3, arrival="gamma", cv=3.0)
    sim = make_sim(kv=12_000)
    trace = TraceRecorder()
    sim.observer = trace
    sim.run(wl)

    decisions = [e for e in trace.events if e.kind == "schedule"]
    assert decisions
    assert all(d.data["policy"] == "andes" for d in decisions)
    triggered = [d for d in decisions if d.data.get("triggered")]
    assert triggered, "tight KV never triggered the knapsack"
    for d in triggered:
        assert d.data["knapsack_value"] > -np.inf
        assert d.data["b_chosen"] <= max(d.data["b_candidates"])
        assert "q_wait_mean" in d.data        # BatchPricing.summary()
        # the full gain vector rides along when the live set is small
        if "gains" in d.data:
            assert len(d.data["gains"]) == d.data["n_live"]
    # a preempting decision names its victims
    assert any(d.data["victims"] for d in triggered)


def test_cluster_scale_events_carry_signal():
    cfg = ClusterConfig(
        n_replicas=1, router="qoe", kv_capacity_tokens=15_000,
        autoscaler=AutoscalerConfig(
            min_replicas=1, max_replicas=4,
            provision_delay=5.0, cooldown=10.0, window=15.0,
        ),
    )
    wl = make_workload(200, 8.0, seed=2, arrival="gamma", cv=3.0)
    cs = ClusterSimulator(LAT, cfg)
    trace = TraceRecorder(lifecycle_only=True)
    cs.observer = trace
    res = cs.run(wl)
    assert res.peak_replicas > 1

    scale = [e for e in trace.events if e.kind == "scale"]
    ups = [e for e in scale if e.data["action"] == "scale_up"]
    assert ups
    for e in ups:
        sig = e.data["signal"]
        assert sig is not None and "slo_attainment" in sig
    assert any(e.data["action"] == "provision_ready" for e in scale)
    # routed emits carry the id of the replica that served them
    replicas_seen = {e.replica for e in trace.events if e.kind == "emit"}
    assert len(replicas_seen) > 1
    # route decisions carry per-replica scores once the fleet has grown
    routes = [e for e in trace.events if e.kind == "route"]
    assert any(e.data["scores"] and len(e.data["scores"]) > 1
               for e in routes)
    assert_trace_reconciles(trace.events, res.admitted)


# ---------------------------------------------------------------------------
# Composition + legacy event_sink compatibility
# ---------------------------------------------------------------------------

def test_compose_flattens_and_filters():
    a, b, c = TraceRecorder(), TraceRecorder(), TraceRecorder()
    assert compose() is None
    assert compose(None, None) is None
    assert compose(a) is a
    m = compose(a, None, compose(b, c))
    assert isinstance(m, MultiObserver)
    assert m.children == (a, b, c)


def test_multi_observer_fans_out_and_scoped_stamps():
    t1, t2 = TraceRecorder(), TraceRecorder()
    m = MultiObserver(t1, t2)
    r = Request(rid=9, arrival=0.0, prompt_len=4, output_len=2,
                spec=QoESpec(ttft=1.0, tds=4.8))
    m.submit(r, 0.0)
    m.emit(r, 1.0, 1)
    assert [e.kind for e in t1.events] == ["arrival", "first_token", "emit"]
    assert [e.to_json() for e in t1.events] == [e.to_json()
                                               for e in t2.events]

    t3 = TraceRecorder()
    s = ScopedObserver(t3, replica=5)
    s.submit(r, 0.0)
    s.emit(r, 1.0, 1)
    assert all(e.replica == 5 for e in t3.events)
    # an already-stamped event passes through untouched
    s.emit(r, 2.0, 1, replica=7)
    assert t3.events[-1].replica == 7


def test_legacy_event_sink_still_works_and_composes():
    wl = make_workload(40, 8.0, seed=3, arrival="gamma", cv=3.0)
    base = make_sim(kv=12_000).run(copy.deepcopy(wl))

    sim = make_sim(kv=12_000)
    seen = []
    trace = TraceRecorder()
    sim.observer = trace                       # observer AND legacy sink
    sim.event_sink = lambda kind, req, t, k: seen.append((kind, req.rid, k))
    res = sim.run(copy.deepcopy(wl))

    assert fingerprint(base.requests) == fingerprint(res.requests)
    kinds = {kind for kind, _, _ in seen}
    assert kinds >= {"emit", "finish"}
    # the sink saw exactly the emitted tokens the trace saw
    assert sum(k for kind, _, k in seen if kind == "emit") == \
        sum(e.data["k"] for e in trace.events if e.kind == "emit")
    # adapter maps hooks -> legacy (kind, req, t, k) tuples
    sink_calls = []
    ad = EventSinkAdapter(lambda *a: sink_calls.append(a))
    r = res.requests[0]
    ad.emit(r, 1.0, 2)
    ad.finish(r, 2.0)
    assert sink_calls == [("emit", r, 1.0, 2), ("finish", r, 2.0, 0)]


def test_client_streaming_composes_with_observers():
    """ServingClient (now observer-based) must coexist with a user trace:
    both see the same stream, and behavior stays bit-identical."""
    from repro.api import ServingClient

    wl = make_workload(40, 4.0, seed=17, arrival="gamma", cv=3.0)
    direct = make_sim().run(copy.deepcopy(wl))

    sim = make_sim()
    trace = TraceRecorder()
    sim.observer = trace                      # user observer first
    client = ServingClient(sim)               # client attaches alongside
    handles = [client.submit_request(r) for r in copy.deepcopy(wl)]
    client.drain()

    d = {r.rid: r for r in direct.requests}
    for h in handles:
        assert d[h.rid].emit_times == h.request.emit_times
        assert d[h.rid].final_qoe() == h.qoe()
    assert_trace_reconciles(trace.events, [h.request for h in handles])


def test_null_observer_is_inert_default():
    """The Observer base is a pure no-op: every hook returns None, and an
    unobserved backend holds no observer at all."""
    obs = Observer()
    r = Request(rid=0, arrival=0.0, prompt_len=4, output_len=2,
                spec=QoESpec(ttft=1.0, tds=4.8))
    assert obs.submit(r, 0.0) is None
    assert obs.emit(r, 0.0, 1) is None
    assert obs.schedule(0.0, {}) is None
    sim = make_sim()
    assert sim.obs is None and sim.observer is None
