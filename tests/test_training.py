"""Training substrate: optimizer, convergence, microbatching, checkpoint."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.training import (
    OptimizerConfig,
    build_train_step,
    init_train_state,
    lr_schedule,
    packed_batches,
    restore_checkpoint,
    save_checkpoint,
)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[10]                       # warmup
    assert lrs[10] == pytest.approx(1e-3, rel=0.01)
    assert lrs[100] == pytest.approx(1e-4, rel=0.05)   # min ratio 0.1


def test_loss_decreases():
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params, opt = init_train_state(m, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(m, OptimizerConfig(lr=1e-3, warmup_steps=5,
                                                       total_steps=50)))
    it = packed_batches(cfg.vocab_size, 8, 64, seed=0)
    losses = []
    for _ in range(50):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, metr = step(params, opt, batch)
        losses.append(float(metr["loss"]))
    assert losses[-1] < losses[0] - 1.0


@pytest.mark.slow
def test_microbatched_grads_match_full():
    """Gradient accumulation must equal the full-batch gradient step."""
    cfg = get_smoke_config("granite-3-2b")
    m = Model(cfg)
    params, opt = init_train_state(m, jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    full = jax.jit(build_train_step(m, ocfg, microbatches=1, remat=False))
    micro = jax.jit(build_train_step(m, ocfg, microbatches=4, remat=False))
    it = packed_batches(cfg.vocab_size, 8, 32, seed=1)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    p1, _, m1 = full(params, opt, batch)
    p2, _, m2 = micro(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-4)


@pytest.mark.slow
def test_remat_matches_no_remat():
    cfg = get_smoke_config("llama3-8b")
    batch_it = packed_batches(cfg.vocab_size, 4, 32, seed=2)
    batch = {k: jnp.asarray(v) for k, v in next(batch_it).items()}
    m_plain = Model(cfg)
    m_remat = Model(cfg, remat=True)
    params = m_plain.init(jax.random.PRNGKey(0))
    l1 = float(m_plain.loss(params, batch))
    l2 = float(m_remat.loss(params, batch))
    assert l1 == pytest.approx(l2, rel=1e-6)
    g1 = jax.grad(m_plain.loss)(params, batch)
    g2 = jax.grad(m_remat.loss)(params, batch)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    m = Model(cfg)
    params, opt = init_train_state(m, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save_checkpoint(path, params, opt, step=7)
        p2, o2, step = restore_checkpoint(path, params, opt)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_synthetic_corpus_learnable_structure():
    from repro.training.data import SyntheticCorpus
    c = SyntheticCorpus(128, seed=0, bigram_strength=0.8)
    toks = c.sample(5000)
    # successor structure: P(succ | tok) should be high
    hits = sum(1 for i in range(len(toks) - 1) if toks[i + 1] == c.succ[toks[i]])
    assert hits / len(toks) > 0.5
