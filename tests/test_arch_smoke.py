"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate the REDUCED variant of the
same family (<= 2-4 layers, d_model <= 512, <= 4 experts), run one forward
AND one train step on CPU, assert output shapes and no NaNs; then exercise
the serve path (prefill + decode) and check it is consistent with the full
forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Model
from repro.training import OptimizerConfig, build_train_step, init_train_state

ASSIGNED = [a for a in ARCH_IDS if a != "opt-66b"]


def make_batch(cfg, rng, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
    }
    # next-token labels (identity labels give ~0 loss on tied-embed models)
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.kind in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(rng, (b, s, cfg.d_model)) * 0.1
    if cfg.kind == "vlm":
        batch["patch_embeds"] = jax.random.normal(rng, (b, 4, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = m.forward_train(params, batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.slow
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params, opt = init_train_state(m, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(m, OptimizerConfig(warmup_steps=1,
                                                       total_steps=10)))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, jax.random.PRNGKey(1)).items()}
    params2, opt2, metrics = step(params, opt, batch)
    assert float(metrics["loss"]) > 0 and not np.isnan(float(metrics["loss"]))
    assert not np.isnan(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S, G = 2, 12, 3
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + G), 0, cfg.vocab_size)
    extra = {}
    if cfg.kind in ("encdec", "audio"):
        extra["frames"] = jax.random.normal(jax.random.PRNGKey(3), (B, 8, cfg.d_model)) * 0.1
    if cfg.kind == "vlm":
        extra["patch_embeds"] = jax.random.normal(jax.random.PRNGKey(3), (B, 4, cfg.d_model)) * 0.1
    full_logits, _ = m.forward_train(params, {"tokens": toks, **extra})

    n_patch = 4 if cfg.kind == "vlm" else 0
    cache = m.init_cache(B, S + G + n_patch, enc_seq=8, dtype=jnp.float32)
    lg, cache = m.prefill(params, {"tokens": toks[:, :S], **extra}, cache)
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-6
    errs = [float(jnp.max(jnp.abs(lg - full_logits[:, S - 1]))) / scale]
    for t in range(G):
        lg, cache = m.decode_step(params, toks[:, S + t], cache)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, S + t]))) / scale)
    assert max(errs) < 5e-3, errs


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_param_shapes_abstract(arch):
    """The FULL production config must build abstractly (no allocation)."""
    cfg = get_config(arch)
    m = Model(cfg, param_dtype=jnp.bfloat16)
    params = m.abstract_params()
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    # within 12% of the analytic param count (analytic misses small extras)
    assert abs(n - cfg.param_count()) / cfg.param_count() < 0.12
