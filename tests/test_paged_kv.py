"""Paged/block KV accounting (PR 8): manager unit tests + the
degenerate-case differential oracles that pin the refactor.

The two degenerate configurations reproduce the legacy fixed-slot
manager exactly (see kv_manager module docstring):

  * page_size >= max_seq — literally the legacy code path (paged=False);
  * page_size = 1 — one page per token, so page arithmetic IS token
    arithmetic and a paged ENGINE must reproduce the default engine
    bit-for-bit: token ids, emit timestamps, preemptions, final QoE.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    TPU_V5E,
    make_scheduler,
)
from repro.core.policies.base import Scheduler
from repro.models import Model
from repro.serving import KVSlotManager, Request, ServingEngine, fingerprint


def mk_req(rid, ctx, out_len=8):
    return Request(rid=rid, arrival=0.0, prompt_len=ctx, output_len=out_len,
                   spec=QoESpec(ttft=1.0, tds=4.8))


# --------------------------------------------------------------------------
# manager unit tests
# --------------------------------------------------------------------------
class TestPagedManager:
    def test_pool_sizing_and_pages_for(self):
        kv = KVSlotManager(num_slots=4, max_seq=64, capacity_tokens=100,
                           page_size=8)
        assert kv.paged
        assert kv.total_pages == 13            # ceil(100 / 8)
        assert kv.pages_for(0) == 0
        assert kv.pages_for(1) == 1
        assert kv.pages_for(8) == 1
        assert kv.pages_for(9) == 2

    def test_block_table_tracks_growth(self):
        kv = KVSlotManager(num_slots=2, max_seq=64, capacity_tokens=128,
                           page_size=8)
        r = mk_req(0, 10)
        kv.allocate(r)
        assert len(kv.block_table[0]) == 2     # ceil(10/8)
        for _ in range(6):                     # 10 -> 16: still 2 pages
            kv.grow(r)
        assert len(kv.block_table[0]) == 2
        kv.grow(r)                             # 17: crosses the boundary
        assert len(kv.block_table[0]) == 3
        assert kv.pages_used == 3

    def test_release_recycles_pages(self):
        kv = KVSlotManager(num_slots=2, max_seq=64, capacity_tokens=64,
                           page_size=8)
        r0 = mk_req(0, 20)
        kv.allocate(r0)
        held_pages = list(kv.block_table[0])
        kv.release(r0)
        assert kv.pages_used == 0
        assert 0 not in kv.block_table
        r1 = mk_req(1, 20)
        kv.allocate(r1)
        # LIFO pool: the freshly freed pages are reused
        assert set(kv.block_table[1]) == set(held_pages)

    def test_evict_tail_frees_partial_pages(self):
        kv = KVSlotManager(num_slots=2, max_seq=64, capacity_tokens=64,
                           page_size=8)
        r = mk_req(0, 37)
        kv.allocate(r)
        assert kv.pages_used == 5              # ceil(37/8)
        freed = kv.evict_tail(r, 20)
        assert freed == 2                      # 5 -> ceil(20/8) = 3
        assert kv.pages_used == 3
        assert kv.tokens_used == 20
        assert kv.held_tokens[0] == 20
        # shrinking below is a no-op when already at/below target
        assert kv.evict_tail(r, 20) == 0
        kv.release(r)
        assert kv.pages_used == 0
        assert kv.tokens_used == 0

    def test_fragmentation_aware_admission(self):
        """Partially-filled last pages consume whole pages: the page
        check can refuse what the raw token check would admit."""
        kv = KVSlotManager(num_slots=4, max_seq=32, capacity_tokens=32,
                           page_size=8)
        kv.allocate(mk_req(0, 9))              # 2 pages (1 token spills)
        kv.allocate(mk_req(1, 9))              # 2 pages
        assert kv.tokens_used == 18
        assert kv.pages_used == 4              # pool exhausted
        cand = mk_req(2, 8)
        assert kv.tokens_used + 8 <= kv.capacity_tokens   # tokens would fit
        assert not kv.can_allocate(cand)                  # pages do not

    def test_overdraft_is_visible_not_corrupting(self):
        """Like the token ledger, the pool tolerates transient overdraft
        with page_utilization > 1 as the signal; release restores."""
        kv = KVSlotManager(num_slots=4, max_seq=32, capacity_tokens=16,
                           page_size=8)
        r0, r1 = mk_req(0, 16), mk_req(1, 16)
        kv.allocate(r0)
        kv.allocate(r1)                        # forced past the pool
        assert kv.pages_used == 4 > kv.total_pages == 2
        assert kv.page_utilization > 1.0
        assert all(p >= kv.total_pages for p in kv.block_table[1])
        kv.release(r1)
        kv.release(r0)
        assert kv.pages_used == 0
        assert sorted(kv.free_pages) == [0, 1]

    def test_physical_page_reporting_clamps_overdraft(self):
        """Overdraft page ids (>= total_pages) are bookkeeping fictions —
        they name no row of the device pool. The *physical* reporting
        surface must clamp to the pool size (a gauge claiming more rows
        in use than HBM holds is a lie to capacity dashboards), while the
        unclamped page_utilization > 1 overdraft signal stays intact."""
        kv = KVSlotManager(num_slots=4, max_seq=32, capacity_tokens=16,
                           page_size=8)
        r0, r1 = mk_req(0, 16), mk_req(1, 16)
        kv.allocate(r0)
        kv.allocate(r1)                        # forced past the pool
        assert kv.page_utilization > 1.0       # overdraft signal preserved
        assert kv.physical_pages_used == kv.total_pages == 2
        assert kv.physical_page_utilization == 1.0
        occ = kv.occupancy()
        assert occ["physical_pages_used"] == 2
        assert occ["physical_page_utilization"] == 1.0
        kv.release(r1)                         # only overdraft pages leave
        assert kv.physical_pages_used == 2
        assert kv.physical_page_utilization == 1.0
        kv.release(r0)
        assert kv.physical_pages_used == 0
        assert kv.physical_page_utilization == 0.0
        # unpaged managers report zero physical pages, like pages_used
        legacy = KVSlotManager(num_slots=4, max_seq=32, capacity_tokens=256,
                               page_size=32)
        assert not legacy.paged
        assert legacy.physical_pages_used == 0
        assert legacy.physical_page_utilization == 0.0

    def test_page_size_max_seq_is_legacy_path(self):
        kv = KVSlotManager(num_slots=4, max_seq=64, capacity_tokens=256,
                           page_size=64)
        assert not kv.paged
        assert kv.total_pages == kv.num_slots
        r = mk_req(0, 30)
        kv.allocate(r)
        assert kv.block_table == {}            # no page machinery engaged
        occ = kv.occupancy()
        assert occ["paged"] is False
        assert occ["page_size"] == 0
        assert occ["pages_used"] == 0

    def test_swap_roundtrip_preserves_pages(self):
        kv = KVSlotManager(num_slots=2, max_seq=64, capacity_tokens=64,
                           page_size=8)
        r = mk_req(0, 20)
        kv.allocate(r)
        kv.swap_out(r, {"k": np.zeros(16, np.uint8)})
        assert kv.pages_used == 0
        assert kv.tokens_used == 0
        sl = kv.swap_in(r)
        assert sl is not None
        kv.allocate(r)                         # engine re-allocates on swap-in
        assert kv.pages_used == 3
        assert kv.tokens_used == 20


# --------------------------------------------------------------------------
# scheduler capacity view
# --------------------------------------------------------------------------
class TestPagedWeights:
    def test_kv_weight_rounds_to_pages(self):
        lat = LatencyModel(get_smoke_config("llama3-8b"), TPU_V5E)
        sched = Scheduler(1024, lat, SchedulerConfig(page_size=16))
        r = mk_req(0, 17)
        r.generated = 0
        assert sched._kv_weight(r) == 32       # ceil(17/16) * 16
        sched_tok = Scheduler(1024, lat, SchedulerConfig())
        assert sched_tok._kv_weight(r) == 17   # page_size=0: legacy integer

    def test_pack_in_order_uses_page_weights(self):
        lat = LatencyModel(get_smoke_config("llama3-8b"), TPU_V5E)
        sched = Scheduler(64, lat, SchedulerConfig(page_size=16))
        reqs = [mk_req(i, 17) for i in range(3)]   # 32 pages-weight each
        kept = sched._pack_in_order(reqs)
        assert len(kept) == 2                  # 3 * 17 = 51 < 64, but 3 * 32 > 64


# --------------------------------------------------------------------------
# engine differential oracles
# --------------------------------------------------------------------------
def _mk_workload(cfg, n, rng, out_len=12, stagger=0.05):
    wl = []
    for i in range(n):
        plen = int(rng.integers(5, 30))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen)))
    return wl


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _run(cfg, m, params, wl, **kw):
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler(
        "andes", kw.get("capacity_tokens", 4 * 64), lat,
        SchedulerConfig(delta_t=kw.pop("delta_t", 50.0)))
    eng = ServingEngine(m, params, sched, lat, num_slots=kw.pop("num_slots", 4),
                        max_seq=64, **kw)
    out = eng.run([r.clone() for r in wl], max_iterations=4000)
    return out, eng


@pytest.mark.parametrize("page_size", [1, 64])
def test_engine_page_differential_uncontended(llama, page_size):
    """page_size=1 (page check == token check) and page_size=max_seq
    (legacy path) must reproduce the default engine bit-for-bit."""
    cfg, m, params = llama
    rng = np.random.default_rng(0)
    wl = _mk_workload(cfg, 6, rng)
    base, _ = _run(cfg, m, params, wl)
    paged, eng = _run(cfg, m, params, wl, page_size=page_size)
    assert eng.kv.paged == (page_size == 1)
    assert fingerprint(paged) == fingerprint(base)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["swap", "recompute"])
def test_engine_page_differential_contended(llama, mode):
    """Bit-for-bit under preemption pressure in BOTH modes: the paged
    accounting must not shift a single scheduling or preemption decision
    when page granularity is the token (page_size=1)."""
    cfg, m, params = llama
    rng = np.random.default_rng(1)
    wl = _mk_workload(cfg, 8, rng, out_len=15, stagger=0.01)
    base, eng_b = _run(cfg, m, params, wl, num_slots=2, capacity_tokens=100,
                       preemption_mode=mode, delta_t=5.0)
    assert eng_b.preemptions > 0, "test requires contention"
    paged, eng_p = _run(cfg, m, params, wl, num_slots=2, capacity_tokens=100,
                        preemption_mode=mode, delta_t=5.0, page_size=1)
    assert eng_p.preemptions == eng_b.preemptions
    assert fingerprint(paged) == fingerprint(base)


def test_engine_wires_page_size_into_scheduler(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 256, lat, SchedulerConfig())
    eng = ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64,
                        capacity_tokens=256, page_size=16)
    assert eng.kv.paged
    assert sched.cfg.page_size == 16
