"""Differential regression: the policy-arena refactor changed NOTHING.

PR 7 moved the schedulers out of `core/scheduler.py` into
`core/policies/` and grew a base class (`_pack_in_order`, shared
`_apply_preemption_cap`, `reset()`), a protocol, and four new policies
around them. The paper's scheduler must be bit-for-bit unaffected.

`LegacyAndesScheduler` below is a frozen TRANSCRIPTION of the
pre-refactor `AndesScheduler` (commit 2a8f9fb, the last commit before
the arena) — every decision-path method copied into this file, sharing
only the bookkeeping base. If a future edit to `policies/andes.py` or
`policies/base.py` shifts even one emit timestamp, the fingerprint
comparison here catches it; the oracle in this file must never be
"fixed" to match (that is the regression).

Also pinned: the vectorized `serve_gains_grid` rows are bit-identical to
the legacy per-candidate pricing pass (scalar `predict_qoe` per B) — the
claim `policies/andes.py` makes in its grid-pricing comment.
"""
from typing import List, Tuple

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import A100_4X, LatencyModel, SchedulerConfig
from repro.core import objectives as obj_lib
from repro.core.policies import AndesScheduler
from repro.core.policies.base import Scheduler
from repro.core.request import Request, ReqState
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_adversarial_workload, make_workload

CFG = get_config("opt-66b")
LAT = LatencyModel(CFG, A100_4X)
KV = 12_000


class LegacyAndesScheduler(Scheduler):
    """Pre-refactor AndesScheduler, transcribed verbatim (frozen oracle).

    Do NOT edit to track changes in policies/ — divergence from this
    class IS the regression this file exists to catch."""

    name = "andes"
    solver = "greedy"

    def schedule(self, now, live, fluid):
        self.iteration += 1
        if not live:
            return []
        running = [r for r in live if r.state == ReqState.RUNNING]
        weights = self._weights(live)

        if not self._legacy_triggered(live, running, weights):
            return self._legacy_admit_all(live, weights)

        b_min, b_max = self._legacy_batch_bounds(live, weights)
        candidates = np.unique(
            np.linspace(b_min, b_max, self.cfg.num_batch_candidates)
            .round().astype(int)
        )

        bp = self.pricer.batch_pricing(now, live, fluid)
        gain_fn = obj_lib.OBJECTIVES[self.cfg.objective]
        is_running = np.array([r.state == ReqState.RUNNING for r in live])

        gains_grid = self.pricer.serve_gains_grid(
            now, fluid, bp, candidates, gain_fn
        ) + self.cfg.stickiness * is_running
        best = (-np.inf, None, None, 0)
        for gains, b in zip(gains_grid, candidates):
            sel, value = self._legacy_solve(gains, weights, int(b))
            if value > best[0]:
                best = (value, sel, gains, int(b))

        chosen = [live[i] for i in np.nonzero(best[1])[0]]
        return self._legacy_preemption_cap(chosen, running, live)

    def idle_steps(self, live, max_steps):
        if not live:
            return 0
        if any(r.state != ReqState.RUNNING for r in live):
            return 0
        stiffest = max((r.spec.tds for r in live), default=0.0)
        if stiffest > 0 and \
                self.lat.per_token_latency(len(live)) > 1.0 / stiffest:
            return 0
        st = self.cfg.state_equiv_tokens
        demand = int(self._weights(live).sum())
        cap = self.cfg.memory_watermark * self.M
        if demand > cap:
            return 0
        grow = 0 if st else len(live)
        if grow == 0:
            return int(max_steps)
        s = 0
        while s < max_steps and demand + (s + 1) * grow <= cap:
            s += 1
        return s

    def _legacy_triggered(self, live, running, weights) -> bool:
        used = sum(r.kv_tokens(self.cfg.state_equiv_tokens) for r in running)
        total_demand = int(weights.sum())
        mem_pressure = total_demand > self.cfg.memory_watermark * self.M \
            or used > self.cfg.memory_watermark * self.M
        if mem_pressure:
            return True
        stiffest = max((r.spec.tds for r in live), default=0.0)
        if stiffest <= 0:
            return False
        return self.lat.per_token_latency(len(live)) > 1.0 / stiffest

    def _legacy_admit_all(self, live, weights) -> List[Request]:
        order = sorted(range(len(live)), key=lambda i: live[i].arrival)
        used, keep = 0, []
        for i in order:
            if used + weights[i] <= self.M:
                keep.append(live[i])
                used += int(weights[i])
        return keep

    def _legacy_batch_bounds(self, live, weights) -> Tuple[int, int]:
        w_sorted = np.sort(weights)
        fits = np.cumsum(w_sorted) <= self.M
        b_max = max(int(fits.sum()), 1)
        stiffest = max((r.spec.tds for r in live), default=1.0)
        b_min = self.lat.max_batch_from_latency(1.0 / max(stiffest, 1e-9))
        return max(1, min(b_min, b_max)), b_max

    def _legacy_solve(self, gains, weights, b):
        pri = gains / np.maximum(weights, 1)
        order = np.argsort(-pri)
        sel = np.zeros(len(gains), bool)
        used = used_n = 0
        value = 0.0
        for i in order:
            if used_n + 1 > b:
                break
            if used + weights[i] <= self.M:
                sel[i] = True
                used += int(weights[i])
                used_n += 1
                value += float(gains[i])
        return sel, value

    def _legacy_preemption_cap(self, chosen, running, live):
        preempted = [r for r in running if r not in chosen]
        if not preempted:
            return chosen
        budget = self.cfg.preemption_cap * max(self.total_requests, 1) \
            - self.total_preemptions
        allowed = max(int(budget), 0)
        if len(preempted) <= allowed:
            return chosen
        preempted.sort(key=lambda r: r.context_len)
        spared = preempted[: len(preempted) - allowed]
        chosen = list(chosen) + spared
        st = self.cfg.state_equiv_tokens
        used = 0
        final: List[Request] = []
        for r in sorted(chosen, key=lambda r: r.state != ReqState.RUNNING):
            w = r.kv_tokens(st)
            if used + w <= self.M:
                final.append(r)
                used += w
        return final


def _fingerprint(reqs):
    return [(r.rid, r.generated, tuple(r.emit_times), r.preemptions,
             r.final_qoe()) for r in sorted(reqs, key=lambda r: r.rid)]


def _simulate(sched_cls, workload, cap=1.0):
    cfg = SchedulerConfig(preemption_cap=cap)
    sched = sched_cls(KV, LAT, cfg)
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=KV))
    res = sim.run(workload)
    return res, sched


WORKLOADS = {
    "contended": lambda: make_workload(80, 8.0, seed=3,
                                       arrival="gamma", cv=3.0),
    "burst": lambda: make_adversarial_workload("burst", 100, 6.0, seed=1),
    "heavy_tail": lambda: make_adversarial_workload(
        "heavy_tail", 80, 6.0, seed=2),
}


@pytest.mark.parametrize("trace", sorted(WORKLOADS))
@pytest.mark.parametrize("cap", [0.25, 1.0])
def test_andes_bit_for_bit_vs_prerefactor_oracle(trace, cap):
    """Every emit timestamp, preemption count and final QoE produced by
    the refactored AndesScheduler must equal the pre-refactor
    transcription's — on bursty, heavy-tailed and contended traces, at a
    tight and at the default preemption cap."""
    res_new, s_new = _simulate(AndesScheduler, WORKLOADS[trace](), cap)
    res_old, s_old = _simulate(LegacyAndesScheduler, WORKLOADS[trace](), cap)
    assert _fingerprint(res_new.requests) == _fingerprint(res_old.requests)
    assert res_new.makespan == res_old.makespan
    assert res_new.preemptions == res_old.preemptions
    assert res_new.iterations == res_old.iterations
    assert res_new.batch_sizes == res_old.batch_sizes
    assert s_new.total_preemptions == s_old.total_preemptions


def test_serve_gains_grid_rows_match_legacy_per_b_pricing():
    """The vectorized grid pricing (§4.2 #2/#3 hot path) must be
    bit-identical to the legacy loop that priced each candidate B with a
    scalar `predict_qoe` call — captured on real mid-run triggered
    scheduler states, not synthetic ones."""
    sched = AndesScheduler(KV, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=KV))
    states = []
    inner = sched.schedule

    def spy(now, live, fluid):
        running = [r for r in live if r.state == ReqState.RUNNING]
        w = sched._weights(live)
        if live and sched._triggered(live, running, w) and len(states) < 8:
            bp = sched.pricer.batch_pricing(now, live, fluid)
            b_min, b_max = sched._batch_bounds(live, w)
            cands = np.unique(
                np.linspace(b_min, b_max, sched.cfg.num_batch_candidates)
                .round().astype(int))
            gain_fn = obj_lib.OBJECTIVES[sched.cfg.objective]
            grid = sched.pricer.serve_gains_grid(now, fluid, bp, cands,
                                                 gain_fn)
            legacy = []
            for b in cands:
                rate = LAT.token_rate(int(b), int(b * bp.mean_ctx))
                q_serve = fluid.predict_qoe(
                    now, sched.cfg.delta_t, rate,
                    bp.delays_slot, bp.exp_len)[bp.idx]
                legacy.append(gain_fn(q_serve, bp.q_wait, bp.q_now)
                              * bp.weights)
            states.append((grid, np.stack(legacy)))
        return inner(now, live, fluid)

    sched.schedule = spy
    sim.run(make_workload(60, 8.0, seed=3, arrival="gamma", cv=3.0))
    assert states, "workload never triggered the knapsack"
    for grid, legacy in states:
        np.testing.assert_array_equal(grid, legacy)
