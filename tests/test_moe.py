"""MoE layer invariants: routing, capacity, drops, chunking, load balance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

import repro.models.moe as moe
from repro.configs import get_smoke_config

CFG = get_smoke_config("qwen2-moe-a2.7b")


def setup_params(seed=0):
    return moe.init_moe(jax.random.PRNGKey(seed), CFG, jnp.float32)


def test_output_shape_and_finite():
    p = setup_params()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, CFG.d_model)) * 0.3
    y, aux = moe.moe_apply(p, x, CFG)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y)) and jnp.isfinite(aux)
    assert float(aux) > 0


def test_generous_capacity_matches_dense_topk():
    """With no drops, MoE output == explicit dense top-k mixture."""
    old = moe.CAPACITY_FACTOR
    moe.CAPACITY_FACTOR = 16.0
    try:
        p = setup_params()
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, CFG.d_model)) * 0.3
        y, _ = moe.moe_apply(p, x, CFG)
        # dense reference: run every expert on every token, weight by router
        xt = x.reshape(-1, CFG.d_model)
        logits = xt @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        tw, te = jax.lax.top_k(probs, CFG.moe.top_k)
        tw = tw / tw.sum(-1, keepdims=True)
        ref = jnp.zeros_like(xt)
        for e in range(CFG.moe.num_experts):
            h = jax.nn.silu(xt @ p["experts"]["gate"][e]) * (xt @ p["experts"]["up"][e])
            out_e = h @ p["experts"]["down"][e]
            w = jnp.sum(jnp.where(te == e, tw, 0.0), axis=-1)
            ref = ref + out_e * w[:, None]
        from repro.models.layers import mlp_apply
        ref = ref + mlp_apply(p["shared"], xt)
        np.testing.assert_allclose(np.asarray(y.reshape(-1, CFG.d_model)),
                                   np.asarray(ref), atol=1e-4, rtol=1e-4)
    finally:
        moe.CAPACITY_FACTOR = old


def test_tight_capacity_drops_but_stays_finite():
    old = moe.CAPACITY_FACTOR
    moe.CAPACITY_FACTOR = 0.25
    try:
        p = setup_params()
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, CFG.d_model)) * 0.3
        y, _ = moe.moe_apply(p, x, CFG)
        assert jnp.all(jnp.isfinite(y))
    finally:
        moe.CAPACITY_FACTOR = old


def test_padding_tokens_do_not_consume_capacity():
    p = setup_params()
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, CFG.d_model)) * 0.3
    valid = jnp.arange(32)[None] < 16
    y_masked, _ = moe.moe_apply(p, x, CFG, valid=valid)
    y_short, _ = moe.moe_apply(p, x[:, :16], CFG)
    np.testing.assert_allclose(np.asarray(y_masked[:, :16]),
                               np.asarray(y_short), atol=2e-4, rtol=2e-3)


@given(st.integers(8, 64), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
@pytest.mark.slow
def test_chunked_equals_global_no_drop(seq, seed):
    old = moe.CAPACITY_FACTOR
    moe.CAPACITY_FACTOR = 16.0
    try:
        p = setup_params(seed % 3)
        x = jax.random.normal(jax.random.PRNGKey(seed), (1, seq, CFG.d_model)) * 0.3
        y1, _ = moe.moe_apply(p, x, CFG)
        y2, _ = moe.moe_apply_chunked(p, x, CFG, seq_chunk=8)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-3)
    finally:
        moe.CAPACITY_FACTOR = old


def test_aux_loss_balanced_router_is_minimal():
    """A perfectly uniform router gives aux ~= coef (the Switch minimum)."""
    p = setup_params()
    p = dict(p, router=jnp.zeros_like(p["router"]))   # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, CFG.d_model)) * 0.3
    _, aux_uniform = moe.moe_apply(p, x, CFG)
    coef = CFG.moe.router_aux_loss_coef
    assert float(aux_uniform) == pytest.approx(coef, rel=0.05)
