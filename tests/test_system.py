"""End-to-end system behaviour: the paper's headline pipeline.

Workload -> Andes scheduler -> serving -> client token buffer -> QoE, both
on the simulator (paper scale) and the real engine (real model on CPU).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import (
    A100_4X,
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    TPU_V5E,
    TokenBuffer,
    make_scheduler,
)
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_workload


def test_end_to_end_sim_pipeline():
    """Full paper pipeline at the OPT-66B operating point."""
    cfg = get_config("opt-66b")
    lat = LatencyModel(cfg, A100_4X)
    m = 65_000
    wl = make_workload(300, 3.3, seed=7)
    sched = make_scheduler("andes", m, lat, SchedulerConfig())
    res = ServingSimulator(sched, lat, SimConfig(kv_capacity_tokens=m)).run(wl)
    assert all(r.generated >= r.output_len for r in res.requests)
    assert res.avg_qoe() > 0.85
    # token buffer invariant: user-visible TDS never exceeds expectation
    for r in res.requests[:50]:
        buf = TokenBuffer(r.spec.tds)
        deliveries = [buf.push(t) for t in r.emit_times]
        gaps = np.diff(deliveries)
        assert np.all(gaps >= 1.0 / r.spec.tds - 1e-9)


@pytest.mark.slow
def test_end_to_end_real_engine_qoe():
    """Real model + Andes + contention: good QoE, exact accounting."""
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(5)
    wl = []
    for i in range(10):
        plen = int(rng.integers(8, 20))
        wl.append(Request(rid=i, arrival=i * 0.02, prompt_len=plen,
                          output_len=12, spec=QoESpec(ttft=1.0, tds=4.8),
                          prompt_tokens=rng.integers(0, cfg.vocab_size, plen)))
    cap = 250
    eng = ServingEngine(model, params,
                        make_scheduler("andes", cap, lat, SchedulerConfig()),
                        lat, num_slots=4, max_seq=64, capacity_tokens=cap)
    out = eng.run(wl, max_iterations=3000)
    assert all(r.generated >= r.output_len for r in out)
    qoes = [r.final_qoe() for r in out]
    assert np.mean(qoes) > 0.8
