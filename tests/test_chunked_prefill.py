"""Chunked prefill (PR 8): differential oracles, interleaving, pricing.

The chunk design is masked recompute: each chunk re-runs the bucketed
prefill of ``toks[:cursor]`` at ``bucket(cursor)``, so the FINAL chunk —
whose prefix is the whole prompt — is the identical jitted call the
monolithic path makes. Committed cache contents and the first token are
therefore bit-identical to monolithic prefill *by construction*; these
tests pin that construction.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    TPU_V5E,
    make_scheduler,
)
from repro.core.request import ReqState
from repro.models import Model
from repro.obs import MetricsObserver, MetricsRegistry, TraceRecorder
from repro.serving import Request, ServingEngine, fingerprint
from repro.serving.engine import _read_slot


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _mk_workload(cfg, n, rng, out_len=10, stagger=0.05, pmin=5, pmax=40):
    wl = []
    for i in range(n):
        plen = int(rng.integers(pmin, pmax))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen)))
    return wl


def _mk_engine(cfg, m, params, **kw):
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 4 * 64, lat, SchedulerConfig())
    return ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64, **kw)


def _run(cfg, m, params, wl, **kw):
    eng = _mk_engine(cfg, m, params, **kw)
    out = eng.run([r.clone() for r in wl], max_iterations=4000)
    return out, eng


def test_chunk_larger_than_prompts_is_identity(llama):
    """No prompt exceeds the chunk: the chunked engine never engages the
    chunk path and must be bit-for-bit the default engine — tokens,
    timestamps, preemptions, QoE."""
    cfg, m, params = llama
    rng = np.random.default_rng(0)
    wl = _mk_workload(cfg, 6, rng)
    base, _ = _run(cfg, m, params, wl)
    chunked, _ = _run(cfg, m, params, wl, prefill_chunk=48)
    assert fingerprint(chunked) == fingerprint(base)


def test_chunked_tokens_match_monolithic(llama):
    """Small chunk: timing differs (chunks are priced per chunk) but the
    committed token ids must be identical — the differential oracle for
    the masked-recompute construction."""
    cfg, m, params = llama
    rng = np.random.default_rng(2)
    wl = _mk_workload(cfg, 6, rng, pmin=10, pmax=40)
    base, _ = _run(cfg, m, params, wl)
    chunked, eng = _run(cfg, m, params, wl, prefill_chunk=8)
    assert eng.prefill_chunk == 8
    base_toks = {r.rid: list(r.output_tokens) for r in base}
    assert {r.rid: list(r.output_tokens) for r in chunked} == base_toks
    assert all(r.generated >= r.output_len for r in chunked)


def test_committed_cache_bit_identical(llama):
    """One long prompt through chunk=8 vs monolithic: after both runs the
    request's cache row (keys/values written by prefill + decode) must be
    bit-identical — the final chunk IS the monolithic jitted call."""
    cfg, m, params = llama
    rng = np.random.default_rng(3)
    plen = 37
    wl = [Request(rid=0, arrival=0.0, prompt_len=plen, output_len=8,
                  spec=QoESpec(ttft=1.0, tds=4.8),
                  prompt_tokens=rng.integers(0, cfg.vocab_size, plen))]
    _, eng_a = _run(cfg, m, params, wl)
    _, eng_b = _run(cfg, m, params, wl, prefill_chunk=8)
    row_a = _read_slot(eng_a.cache, 0)
    row_b = _read_slot(eng_b.cache, 0)
    for leaf_a, leaf_b in zip(jax.tree.leaves(row_a), jax.tree.leaves(row_b)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


def test_chunks_interleave_with_decode(llama):
    """The point of chunking: while a long prompt prefills chunk by
    chunk, already-resident requests keep emitting tokens. The trace
    must show emit events for other requests BETWEEN the long request's
    first and last prefill_chunk events."""
    cfg, m, params = llama
    rng = np.random.default_rng(4)
    wl = [Request(rid=i, arrival=0.0, prompt_len=6, output_len=30,
                  spec=QoESpec(ttft=1.0, tds=4.8),
                  prompt_tokens=rng.integers(0, cfg.vocab_size, 6))
          for i in range(3)]
    wl.append(Request(rid=3, arrival=0.05, prompt_len=48, output_len=8,
                      spec=QoESpec(ttft=1.0, tds=4.8),
                      prompt_tokens=rng.integers(0, cfg.vocab_size, 48)))
    eng = _mk_engine(cfg, m, params, prefill_chunk=8)
    trace = TraceRecorder()
    eng.observer = trace
    eng.run([r.clone() for r in wl], max_iterations=4000)
    chunk_idx = [i for i, ev in enumerate(trace.events)
                 if ev.kind == "prefill_chunk" and ev.rid == 3]
    assert len(chunk_idx) == 6                 # ceil(48 / 8)
    cursors = [trace.events[i].data["cursor"] for i in chunk_idx]
    assert cursors == [8, 16, 24, 32, 40, 48]
    interleaved = [ev for ev in trace.events[chunk_idx[0]:chunk_idx[-1]]
                   if ev.kind == "emit" and ev.rid != 3]
    assert interleaved, "no decode progress during the chunked prefill"


def test_prefill_chunk_metrics_counter(llama):
    cfg, m, params = llama
    rng = np.random.default_rng(5)
    wl = _mk_workload(cfg, 6, rng, pmin=4, pmax=40, stagger=0.2)
    eng = _mk_engine(cfg, m, params, prefill_chunk=8)
    reg = MetricsRegistry()
    eng.observer = MetricsObserver(reg)
    out = eng.run([r.clone() for r in wl], max_iterations=4000)
    assert eng.preemptions == 0                # else recompute re-chunks
    expected = sum(-(-r.prompt_len // 8) for r in wl if r.prompt_len > 8)
    assert reg.value("prefill_chunks_total") == expected
    assert all(r.generated >= r.output_len for r in out)


def test_chunked_with_preemption_completes(llama):
    """Chunked prefill under contention, both preemption modes: cursors
    must survive swap round-trips and rewind on recompute, and the trace
    must still drain completely."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(6)
    wl = _mk_workload(cfg, 8, rng, out_len=12, stagger=0.01,
                      pmin=10, pmax=40)
    for mode in ("swap", "recompute"):
        sched = make_scheduler("andes", 100, lat,
                               SchedulerConfig(delta_t=5.0))
        eng = ServingEngine(m, params, sched, lat, num_slots=2, max_seq=64,
                            capacity_tokens=100, preemption_mode=mode,
                            prefill_chunk=8)
        out = eng.run([r.clone() for r in wl], max_iterations=4000)
        assert all(r.generated >= r.output_len for r in out), mode
        assert all(r.prefill_cursor == 0 for r in out), mode


def test_chunk_requires_bucketed_prefill(llama):
    """Chunking is built on the staged bucketed-prefill machinery; an
    engine without it (the legacy baseline hot path) must refuse the
    flag loudly instead of silently serving monolithic."""
    from repro.serving import HotpathConfig

    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 256, lat, SchedulerConfig())
    with pytest.raises(ValueError):
        ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64,
                      prefill_chunk=8, hotpath=HotpathConfig.baseline())


# --------------------------------------------------------------------------
# pricing: the knapsack sees honest chunked TTFTs
# --------------------------------------------------------------------------
def test_latency_model_chunk_costs():
    cfg = get_smoke_config("llama3-8b")
    lat = LatencyModel(cfg, TPU_V5E)
    # sum of per-chunk costs, exact
    manual = (lat.prefill_chunk_latency(8, 8)
              + lat.prefill_chunk_latency(8, 16)
              + lat.prefill_chunk_latency(4, 20))
    assert lat.chunked_prefill_latency(20, 8) == pytest.approx(manual)
    # degenerate: one chunk == monolithic prefill
    assert lat.chunked_prefill_latency(20, 32) == lat.prefill_latency(20)
    assert lat.chunked_prefill_latency(20, 0) == lat.prefill_latency(20)
    # a mid-prefill resume prices only the remaining chunks
    resumed = lat.chunked_prefill_latency(20, 8, start=16)
    assert resumed == pytest.approx(lat.prefill_chunk_latency(4, 20))
    # chunking adds per-chunk overhead: never cheaper than monolithic
    assert lat.chunked_prefill_latency(64, 8) > lat.prefill_latency(64)


def test_serve_delay_prices_chunks():
    cfg = get_smoke_config("llama3-8b")
    lat = LatencyModel(cfg, TPU_V5E)
    chunked = make_scheduler("andes", 1024, lat,
                             SchedulerConfig(prefill_chunk=8))
    legacy = make_scheduler("andes", 1024, lat, SchedulerConfig())
    r = Request(rid=0, arrival=0.0, prompt_len=40, output_len=8,
                spec=QoESpec(ttft=1.0, tds=4.8))
    # WAITING: the chunked backend owes every chunk
    assert chunked.pricer.serve_delay(r) == pytest.approx(
        lat.chunked_prefill_latency(40, 8))
    assert legacy.pricer.serve_delay(r) == lat.prefill_latency(40)
    # RUNNING mid-prefill: remaining chunks only (not the RUNNING zero)
    r.state = ReqState.RUNNING
    r.prefill_cursor = 16
    assert chunked.pricer.serve_delay(r) == pytest.approx(
        lat.chunked_prefill_latency(40, 8, start=16))
    r.prefill_cursor = 0
    assert chunked.pricer.serve_delay(r) == 0.0
    # SWAPPED mid-prefill: swap restore + remaining chunks
    r.state = ReqState.SWAPPED
    r.prefill_cursor = 16
    assert chunked.pricer.serve_delay(r) == pytest.approx(
        lat.swap_latency(40) + lat.chunked_prefill_latency(40, 8, start=16))
