"""Persistent device decode loop (ISSUE 10 tentpole, part b) and the
wall-clock multi-step relaxation (satellite).

`Model.decode_persistent` folds a whole multi-step block into one
device-resident `lax.while_loop` whose body is exactly `decode_multi`'s
scan body — so the identity chain is

    sequential single-step ≡ static-j scan ≡ persistent while_loop

bit-for-bit, on both cache layouts. The engine spends the scheduler's
`idle_steps` certificate at full resolution (j is loop *data*, no pow-2
compile grid) and commits the block off ONE host sync through the same
`_commit_block` replay the scan path uses. Wall-clock engines may now
fuse too (`HotpathConfig.wall_multi_step`): token ids stay exact — the
clock decides when, never what — and timestamps are tolerance-gated.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, SchedulerConfig, TPU_V5E, make_scheduler
from repro.models import Model
from repro.serving import (
    HotpathConfig,
    Request,
    ServingEngine,
    Tolerance,
    ToleranceSpec,
    compare_requests,
    fingerprint,
)

_MODELS = {}


def _model(arch="llama3-8b"):
    if arch not in _MODELS:
        cfg = get_smoke_config(arch)
        m = Model(cfg)
        _MODELS[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _MODELS[arch]


def mk_wl(cfg, rng, n=8, out_len=12, stagger=0.2, plo=6, phi=40):
    wl = []
    for i in range(n):
        plen = int(rng.integers(plo, phi))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen)))
    return wl


def clone(wl):
    return [r.clone() for r in wl]


def mk_engine(arch="llama3-8b", *, hotpath=None, num_slots=8, max_seq=64,
              cap=None, eos_id=-1, **kw):
    cfg, m, params = _model(arch)
    lat = LatencyModel(cfg, TPU_V5E)
    cap = cap if cap is not None else num_slots * max_seq
    sched = make_scheduler("andes", cap, lat, SchedulerConfig())
    return ServingEngine(m, params, sched, lat, num_slots=num_slots,
                         max_seq=max_seq, capacity_tokens=cap,
                         eos_id=eos_id, hotpath=hotpath, **kw)


def assert_bitforbit(out_a, out_b):
    assert len(out_a) == len(out_b)
    for a, b in zip(out_a, out_b):
        assert a.rid == b.rid
        assert a.output_tokens == b.output_tokens, a.rid
        assert a.emit_times == b.emit_times, a.rid
        assert a.preemptions == b.preemptions, a.rid
        assert a.generated == b.generated, a.rid
        assert a.final_qoe() == b.final_qoe(), a.rid


# ---------------------------------------------------------------------------
# model layer: while_loop ≡ scan ≡ single-step
# ---------------------------------------------------------------------------

def _prefilled_cache(cfg, m, params, B=4, S=48):
    rng = np.random.default_rng(0)
    pre = jax.jit(lambda p, t, l, c: m.prefill(
        p, {"tokens": t, "lengths": l}, c))
    toks = np.zeros((B, 32), np.int32)
    lens = np.array([9, 13, 21, 30], np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.integers(0, cfg.vocab_size, l)
    cache = m.init_cache(B, S, dtype=jnp.float32)
    logits, cache = pre(params, jnp.asarray(toks), jnp.asarray(lens), cache)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache


def test_persistent_equals_scan_foundation():
    """Dynamic-j while_loop ids and final cache are bit-identical to the
    static-j scan for every j — the identity the engine path rests on."""
    cfg, m, params = _model()
    t0, cache0 = _prefilled_cache(cfg, m, params)
    dec_multi = jax.jit(m.decode_multi, static_argnames=("j",))
    dec_pers = jax.jit(m.decode_persistent,
                       static_argnames=("j_cap", "eos_id"))
    active = jnp.ones((4,), bool)
    for j in (1, 3, 6):
        ref_ids, ref_c = dec_multi(params, t0, dict(cache0), j=j)
        ids, c, steps = dec_pers(params, t0, dict(cache0),
                                 jnp.int32(j), active, j_cap=8, eos_id=-1)
        assert int(steps) == j
        assert (np.asarray(ids[:j]) == np.asarray(ref_ids)).all()
        assert (np.asarray(ids[j:]) == 0).all()     # unwritten buffer rows
        for a, b in zip(jax.tree.leaves(c), jax.tree.leaves(ref_c)):
            assert (np.asarray(a) == np.asarray(b)).all()


def test_persistent_eos_early_exit():
    """With eos_id set the loop stops once every ACTIVE row has emitted
    EOS — and until then the committed prefix stays scan-identical."""
    cfg, m, params = _model()
    t0, cache0 = _prefilled_cache(cfg, m, params)
    dec_multi = jax.jit(m.decode_multi, static_argnames=("j",))
    dec_pers = jax.jit(m.decode_persistent,
                       static_argnames=("j_cap", "eos_id"))
    j = 6
    ref_ids = np.asarray(dec_multi(params, t0, dict(cache0), j=j)[0])
    # pick the token row 0 emits at step 2 as EOS and mark ONLY row 0
    # active: the loop must stop right after that step
    eos = int(ref_ids[2, 0])
    active = jnp.asarray([True, False, False, False])
    ids, _, steps = dec_pers(params, t0, dict(cache0),
                             jnp.int32(j), active, j_cap=8, eos_id=eos)
    ids = np.asarray(ids)
    n = int(steps)
    assert n <= j
    assert (ids[:n] == ref_ids[:n]).all()           # prefix scan-identical
    assert eos in ids[:n, 0]                        # row 0 reached its EOS
    if n < j:
        assert (ids[n:] == 0).all()
    # all rows active and eos_id < 0: always the full j
    _, _, full = dec_pers(params, t0, dict(cache0), jnp.int32(j),
                          jnp.ones((4,), bool), j_cap=8, eos_id=-1)
    assert int(full) == j


# ---------------------------------------------------------------------------
# engine layer: persistent ≡ scan ≡ single-step, both cache layouts
# ---------------------------------------------------------------------------

def _run_triple(wl, *, eos_id=-1, out_kw=None, **eng_kw):
    out_kw = out_kw or {}
    res = {}
    for name, hp in (
        ("persistent", HotpathConfig(multi_step=8, persistent=True)),
        ("scan", HotpathConfig(multi_step=8, persistent=False)),
        ("single", HotpathConfig(multi_step=1)),
    ):
        eng = mk_engine(hotpath=hp, eos_id=eos_id, **eng_kw)
        out = eng.run(clone(wl), max_iterations=20_000)
        res[name] = (out, eng)
    return res


def test_persistent_engine_equals_scan_and_single():
    cfg, _, _ = _model()
    rng = np.random.default_rng(4)
    wl = mk_wl(cfg, rng, n=8, out_len=24, stagger=0.15)
    res = _run_triple(wl)
    assert_bitforbit(res["persistent"][0], res["scan"][0])
    assert_bitforbit(res["persistent"][0], res["single"][0])
    ep, es = res["persistent"][1], res["scan"][1]
    assert ep.persistent_blocks > 0, "persistent path never engaged"
    assert ep.persistent_blocks == ep.multi_step_blocks
    assert es.persistent_blocks == 0
    # unquantized j: the while_loop never fuses FEWER iterations per block
    # than the pow-2-quantized scan, so it never syncs more often
    assert ep.host_syncs <= es.host_syncs
    assert ep.host_syncs < res["single"][1].host_syncs


def test_persistent_engine_eos_truncation():
    """EOS overshoot: the device may run past the token that finishes a
    request; the commit replay truncates exactly where single-stepping
    stops and the length gate rolls the cache back."""
    cfg, _, _ = _model()
    rng = np.random.default_rng(6)
    wl = mk_wl(cfg, rng, n=6, out_len=24, stagger=0.1)
    probe = mk_engine(hotpath=HotpathConfig(multi_step=1))
    out = probe.run(clone(wl), max_iterations=20_000)
    mid_tokens = [t for r in out for t in r.output_tokens[2:-2]]
    eos = int(np.bincount(np.asarray(mid_tokens)).argmax())
    res = _run_triple(wl, eos_id=eos)
    assert any(r.output_tokens and r.output_tokens[-1] == eos
               and r.generated < r.output_len
               for r in res["single"][0]), "EOS never fired — vacuous"
    assert_bitforbit(res["persistent"][0], res["single"][0])
    assert res["persistent"][1].persistent_blocks > 0


def test_persistent_engine_physical_paged():
    """The persistent loop over the physically paged cache, with pages
    growing mid-block (small page size forces boundary crossings inside
    fused blocks): the pre-reservation must cover every in-loop write."""
    cfg, _, _ = _model()
    rng = np.random.default_rng(7)
    wl = mk_wl(cfg, rng, n=8, out_len=20, stagger=0.15)
    res = _run_triple(wl, page_size=4)
    ep = res["persistent"][1]
    assert ep.physical_pages
    assert ep.persistent_blocks > 0
    assert_bitforbit(res["persistent"][0], res["scan"][0])
    assert_bitforbit(res["persistent"][0], res["single"][0])
    # and physical ≡ accounting-only under the persistent loop
    acct = mk_engine(hotpath=HotpathConfig(multi_step=8), page_size=4,
                     physical_pages=False)
    out_acct = acct.run(clone(wl), max_iterations=20_000)
    assert_bitforbit(res["persistent"][0], out_acct)


# ---------------------------------------------------------------------------
# speculative blocks: multi-step INSIDE speculation
# ---------------------------------------------------------------------------

def _run_spec_pair(wl, *, k=2, eos_id=-1, **kw):
    """Same spec engine, fused-block vs single-round; the acceptance-
    dependent clock is the thing under test, so the draft is a perturbed
    copy of the target (realistic partial acceptance)."""
    from repro.core import SpeculativeLatencyModel
    cfg, m, params = _model()
    pert = jax.tree.map(
        lambda a: a + 1e-3 * jax.random.normal(
            jax.random.PRNGKey(9), a.shape, a.dtype), params)
    res = {}
    for name, hp in (("block", HotpathConfig(multi_step=8, persistent=True)),
                     ("single", HotpathConfig(multi_step=8,
                                              persistent=False))):
        slat = SpeculativeLatencyModel(cfg, TPU_V5E, cfg, k=k)
        cap = kw.get("capacity_tokens", 8 * 64)
        sched = make_scheduler("andes", cap, slat, SchedulerConfig())
        eng = ServingEngine(m, params, sched, slat, num_slots=8, max_seq=64,
                            draft_model=m, draft_params=pert, spec_k=k,
                            eos_id=eos_id, hotpath=hp, **kw)
        out = eng.run(clone(wl), max_iterations=20_000)
        res[name] = (out, eng)
    return res


def test_spec_block_equals_single_round():
    """Folding verify rounds into one device while_loop moves no token,
    timestamp, or scheduling decision: the certificate is spent in tokens
    (a round consumes up to k+1) and the replay reprices every round's
    tick at the context acceptance actually reached."""
    cfg, _, _ = _model()
    rng = np.random.default_rng(11)
    wl = mk_wl(cfg, rng, n=8, out_len=18, stagger=0.05)
    res = _run_spec_pair(wl)
    assert_bitforbit(res["block"][0], res["single"][0])
    eb, es = res["block"][1], res["single"][1]
    assert eb.persistent_blocks > 0, "spec block path never engaged"
    assert es.persistent_blocks == 0
    assert eb.host_syncs < es.host_syncs
    # lossless: every request still runs to completion
    assert all(r.generated == r.output_len for r in res["block"][0])


def test_spec_block_eos_truncation():
    """An EOS inside a committed round finishes the request mid-block;
    the replay discards every later round and both length gates (target
    AND draft cache) roll back — bit-identical to single-round spec."""
    cfg, _, _ = _model()
    rng = np.random.default_rng(12)
    wl = mk_wl(cfg, rng, n=6, out_len=20, stagger=0.05)
    probe = _run_spec_pair(wl)["single"][0]
    mid_tokens = [t for r in probe for t in r.output_tokens[2:-2]]
    eos = int(np.bincount(np.asarray(mid_tokens)).argmax())
    res = _run_spec_pair(wl, eos_id=eos)
    assert any(r.output_tokens and r.output_tokens[-1] == eos
               and r.generated < r.output_len
               for r in res["single"][0]), "EOS never fired — vacuous"
    assert_bitforbit(res["block"][0], res["single"][0])
    assert res["block"][1].persistent_blocks > 0


# ---------------------------------------------------------------------------
# wall-clock multi-step (satellite 1): fused blocks on a real clock
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_wall_multi_step_tolerance():
    """A wall engine with fused blocks enabled: token text identical to
    the virtual reference (hard gate), timing within the tolerance spec,
    and the fast path really engaged. wall_multi_step=False restores the
    PR 9 single-step wall engine."""
    cfg, _, _ = _model()
    rng = np.random.default_rng(8)
    wl = mk_wl(cfg, rng, n=6, out_len=10, stagger=0.03, plo=5, phi=16)
    ref_eng = mk_engine(num_slots=4, max_seq=64)
    ref = ref_eng.run(clone(wl), max_iterations=2000)
    eng_w = ServingEngine(*_mk_wall_parts(), num_slots=4, max_seq=64,
                          clock="wall")
    eng_w.run(clone(wl[:2]), max_iterations=200)        # jit warmup
    cand = eng_w.run(clone(wl), max_iterations=2000)
    assert eng_w.multi_step_blocks > 0, "wall fast path never engaged"
    spec = ToleranceSpec(
        ttft_mean_diff=Tolerance(abs_tol=0.5),
        ttft_p95_diff=Tolerance(abs_tol=1.0),
        ttft_max_diff=Tolerance(abs_tol=2.0),
        tds_mean_diff=Tolerance(abs_tol=2.0, rel_tol=0.5),
        qoe_mean_diff=Tolerance(abs_tol=0.30),
        qoe_max_diff=Tolerance(abs_tol=0.60),
        qoe_mean_of=Tolerance(abs_tol=0.30),
    )
    rep = compare_requests(ref, cand, spec)
    assert not rep.token_mismatches, rep.summary()
    assert not rep.missing_rids
    rep.assert_ok()
    # the off switch still exists for strict single-step wall serving
    eng_off = ServingEngine(*_mk_wall_parts(), num_slots=4, max_seq=64,
                            clock="wall",
                            hotpath=HotpathConfig(wall_multi_step=False))
    eng_off.run(clone(wl), max_iterations=2000)
    assert eng_off.multi_step_blocks == 0


def _mk_wall_parts():
    cfg, m, params = _model()
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 4 * 64, lat, SchedulerConfig())
    return m, params, sched, lat
