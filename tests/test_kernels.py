"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

Every kernel runs in interpret mode on CPU (the kernel body executes in
Python) and must match its ref.py oracle to tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.selective_scan import selective_scan


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA
    (1, 200, 4, 1, 32),      # MQA + ragged seq (padding path)
    (2, 64, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.slow
def test_flash_attention_sweep(b, s, h, kv, hd, dtype, causal):
    q = rand(0, (b, s, h, hd), dtype)
    k = rand(1, (b, s, kv, hd), dtype)
    v = rand(2, (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_flash_attention_sliding_window():
    q = rand(0, (2, 256, 4, 64), jnp.float32)
    k = rand(1, (2, 256, 2, 64), jnp.float32)
    v = rand(2, (2, 256, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=50, block_q=64,
                          block_k=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True, window=50)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 128, 4, 4, 64),
    (3, 300, 8, 2, 64),      # ragged + padding
    (2, 512, 16, 1, 32),     # MQA deep cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.slow
def test_decode_attention_sweep(b, s, h, kv, hd, dtype):
    q = rand(0, (b, h, hd), dtype)
    k = rand(1, (b, s, kv, hd), dtype)
    v = rand(2, (b, s, kv, hd), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, s + 1, b), jnp.int32
    )
    out = decode_attention(q, k, v, lengths, block_k=64, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_decode_attention_window():
    b, s, h, kv, hd = 2, 256, 8, 4, 64
    q = rand(0, (b, h, hd), jnp.float32)
    k = rand(1, (b, s, kv, hd), jnp.float32)
    v = rand(2, (b, s, kv, hd), jnp.float32)
    lengths = jnp.array([256, 100], jnp.int32)
    out = decode_attention(q, k, v, lengths, window=32, block_k=64,
                           interpret=True)
    expect = ref.decode_attention_ref(q, k, v, lengths, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


# ---------------------------------------------------------------------------
# selective scan (Mamba-1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d,n", [
    (1, 128, 64, 16),
    (2, 256, 128, 16),
    (1, 64, 256, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.slow
def test_selective_scan_sweep(b, s, d, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)) - 1).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n), dtype)
    C = jax.random.normal(ks[4], (b, s, n), dtype)
    D = jnp.ones((d,)) * 0.3
    out = selective_scan(x, dt, A, B, C, D, chunk=64, block_d=64, interpret=True)
    expect = ref.selective_scan_ref(x, dt, A, B, C, D)
    scale = float(jnp.max(jnp.abs(expect))) + 1e-6
    assert float(jnp.max(jnp.abs(out - expect))) / scale < 1e-5


def test_chunked_scan_matches_sequential():
    """XLA chunked associative form == sequential oracle."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    b, s, d, n = 2, 192, 64, 16
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((d,)) * 0.3
    out = ops.selective_scan(x, dt, A, B, C, D, impl="chunked", chunk=64)
    expect = ref.selective_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)


def test_ssd_chunked_matches_sequential():
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    b, s, nh, hd, n = 2, 128, 4, 32, 16
    x = jax.random.normal(ks[0], (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((nh,)) * 0.3
    out = ops.ssd(x, dt, A, B, C, D, impl="chunked", chunk=32)
    expect = ref.ssd_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-4, rtol=2e-3)


def test_scan_step_consistency():
    """Sequential decode steps == full-sequence scan."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, d, n = 1, 32, 16, 8
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((d,)) * 0.3
    full = ref.selective_scan_ref(x, dt, A, B, C, D)
    h = jnp.zeros((b, d, n))
    for t in range(s):
        h, y = ops.selective_scan_step(h, x[:, t], dt[:, t], A, B[:, t], C[:, t], D)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, t]),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention (physical page pool)
# ---------------------------------------------------------------------------

def _paginate(k, v, lengths, page, seed=0):
    """Scatter contiguous (B, S, KV, hd) caches into a shuffled page pool.

    Returns (k_pool, v_pool, block_tables) with page assignment randomized
    across requests (physical page order must not matter) and unowned pool
    rows filled with noise (masking must make them invisible)."""
    b, s, kvh, hd = k.shape
    max_pages = -(-s // page)
    pad = max_pages * page - s
    kp = np.pad(np.asarray(k, np.float32), ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = np.pad(np.asarray(v, np.float32), ((0, 0), (0, pad), (0, 0), (0, 0)))
    needed = [-(-int(L) // page) for L in np.asarray(lengths)]
    p_total = sum(needed) + 3                      # + never-owned noise pages
    rng = np.random.default_rng(seed)
    ids = list(rng.permutation(p_total))
    k_pool = rng.normal(size=(p_total, page, kvh, hd)).astype(np.float32)
    v_pool = rng.normal(size=(p_total, page, kvh, hd)).astype(np.float32)
    tables = np.full((b, max_pages), p_total, np.int32)    # sentinel = P
    for bi in range(b):
        for pi in range(needed[bi]):
            pid = ids.pop()
            tables[bi, pi] = pid
            k_pool[pid] = kp[bi, pi * page:(pi + 1) * page]
            v_pool[pid] = vp[bi, pi * page:(pi + 1) * page]
    dt = k.dtype
    return (jnp.asarray(k_pool, dt), jnp.asarray(v_pool, dt),
            jnp.asarray(tables))


def test_paged_decode_ref_matches_contiguous_bitwise():
    """When max_pages * page == S the paged gather rebuilds the exact
    contiguous view, so the oracle is bit-identical to the contiguous
    oracle — the property the engine's degenerate page-size differentials
    stand on."""
    b, s, h, kv, hd = 3, 64, 4, 2, 32
    q = rand(0, (b, h, hd), jnp.float32)
    k = rand(1, (b, s, kv, hd), jnp.float32)
    v = rand(2, (b, s, kv, hd), jnp.float32)
    lengths = jnp.array([64, 17, 40], jnp.int32)
    for page in (1, 8, 16, 64):                    # all divide S
        k_pool, v_pool, bt = _paginate(k, v, lengths, page, seed=page)
        out = ref.paged_decode_attention_ref(q, k_pool, v_pool, bt, lengths)
        expect = ref.decode_attention_ref(q, k, v, lengths)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("b,s,h,kv,hd,page", [
    (1, 128, 4, 4, 64, 16),
    (3, 300, 8, 2, 64, 32),     # ragged lengths + non-divisible S
    (2, 512, 16, 1, 32, 128),   # MQA deep cache, big pages
    (2, 64, 4, 2, 64, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_sweep(b, s, h, kv, hd, page, dtype):
    q = rand(0, (b, h, hd), dtype)
    k = rand(1, (b, s, kv, hd), dtype)
    v = rand(2, (b, s, kv, hd), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, s + 1, b), jnp.int32
    )
    k_pool, v_pool, bt = _paginate(k, v, lengths, page)
    from repro.kernels.paged_attention import paged_decode_attention
    out = paged_decode_attention(q, k_pool, v_pool, bt, lengths,
                                 interpret=True)
    expect = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_paged_decode_attention_window():
    b, s, h, kv, hd, page = 2, 256, 8, 4, 64, 32
    q = rand(0, (b, h, hd), jnp.float32)
    k = rand(1, (b, s, kv, hd), jnp.float32)
    v = rand(2, (b, s, kv, hd), jnp.float32)
    lengths = jnp.array([256, 100], jnp.int32)
    k_pool, v_pool, bt = _paginate(k, v, lengths, page)
    from repro.kernels.paged_attention import paged_decode_attention
    out = paged_decode_attention(q, k_pool, v_pool, bt, lengths, window=32,
                                 interpret=True)
    expect = ref.decode_attention_ref(q, k, v, lengths, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_paged_decode_ops_dispatch():
    b, s, h, kv, hd, page = 2, 64, 4, 2, 32, 16
    q = rand(0, (b, h, hd), jnp.float32)
    k = rand(1, (b, s, kv, hd), jnp.float32)
    v = rand(2, (b, s, kv, hd), jnp.float32)
    lengths = jnp.array([30, 64], jnp.int32)
    k_pool, v_pool, bt = _paginate(k, v, lengths, page)
    via_ref = ops.paged_decode_attention(q, k_pool, v_pool, bt, lengths,
                                         impl="ref")
    via_pallas = ops.paged_decode_attention(q, k_pool, v_pool, bt, lengths,
                                            impl="pallas")
    expect = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(via_ref), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(via_pallas), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)
