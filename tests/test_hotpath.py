"""Engine hot-path regression suite (PR 5).

Three optimization families (serving/engine.py HotpathConfig — bucketed
batched prefill, fused on-device sampling, multi-step decode) must be
lossless: the differential oracles in test_engine_steppable / test_sim_vs_
engine / test_speculative / test_api already run with them ON by default;
this file pins the *mechanisms* those suites rely on:

  * foundation: fused argmax decode ≡ decode_step + host argmax, and the
    multi-step scan ≡ sequential fused steps, bit-for-bit;
  * bucketed+batched prefill ≡ exact-length batch-1 prefill (argmax-exact,
    logits allclose) for every model family the engine serves;
  * prefill compile count bounded by the bucket grid — not by the number
    of distinct prompt lengths — over a mixed-length trace;
  * multi-step engines reproduce single-step engines bit-for-bit,
    including EOS truncation mid-block;
  * the arrival-queue cursor preserves stable equal-arrival order and
    late submits of past arrivals.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, SchedulerConfig, TPU_V5E, make_scheduler
from repro.models import Model
from repro.models import cache as cache_lib
from repro.serving import HotpathConfig, Request, ServingEngine
from repro.serving.engine import BucketedPrefill
from repro.serving.simulator import ServingSimulator, SimConfig


_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = get_smoke_config(arch)
        m = Model(cfg)
        _MODELS[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _MODELS[arch]


def mk_wl(cfg, rng, n=8, out_len=12, stagger=0.2, plo=6, phi=40):
    wl = []
    for i in range(n):
        plen = int(rng.integers(plo, phi))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    return wl


def clone(wl):
    return [r.clone() for r in wl]


def mk_engine(arch="llama3-8b", *, hotpath=None, num_slots=8, max_seq=64,
              cap=None, eos_id=-1, sched_cfg=None):
    cfg, m, params = _model(arch)
    lat = LatencyModel(cfg, TPU_V5E)
    cap = cap if cap is not None else num_slots * max_seq
    sched = make_scheduler("andes", cap, lat, sched_cfg or SchedulerConfig())
    return ServingEngine(m, params, sched, lat, num_slots=num_slots,
                         max_seq=max_seq, capacity_tokens=cap,
                         eos_id=eos_id, hotpath=hotpath)


def assert_bitforbit(out_a, out_b):
    assert len(out_a) == len(out_b)
    for a, b in zip(out_a, out_b):
        assert a.rid == b.rid
        assert a.output_tokens == b.output_tokens, a.rid
        assert a.emit_times == b.emit_times, a.rid        # exact floats
        assert a.preemptions == b.preemptions, a.rid
        assert a.generated == b.generated, a.rid
        assert a.final_qoe() == b.final_qoe(), a.rid


# ---------------------------------------------------------------------------
# foundation: the fused device ops are bit-identical to their host splits
# ---------------------------------------------------------------------------

def test_fused_sampling_foundation():
    """decode_tokens (device argmax) and decode_multi (fused scan) must be
    bit-identical to decode_step + host argmax iterated — the identity
    every hot-path differential guarantee reduces to."""
    cfg, m, params = _model("llama3-8b")
    rng = np.random.default_rng(0)
    B, S = 4, 48
    pre = jax.jit(lambda p, t, l, c: m.prefill(
        p, {"tokens": t, "lengths": l}, c))
    toks = np.zeros((B, 32), np.int32)
    lens = np.array([9, 13, 21, 30], np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.integers(0, cfg.vocab_size, l)
    cache0 = m.init_cache(B, S, dtype=jnp.float32)
    logits, cache0 = pre(params, jnp.asarray(toks), jnp.asarray(lens), cache0)
    t0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    dec = jax.jit(m.decode_step)
    dec_tok = jax.jit(m.decode_tokens)
    dec_multi = jax.jit(m.decode_multi, static_argnames=("j",))

    # sequential reference: host argmax feedback, 6 iterations
    c, tok, ref = dict(cache0), t0, []
    for _ in range(6):
        logits, c = dec(params, tok, c)
        tok = jnp.asarray(np.asarray(jnp.argmax(logits, axis=-1), np.int32))
        ref.append(np.asarray(tok))
    ref = np.stack(ref)

    # fused single-step, iterated
    c1, tok1, out1 = dict(cache0), t0, []
    for _ in range(6):
        tok1, c1 = dec_tok(params, tok1, c1)
        out1.append(np.asarray(tok1))
    assert (np.stack(out1) == ref).all()

    # fused multi-step scan, one dispatch
    out6, c6 = dec_multi(params, t0, dict(cache0), j=6)
    assert (np.asarray(out6) == ref).all()
    for a, b in zip(jax.tree.leaves(c6), jax.tree.leaves(c)):
        assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# bucketed + batched prefill ≡ exact-length batch-1 (per model family)
# ---------------------------------------------------------------------------

def _prefill_property(arch, *, batch_rows):
    cfg, m, params = _model(arch)
    rng = np.random.default_rng(1)
    lens = [5, 9, 17, 23]
    toks = [rng.integers(0, cfg.vocab_size, l).astype(np.int32)
            for l in lens]
    enc_seq = 8 if cfg.kind in ("encdec", "audio") else 0
    jit_pre = jax.jit(lambda p, b, c: m.prefill(p, b, c))

    def run_padded(group):
        """Padded-to-bucket-32, lengths-masked, jitted (the hot path)."""
        n = len(group)
        T = np.zeros((n, 32), np.int32)
        L = np.zeros((n,), np.int32)
        for i, t in enumerate(group):
            T[i, : len(t)] = t
            L[i] = len(t)
        batch = {"tokens": jnp.asarray(T), "lengths": jnp.asarray(L)}
        if enc_seq:
            batch["frames"] = jnp.zeros((n, enc_seq, cfg.d_model),
                                        jnp.float32)
        c = m.init_cache(n, 48, enc_seq=enc_seq, dtype=jnp.float32)
        logits, _ = jit_pre(params, batch, c)
        return np.asarray(logits)

    def run_exact(t):
        """Eager exact-length batch-1 (the pre-PR-5 engine path)."""
        batch = {"tokens": jnp.asarray(t)[None]}
        if enc_seq:
            batch["frames"] = jnp.zeros((1, enc_seq, cfg.d_model),
                                        jnp.float32)
        c = m.init_cache(1, 48, enc_seq=enc_seq, dtype=jnp.float32)
        logits, _ = m.prefill(params, batch, c)
        return np.asarray(logits[0])

    exact = [run_exact(t) for t in toks]
    if batch_rows:
        padded = run_padded(toks)
    else:   # MoE: capacity routing couples rows — the engine goes batch-1
        padded = np.stack([run_padded([t])[0] for t in toks])
    for i, l in enumerate(lens):
        np.testing.assert_allclose(padded[i], exact[i], atol=1e-5, rtol=1e-5)
        assert int(np.argmax(padded[i])) == int(np.argmax(exact[i])), l


@pytest.mark.parametrize("arch", ["llama3-8b", "falcon-mamba-7b"])
def test_bucketed_prefill_matches_exact(arch):
    _prefill_property(arch, batch_rows=True)


@pytest.mark.slow
@pytest.mark.parametrize("arch", [
    "zamba2-2.7b",          # hybrid (mamba2 + shared attention)
    "seamless-m4t-medium",  # encdec (frames path)
    "pixtral-12b",          # vlm (dense prefill, no patches)
])
def test_bucketed_prefill_matches_exact_all_kinds(arch):
    _prefill_property(arch, batch_rows=True)


def test_moe_prefill_stays_exact_length():
    """MoE is the one family bucketed prefill CANNOT serve exactly: expert
    capacity is proportional to the forward's total token count (padding
    included — moe.py), so a padded prompt sees a different capacity gate
    and can drop different tokens. The engine must fall back to the eager
    exact-length path — prefill compiles then track distinct lengths, and
    the differential oracles stay exact by construction."""
    cfg, m, params = _model("qwen2-moe-a2.7b")
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 4 * 64, lat, SchedulerConfig())
    eng = ServingEngine(m, params, sched, lat, num_slots=3, max_seq=64,
                        capacity_tokens=4 * 64)
    assert eng.hotpath.prefill_buckets          # hot path is on...
    assert not eng._prefill_bucketable          # ...but MoE is excluded
    rng = np.random.default_rng(9)
    out = eng.run(mk_wl(cfg, rng, n=3, out_len=4, plo=6, phi=20),
                  max_iterations=500)
    assert all(r.generated >= r.output_len for r in out)
    # exact-length signatures, not buckets
    lens = {(1, r.prompt_len) for r in out}
    assert set(eng.hotpath_stats()["prefill_shapes"]) == lens


def test_batched_rows_bitwise_equal_batch1():
    """Row independence — the property that makes the engine's batched
    admission flush bit-identical to the legacy oracle's sequential
    prefills: a request's row in an N-row padded call equals its own
    1-row padded call EXACTLY (same bucket, so same per-row shapes)."""
    cfg, m, params = _model("llama3-8b")
    rng = np.random.default_rng(2)
    bp = BucketedPrefill(m, 64, jnp.float32, max_seq=64, bucket_min=16)
    toks = [rng.integers(0, cfg.vocab_size, l).astype(np.int32)
            for l in (7, 12, 15)]
    firstN, srcN = bp.run(params, toks)
    firstN = np.asarray(firstN)
    for i, t in enumerate(toks):
        f1, s1 = bp.run(params, [t])
        assert int(np.asarray(f1)[0]) == int(firstN[i])
        for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(srcN)):
            a, b = np.asarray(a), np.asarray(b)
            ax = 0 if a.ndim == 1 else 1
            assert (np.take(a, 0, ax) == np.take(b, i, ax)).all()


# ---------------------------------------------------------------------------
# compile-count regression: buckets, not distinct lengths
# ---------------------------------------------------------------------------

def test_prefill_compile_count_bounded_by_buckets():
    """50-request mixed-length trace: the optimized engine's prefill
    compile count must be bounded by the bucket grid (#length-buckets x
    #row-buckets) — NOT by the number of distinct prompt lengths, which is
    what the eager baseline pays. The engine's jit entry point doubles as
    the counting cache: its signature set is checked against jax's own
    compile-cache size so the bookkeeping cannot drift from reality."""
    cfg, m, params = _model("llama3-8b")
    rng = np.random.default_rng(3)
    wl = mk_wl(cfg, rng, n=50, out_len=6, stagger=0.05, plo=6, phi=60)
    n_lengths = len({r.prompt_len for r in wl})
    eng = mk_engine()
    eng.run(clone(wl), max_iterations=20_000)
    stats = eng.hotpath_stats()
    n_len_buckets = len(stats["prefill_bucket_grid"])
    n_row_buckets = len({s[0] for s in stats["prefill_shapes"]})
    bound = n_len_buckets * n_row_buckets
    assert stats["prefill_compiles"] <= bound, stats
    assert n_lengths > bound, (
        "trace too narrow to demonstrate the compile-count win")
    # the jit cache itself (when introspectable) must agree with the
    # signature bookkeeping the benchmark gates on
    cache_size = getattr(eng._prefill._jit, "_cache_size", None)
    if callable(cache_size):
        assert cache_size() <= stats["prefill_compiles"]


# ---------------------------------------------------------------------------
# multi-step decode ≡ single-step, bit-for-bit
# ---------------------------------------------------------------------------

def _run_pair(wl, hp_multi, hp_single, **kw):
    a = mk_engine(hotpath=hp_multi, **kw)
    out_a = a.run(clone(wl), max_iterations=20_000)
    b = mk_engine(hotpath=hp_single, **kw)
    out_b = b.run(clone(wl), max_iterations=20_000)
    assert_bitforbit(out_a, out_b)
    assert a.now == b.now
    assert a.iterations == b.iterations
    assert len(a.batch_sizes) == len(b.batch_sizes)
    assert a.sched.iteration == b.sched.iteration
    return a, b


def test_multi_step_equals_single_step():
    cfg, _, _ = _model("llama3-8b")
    rng = np.random.default_rng(4)
    wl = mk_wl(cfg, rng, n=8, out_len=24, stagger=0.15)
    multi, single = _run_pair(
        wl, HotpathConfig(multi_step=8), HotpathConfig(multi_step=1))
    assert multi.multi_step_blocks > 0, "fast path never engaged"
    assert multi.host_syncs < single.host_syncs


def test_multi_step_respects_pending_arrivals():
    """A late stiff arrival mid-drain: the block must stop at the same
    iteration boundary single-stepping admits it at."""
    cfg, _, _ = _model("llama3-8b")
    rng = np.random.default_rng(5)
    wl = mk_wl(cfg, rng, n=6, out_len=30, stagger=0.01)
    wl.append(Request(
        rid=99, arrival=0.35, prompt_len=10, output_len=12,
        spec=QoESpec(ttft=0.3, tds=8.0),
        prompt_tokens=rng.integers(0, cfg.vocab_size, 10)))
    _run_pair(wl, HotpathConfig(multi_step=8), HotpathConfig(multi_step=1))


def test_multi_step_with_eos_truncation():
    """EOS is unpredictable, so a multi-step block may overshoot it; the
    commit must stop exactly where single-stepping stops and the
    length-gate rollback must leave no trace in later tokens."""
    cfg, _, _ = _model("llama3-8b")
    rng = np.random.default_rng(6)
    wl = mk_wl(cfg, rng, n=6, out_len=24, stagger=0.1)
    # find a token that actually occurs mid-stream, then rerun with it as
    # EOS so blocks really do truncate
    probe = mk_engine(hotpath=HotpathConfig(multi_step=1))
    out = probe.run(clone(wl), max_iterations=20_000)
    mid_tokens = [t for r in out for t in r.output_tokens[2:-2]]
    assert mid_tokens, "probe trace too short"
    eos = int(np.bincount(np.asarray(mid_tokens)).argmax())
    multi, single = _run_pair(
        wl, HotpathConfig(multi_step=8), HotpathConfig(multi_step=1),
        eos_id=eos)
    assert any(r.output_tokens and r.output_tokens[-1] == eos
               and r.generated < r.output_len
               for r in single.seen), "EOS never fired — test is vacuous"
    assert multi.multi_step_blocks > 0, "fast path never engaged"


def test_multi_step_incremental_until_equals_upfront():
    """Replica.advance_to's `until` bound: stepping incrementally toward
    each arrival with step(until=arrival) must replay the all-upfront
    engine bit-for-bit even when multi-step blocks are active."""
    cfg, _, _ = _model("llama3-8b")
    rng = np.random.default_rng(7)
    wl = mk_wl(cfg, rng, n=8, out_len=20, stagger=0.12)

    a = mk_engine()
    out_a = a.run(clone(wl), max_iterations=20_000)

    b = mk_engine()
    wl_b = clone(wl)
    for r in wl_b:
        while b.has_work and b.now < r.arrival:
            if not b.step(until=r.arrival):
                break
        b.submit(r)
    while b.step():
        pass
    assert_bitforbit(wl_b, out_a)
    assert a.multi_step_blocks > 0


# ---------------------------------------------------------------------------
# arrival-queue cursor: stable order, late submits, protocol view
# ---------------------------------------------------------------------------

def test_arrival_queue_equal_arrival_stability():
    """Equal-arrival requests must be admitted in submit order (the
    bisect_right insert above the cursor ≡ the old insort semantics)."""
    lat = LatencyModel(get_smoke_config("llama3-8b"), TPU_V5E)
    sim = ServingSimulator(make_scheduler("fcfs", 4096, lat), lat,
                           SimConfig(kv_capacity_tokens=4096))
    for rid in (3, 1, 4, 1 + 4, 9, 2, 6):
        sim.submit(Request(rid=rid, arrival=1.0, prompt_len=8, output_len=2,
                           spec=QoESpec(ttft=1.0, tds=4.8)))
    sim._admit_arrivals(2.0)
    assert [r.rid for r in sim.live] == [3, 1, 4, 5, 9, 2, 6]
    assert sim.pending == []


def test_arrival_queue_late_submit_of_past_arrival():
    """A request submitted with an arrival earlier than already-admitted
    ones must still be admitted (the cursor clamps the insert position —
    it can never land inside the consumed prefix)."""
    lat = LatencyModel(get_smoke_config("llama3-8b"), TPU_V5E)
    sim = ServingSimulator(make_scheduler("fcfs", 4096, lat), lat,
                           SimConfig(kv_capacity_tokens=4096))
    for rid, arr in ((0, 0.0), (1, 0.5), (2, 1.0)):
        sim.submit(Request(rid=rid, arrival=arr, prompt_len=8, output_len=4,
                           spec=QoESpec(ttft=1.0, tds=4.8)))
    sim._admit_arrivals(2.0)          # consume everything
    assert len(sim.live) == 3
    sim.submit(Request(rid=9, arrival=0.25, prompt_len=8, output_len=4,
                       spec=QoESpec(ttft=1.0, tds=4.8)))
    assert [r.rid for r in sim.pending] == [9]
    sim._admit_arrivals(2.0)
    assert [r.rid for r in sim.live] == [0, 1, 2, 9]
    assert sim.has_work


def test_queue_cursor_drain_is_linear():
    """Admitting a deep queue must not re-shift the list per request: the
    compaction counter stays O(n) total (regression guard for the old
    pop(0) O(n²) drain). Checked behaviorally: a 5k-request drain through
    _admit_arrivals completes with the cursor consuming every entry."""
    lat = LatencyModel(get_smoke_config("llama3-8b"), TPU_V5E)
    sim = ServingSimulator(make_scheduler("fcfs", 1 << 22, lat), lat,
                           SimConfig(kv_capacity_tokens=1 << 22))
    n = 5000
    for i in range(n):
        sim.submit(Request(rid=i, arrival=i * 1e-4, prompt_len=4,
                           output_len=1, spec=QoESpec(ttft=1.0, tds=4.8)))
    sim._admit_arrivals(1.0)
    assert len(sim.live) == n
    assert not sim.pending
    assert sim._pending_pos == 0      # compacted


# ---------------------------------------------------------------------------
# pricing grid ≡ per-candidate scalar pricing
# ---------------------------------------------------------------------------

def test_predict_qoe_grid_rows_match_scalar():
    from repro.core.qoe import FluidQoE
    rng = np.random.default_rng(8)
    fl = FluidQoE()
    for i in range(6):
        fl.add(float(i) * 0.3, QoESpec(ttft=1.0, tds=4.8))
    fl.emit(np.arange(4), 2.0, 1)
    fl.emit(np.arange(2), 2.5, 3)
    rates = np.array([0.0, 1.3, 4.8, 7.7, 50.0])
    delay = rng.uniform(0, 2, 6)
    exp_len = rng.uniform(8, 64, 6)
    grid = fl.predict_qoe_grid(3.0, 50.0, rates, delay, exp_len)
    for i, r in enumerate(rates):
        row = fl.predict_qoe(3.0, 50.0, r, delay, exp_len)
        assert (grid[i] == row).all(), i
