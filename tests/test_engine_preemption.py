"""Unit tests for the engine's preemption paths: `_preempt` (swap-to-host
vs recompute) and `_swap_in`.

The integration suite (test_engine.py::test_preemption_exactness) already
proves preempted requests finish with the right tokens end-to-end; these
tests pin the mechanism itself — the KV/state slice that comes back from
host RAM is *bit-identical* to what was parked, the slot/token accounting
balances on both sides, and the recompute path genuinely drops state.

The speculative tests extend the same pins to the draft cache: a preempted
speculative request parks *two* slices (target + draft, same rid, same
slot decision), both must round-trip host RAM bit-identically — including
the stale rejected-proposal entries beyond the committed frontier, which
the length gate makes inert — and the resumed request must continue the
exact token sequence.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    LatencyModel,
    QoESpec,
    SpeculativeLatencyModel,
    TPU_V5E,
    make_scheduler,
)
from repro.models import Model
from repro.serving import Request, ReqState, ServingEngine
from repro.serving.engine import _read_slot


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def mk_req(cfg, rng, rid=0, out_len=10, plen=12):
    return Request(
        rid=rid, arrival=0.0, prompt_len=plen, output_len=out_len,
        spec=QoESpec(ttft=1.0, tds=4.8),
        prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
    )


def mk_engine(m, params, lat, mode="swap"):
    sched = make_scheduler("fcfs", 10_000, lat)
    return ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64,
                         preemption_mode=mode)


def tree_equal(a, b):
    eq = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))), a, b
    )
    return all(jax.tree.leaves(eq))


def start_running(eng, r, steps=2):
    """Submit and step until the request is mid-decode."""
    eng.submit(r)
    for _ in range(steps):
        assert eng.step()
    assert r.state == ReqState.RUNNING and r.generated > 0
    return r.engine_slot


def test_swap_roundtrip_preserves_kv_exactly(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(0)
    eng = mk_engine(m, params, lat, mode="swap")
    r = mk_req(cfg, rng)
    slot = start_running(eng, r)

    before = jax.device_get(_read_slot(eng.cache, slot))
    used_before = eng.kv.tokens_used

    eng._preempt(r)
    assert r.state == ReqState.SWAPPED
    assert r.preemptions == 1 and eng.preemptions == 1
    assert slot in eng.kv.free_slots and slot not in eng.slot_req
    assert eng.kv.tokens_used == used_before - r.context_len
    # the parked host slice is exactly the device slice that was evicted
    parked = eng.kv.host_store[r.rid]
    assert tree_equal(parked, before)
    assert eng.kv.swap_bytes_total > 0

    eng._swap_in(r)
    assert r.state == ReqState.RUNNING
    assert r.rid not in eng.kv.host_store
    assert eng.kv.tokens_used == used_before
    new_slot = r.engine_slot
    assert eng.slot_req[new_slot] is r
    # the restored device slice is bit-identical to the parked one
    after = jax.device_get(_read_slot(eng.cache, new_slot))
    assert tree_equal(after, before)


def test_swapped_request_finishes_like_uncontended(llama):
    """After a forced swap round-trip mid-decode, the remaining tokens
    must be exactly what an undisturbed engine produces."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(1)

    ref_eng = mk_engine(m, params, lat)
    ref = mk_req(cfg, rng)
    ref_eng.run([ref], max_iterations=100)

    eng = mk_engine(m, params, lat, mode="swap")
    r = Request(rid=ref.rid, arrival=0.0, prompt_len=ref.prompt_len,
                output_len=ref.output_len, spec=ref.spec,
                prompt_tokens=ref.prompt_tokens)
    start_running(eng, r)
    eng._preempt(r)
    while eng.step():            # scheduler swaps it back in and finishes
        pass
    assert r.generated >= r.output_len
    assert r.output_tokens == ref.output_tokens


def test_recompute_preemption_drops_state(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(2)
    eng = mk_engine(m, params, lat, mode="recompute")
    r = mk_req(cfg, rng)
    slot = start_running(eng, r)
    gen_before = r.generated
    used_before = eng.kv.tokens_used

    eng._preempt(r)
    assert r.state == ReqState.WAITING
    assert not r.prefilled                   # must re-prefill from scratch
    assert r.rid not in eng.kv.host_store    # nothing parked
    assert eng.kv.swap_bytes_total == 0
    assert slot in eng.kv.free_slots and slot not in eng.slot_req
    assert eng.kv.tokens_used == used_before - r.context_len
    # generated prefix is kept on the request (recompute replays it)
    assert r.generated == gen_before and len(r.output_tokens) == gen_before


def test_recompute_resumes_token_exact(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(3)

    ref_eng = mk_engine(m, params, lat)
    ref = mk_req(cfg, rng)
    ref_eng.run([ref], max_iterations=100)

    eng = mk_engine(m, params, lat, mode="recompute")
    r = Request(rid=ref.rid, arrival=0.0, prompt_len=ref.prompt_len,
                output_len=ref.output_len, spec=ref.spec,
                prompt_tokens=ref.prompt_tokens)
    start_running(eng, r, steps=3)
    eng._preempt(r)
    while eng.step():            # re-prefills prompt + generated prefix
        pass
    assert r.generated >= r.output_len
    assert r.output_tokens == ref.output_tokens
    assert eng.kv.tokens_used == 0           # everything released


# ---------------------------------------------------------------------------
# Preemption under speculation: both caches round-trip, mid-proposal
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def spec_setup(llama):
    """Target + a perturbed-params draft (partial, context-dependent
    acceptance — so preemption happens with rejected-proposal junk parked
    beyond the committed frontier, the 'mid-proposal' state)."""
    cfg, m, params = llama
    draft_params = jax.tree.map(
        lambda a: a + 1e-3 * jax.random.normal(
            jax.random.PRNGKey(9), a.shape, a.dtype), params
    )
    return cfg, m, params, m, draft_params


def mk_spec_engine(spec_setup, mode="swap", k=2):
    cfg, m, params, dm, dparams = spec_setup
    lat = SpeculativeLatencyModel(cfg, TPU_V5E, dm.cfg, k=k)
    sched = make_scheduler("fcfs", 10_000, lat)
    return ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64,
                         preemption_mode=mode,
                         draft_model=dm, draft_params=dparams, spec_k=k)


def test_spec_swap_roundtrip_preserves_both_kv(spec_setup):
    """Swap out a speculative request mid-proposal: the parked target AND
    draft slices are bit-identical to what was on device — including stale
    rejected-draft entries past the committed length — and both come back
    bit-identical on swap-in."""
    cfg = spec_setup[0]
    rng = np.random.default_rng(10)
    eng = mk_spec_engine(spec_setup, mode="swap")
    r = mk_req(cfg, rng, out_len=20, plen=12)
    slot = start_running(eng, r)
    assert eng.spec_steps > 0            # verify iterations actually ran

    before_t = jax.device_get(_read_slot(eng.cache, slot))
    before_d = eng.draft.park(slot)
    used_before = eng.kv.tokens_used

    eng._preempt(r)
    assert r.state == ReqState.SWAPPED
    assert eng.kv.tokens_used == used_before - r.context_len
    assert tree_equal(eng.kv.host_store[r.rid], before_t)
    assert tree_equal(eng.kv.draft_store[r.rid], before_d)

    eng._swap_in(r)
    assert r.rid not in eng.kv.host_store
    assert r.rid not in eng.kv.draft_store
    assert eng.kv.tokens_used == used_before
    new_slot = r.engine_slot
    assert tree_equal(jax.device_get(_read_slot(eng.cache, new_slot)),
                      before_t)
    assert tree_equal(eng.draft.park(new_slot), before_d)


def test_spec_swapped_resumes_token_exact(spec_setup):
    """After a forced swap round-trip mid-proposal, the speculative engine
    finishes with exactly the tokens an undisturbed baseline produces."""
    cfg, m, params, _, _ = spec_setup
    rng = np.random.default_rng(11)

    ref = mk_req(cfg, rng, out_len=18, plen=12)
    lat = LatencyModel(cfg, TPU_V5E)
    ref_eng = ServingEngine(m, params, make_scheduler("fcfs", 10_000, lat),
                            lat, num_slots=4, max_seq=64)
    ref_eng.run([ref], max_iterations=100)

    eng = mk_spec_engine(spec_setup, mode="swap")
    r = Request(rid=ref.rid, arrival=0.0, prompt_len=ref.prompt_len,
                output_len=ref.output_len, spec=ref.spec,
                prompt_tokens=ref.prompt_tokens)
    start_running(eng, r)
    eng._preempt(r)
    while eng.step():                    # swap back in and finish
        pass
    assert r.generated >= r.output_len
    assert r.output_tokens == ref.output_tokens


def test_spec_recompute_matches_nonspec_recompute(spec_setup):
    """Recompute-mode differential: re-prefill rebuilds the cache in
    prefill layout, whose logits may legitimately flip near-tie argmaxes
    vs the stepwise layout (a pre-existing engine property — see
    test_recompute_resumes_token_exact, which passes only because its
    trace is argmax-robust). The invariant speculation must preserve is
    therefore *equivalence with the non-speculative engine preempted at
    the same point*: same committed prefix dropped and re-prefilled, same
    continuation."""
    cfg, m, params, _, _ = spec_setup
    rng = np.random.default_rng(12)
    proto = mk_req(cfg, rng, out_len=18, plen=12)
    lat = LatencyModel(cfg, TPU_V5E)

    # speculative engine: run to mid-stream, force recompute preemption
    eng = mk_spec_engine(spec_setup, mode="recompute")
    r_spec = Request(rid=proto.rid, arrival=0.0, prompt_len=proto.prompt_len,
                     output_len=proto.output_len, spec=proto.spec,
                     prompt_tokens=proto.prompt_tokens)
    eng.submit(r_spec)
    while r_spec.generated < 6:
        assert eng.step()
    cut = r_spec.generated               # bursts may overshoot 6
    eng._preempt(r_spec)
    assert not r_spec.prefilled and r_spec.rid not in eng.kv.draft_store
    while eng.step():
        pass

    # non-spec engine preempted at the *same* generated count
    ref_eng = ServingEngine(m, params, make_scheduler("fcfs", 10_000, lat),
                            lat, num_slots=4, max_seq=64,
                            preemption_mode="recompute")
    r_ref = Request(rid=proto.rid, arrival=0.0, prompt_len=proto.prompt_len,
                    output_len=proto.output_len, spec=proto.spec,
                    prompt_tokens=proto.prompt_tokens)
    ref_eng.submit(r_ref)
    while r_ref.generated < cut:
        assert ref_eng.step()
    assert r_ref.generated == cut        # 1 token/step: lands exactly
    assert r_ref.output_tokens == r_spec.output_tokens[:cut]
    ref_eng._preempt(r_ref)
    while ref_eng.step():
        pass

    assert r_spec.generated >= r_spec.output_len
    assert r_spec.output_tokens == r_ref.output_tokens


def test_double_swap_roundtrip(llama):
    """Two park/restore cycles in a row must still be exact (regression
    guard for slot-reuse bugs: the second allocate may land on a
    different slot than the first)."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(4)
    eng = mk_engine(m, params, lat, mode="swap")
    r = mk_req(cfg, rng, out_len=12)
    start_running(eng, r)

    for _ in range(2):
        slot = r.engine_slot
        before = jax.device_get(_read_slot(eng.cache, slot))
        eng._preempt(r)
        eng._swap_in(r)
        after = jax.device_get(_read_slot(eng.cache, r.engine_slot))
        assert tree_equal(after, before)
        assert eng.step()        # decode one more token between cycles
    while eng.step():
        pass
    assert r.generated >= r.output_len
