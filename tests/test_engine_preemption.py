"""Unit tests for the engine's preemption paths: `_preempt` (swap-to-host
vs recompute) and `_swap_in`.

The integration suite (test_engine.py::test_preemption_exactness) already
proves preempted requests finish with the right tokens end-to-end; these
tests pin the mechanism itself — the KV/state slice that comes back from
host RAM is *bit-identical* to what was parked, the slot/token accounting
balances on both sides, and the recompute path genuinely drops state.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, TPU_V5E, make_scheduler
from repro.models import Model
from repro.serving import Request, ReqState, ServingEngine
from repro.serving.engine import _read_slot


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def mk_req(cfg, rng, rid=0, out_len=10, plen=12):
    return Request(
        rid=rid, arrival=0.0, prompt_len=plen, output_len=out_len,
        spec=QoESpec(ttft=1.0, tds=4.8),
        prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
    )


def mk_engine(m, params, lat, mode="swap"):
    sched = make_scheduler("fcfs", 10_000, lat)
    return ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64,
                         preemption_mode=mode)


def tree_equal(a, b):
    eq = jax.tree.map(
        lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))), a, b
    )
    return all(jax.tree.leaves(eq))


def start_running(eng, r, steps=2):
    """Submit and step until the request is mid-decode."""
    eng.submit(r)
    for _ in range(steps):
        assert eng.step()
    assert r.state == ReqState.RUNNING and r.generated > 0
    return r.engine_slot


def test_swap_roundtrip_preserves_kv_exactly(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(0)
    eng = mk_engine(m, params, lat, mode="swap")
    r = mk_req(cfg, rng)
    slot = start_running(eng, r)

    before = jax.device_get(_read_slot(eng.cache, slot))
    used_before = eng.kv.tokens_used

    eng._preempt(r)
    assert r.state == ReqState.SWAPPED
    assert r.preemptions == 1 and eng.preemptions == 1
    assert slot in eng.kv.free_slots and slot not in eng.slot_req
    assert eng.kv.tokens_used == used_before - r.context_len
    # the parked host slice is exactly the device slice that was evicted
    parked = eng.kv.host_store[r.rid]
    assert tree_equal(parked, before)
    assert eng.kv.swap_bytes_total > 0

    eng._swap_in(r)
    assert r.state == ReqState.RUNNING
    assert r.rid not in eng.kv.host_store
    assert eng.kv.tokens_used == used_before
    new_slot = r.engine_slot
    assert eng.slot_req[new_slot] is r
    # the restored device slice is bit-identical to the parked one
    after = jax.device_get(_read_slot(eng.cache, new_slot))
    assert tree_equal(after, before)


def test_swapped_request_finishes_like_uncontended(llama):
    """After a forced swap round-trip mid-decode, the remaining tokens
    must be exactly what an undisturbed engine produces."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(1)

    ref_eng = mk_engine(m, params, lat)
    ref = mk_req(cfg, rng)
    ref_eng.run([ref], max_iterations=100)

    eng = mk_engine(m, params, lat, mode="swap")
    r = Request(rid=ref.rid, arrival=0.0, prompt_len=ref.prompt_len,
                output_len=ref.output_len, spec=ref.spec,
                prompt_tokens=ref.prompt_tokens)
    start_running(eng, r)
    eng._preempt(r)
    while eng.step():            # scheduler swaps it back in and finishes
        pass
    assert r.generated >= r.output_len
    assert r.output_tokens == ref.output_tokens


def test_recompute_preemption_drops_state(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(2)
    eng = mk_engine(m, params, lat, mode="recompute")
    r = mk_req(cfg, rng)
    slot = start_running(eng, r)
    gen_before = r.generated
    used_before = eng.kv.tokens_used

    eng._preempt(r)
    assert r.state == ReqState.WAITING
    assert not r.prefilled                   # must re-prefill from scratch
    assert r.rid not in eng.kv.host_store    # nothing parked
    assert eng.kv.swap_bytes_total == 0
    assert slot in eng.kv.free_slots and slot not in eng.slot_req
    assert eng.kv.tokens_used == used_before - r.context_len
    # generated prefix is kept on the request (recompute replays it)
    assert r.generated == gen_before and len(r.output_tokens) == gen_before


def test_recompute_resumes_token_exact(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(3)

    ref_eng = mk_engine(m, params, lat)
    ref = mk_req(cfg, rng)
    ref_eng.run([ref], max_iterations=100)

    eng = mk_engine(m, params, lat, mode="recompute")
    r = Request(rid=ref.rid, arrival=0.0, prompt_len=ref.prompt_len,
                output_len=ref.output_len, spec=ref.spec,
                prompt_tokens=ref.prompt_tokens)
    start_running(eng, r, steps=3)
    eng._preempt(r)
    while eng.step():            # re-prefills prompt + generated prefix
        pass
    assert r.generated >= r.output_len
    assert r.output_tokens == ref.output_tokens
    assert eng.kv.tokens_used == 0           # everything released


def test_double_swap_roundtrip(llama):
    """Two park/restore cycles in a row must still be exact (regression
    guard for slot-reuse bugs: the second allocate may land on a
    different slot than the first)."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(4)
    eng = mk_engine(m, params, lat, mode="swap")
    r = mk_req(cfg, rng, out_len=12)
    start_running(eng, r)

    for _ in range(2):
        slot = r.engine_slot
        before = jax.device_get(_read_slot(eng.cache, slot))
        eng._preempt(r)
        eng._swap_in(r)
        after = jax.device_get(_read_slot(eng.cache, r.engine_slot))
        assert tree_equal(after, before)
        assert eng.step()        # decode one more token between cycles
    while eng.step():
        pass
    assert r.generated >= r.output_len
