"""Minimal deterministic stand-in for `hypothesis`.

The container does not ship `hypothesis`; tier-1 must still run the
property tests. When the real package is importable we re-export it
unchanged. Otherwise a tiny fallback runs each test against
``max_examples`` seeded pseudo-random draws (plus the bound endpoints for
scalar strategies), covering exactly the API surface this repo's tests
use: ``given``, ``settings``, ``st.integers``, ``st.floats``,
``st.lists``. No shrinking, no database — failures print the drawn
arguments instead.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    import functools
    import zlib

    import numpy as np

    class _Strategy:
        def __init__(self, draw, edges=()):
            self.draw = draw
            self.edges = tuple(edges)   # deterministic boundary examples

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                edges=(min_value, max_value),
            )

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                edges=(min_value, max_value),
            )

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(max_examples=25, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 25))
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                # boundary examples first (when every strategy has edges)
                if all(s.edges for s in strategies):
                    for k in range(len(strategies[0].edges)):
                        drawn = [s.edges[min(k, len(s.edges) - 1)]
                                 for s in strategies]
                        _call(fn, args, drawn, kwargs)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    _call(fn, args, drawn, kwargs)
            # keep pytest from treating the drawn parameters as fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _call(fn, args, drawn, kwargs):
        try:
            fn(*args, *drawn, **kwargs)
        except Exception:
            print(f"falsifying example: {fn.__qualname__}{tuple(drawn)}")
            raise
