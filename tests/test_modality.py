"""Modality stubs (serving.modality) feed real enc-dec / VLM serving paths."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving import modality as frontend


def test_audio_frontend_through_encdec():
    cfg = get_smoke_config("seamless-m4t-medium")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ids = jnp.array([0, 7])
    frames = frontend.synthetic_frames(cfg, ids, 8)
    assert frames.shape == (2, 8, cfg.d_model)
    cache = m.init_cache(2, 16, enc_seq=8, dtype=jnp.float32)
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, cache = m.prefill(params, {"tokens": toks, "frames": frames}, cache)
    assert logits.shape == (2, cfg.vocab_size)
    # different samples see different encoder memories
    assert float(jnp.max(jnp.abs(logits[0] - logits[1]))) > 1e-5


def test_vision_frontend_through_vlm():
    cfg = get_smoke_config("pixtral-12b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ids = jnp.array([1, 2])
    patches = frontend.synthetic_patches(cfg, ids, 4)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    cache = m.init_cache(2, 16, dtype=jnp.float32)
    logits, cache = m.prefill(
        params, {"tokens": toks, "patch_embeds": patches}, cache
    )
    assert logits.shape == (2, cfg.vocab_size)
    assert int(cache["length"][0]) == 10        # 4 patches + 6 text tokens
    # the image prefix conditions generation
    cache2 = m.init_cache(2, 16, dtype=jnp.float32)
    patches2 = frontend.synthetic_patches(cfg, ids + 5, 4)
    logits2, _ = m.prefill(
        params, {"tokens": toks, "patch_embeds": patches2}, cache2
    )
    assert float(jnp.max(jnp.abs(logits - logits2))) > 1e-5


def test_specs_match_model_input_specs():
    from repro.configs import get_config, get_shape
    cfg = get_config("seamless-m4t-medium")
    m = Model(cfg)
    specs = m.input_specs(get_shape("prefill_32k"))
    want = frontend.audio_frame_specs(cfg, 32, 32768)
    assert specs["frames"].shape == want.shape
    assert specs["frames"].dtype == want.dtype


def test_frontend_shim_still_reexports_with_deprecation():
    """serving.frontend moved to serving.modality; the shim must keep
    external imports working and warn once."""
    import importlib
    import sys
    import warnings

    sys.modules.pop("repro.serving.frontend", None)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = importlib.import_module("repro.serving.frontend")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert legacy.synthetic_frames is frontend.synthetic_frames
    assert legacy.audio_frame_specs is frontend.audio_frame_specs
