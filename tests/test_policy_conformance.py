"""Cross-policy conformance harness: the arena's shared contract.

Every policy in `repro.core.policies.SCHEDULERS` — baselines, Andes
(greedy + DP), the fairness counters, the burst-preemptive competitor —
must pass one parametrized suite:

  * protocol:        instances satisfy the `SchedulingPolicy` protocol
  * KV budget:       no schedule() call ever returns a batch whose KV
                     demand exceeds M (checked on EVERY call via a
                     wrapped scheduler, not just on outcomes)
  * conservation:    every request finishes with exactly its requested
                     tokens; emissions are strictly ordered and never
                     precede arrival
  * preemption cap:  policies that declare `enforces_preemption_cap`
                     keep avg preemptions/request <= cfg.preemption_cap
  * reset():         rerunning the SAME backend reproduces the first
                     run bit-for-bit (scheduler state fully cleared)
  * determinism:     two fresh backends produce identical schedules —
                     on the simulator for all policies, and on the real
                     engine (k=0) for all policies

Plus the observability half (ISSUE satellite): every policy's
`scheduler.schedule` Observer events carry the acting policy's name and
its pricing/decision summary, and QoE recomputed purely from the trace
reconciles with the reported QoE under FCFS and VTC runs (not just
Andes, which test_obs.py already pins).
"""
import copy

import pytest

from repro.configs import get_config
from repro.core import A100_4X, LatencyModel, SchedulerConfig, make_scheduler
from repro.core.policies import SCHEDULERS, SchedulingPolicy
from repro.obs import TraceRecorder, qoe_from_trace
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_adversarial_workload, make_workload

CFG = get_config("opt-66b")
LAT = LatencyModel(CFG, A100_4X)
KV = 12_000                      # contended: forces queueing + preemption
POLICIES = sorted(SCHEDULERS)
CAP_POLICIES = [p for p in POLICIES
                if SCHEDULERS[p].enforces_preemption_cap]

# the policy-specific decision payload every schedule event must carry
# (beyond the universal policy/iteration/chosen/victims envelope)
PAYLOAD_KEYS = {
    "fcfs": {"kv_used"},
    "round_robin": {"rotated", "kv_used"},
    "andes": {"triggered"},
    "andes_dp": {"triggered"},
    "vtc": {"counter_gap", "n_tenants"},
    "wsc": {"counter_gap", "n_tenants"},
    "burst": {"slack_min", "n_starving"},
}


def mk_sim(policy, kv=KV, **sched_kw):
    sched = make_scheduler(policy, kv, LAT, SchedulerConfig(), **sched_kw)
    return ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=kv))


def contended_workload(n=80, seed=3):
    return make_workload(n, 8.0, seed=seed, arrival="gamma", cv=3.0)


def fingerprint(reqs):
    return [(r.rid, r.generated, tuple(r.emit_times), r.preemptions,
             r.final_qoe()) for r in sorted(reqs, key=lambda r: r.rid)]


# ---------------------------------------------------------------------------
# Protocol + registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_satisfies_scheduling_policy_protocol(policy):
    sched = make_scheduler(policy, KV, LAT, SchedulerConfig())
    assert isinstance(sched, SchedulingPolicy)
    assert sched.name == policy
    # fresh schedulers start zeroed (reset() ran in __init__)
    assert sched.iteration == 0
    assert sched.total_preemptions == 0
    assert sched.total_requests == 0
    assert sched.mean_output_len == 256.0          # estimator at its prior


def test_registry_names_match_class_names():
    for name, cls in SCHEDULERS.items():
        assert cls.name == name


# ---------------------------------------------------------------------------
# KV budget: checked on every single schedule() call
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_kv_budget_never_exceeded(policy):
    sim = mk_sim(policy)
    sched = sim.sched
    st = sched.cfg.state_equiv_tokens
    calls = {"n": 0}
    inner = sched.schedule

    def checked(now, live, fluid):
        batch = inner(now, live, fluid)
        calls["n"] += 1
        demand = sum(r.kv_tokens(st) for r in batch)
        assert demand <= sched.M, \
            f"{policy}: batch demands {demand} KV tokens > M={sched.M}"
        assert len({r.rid for r in batch}) == len(batch), "duplicate rids"
        return batch

    sched.schedule = checked
    sim.run(contended_workload())
    assert calls["n"] > 50, "trace never exercised the scheduler"


# ---------------------------------------------------------------------------
# Conservation + emission ordering
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_token_conservation_and_emission_order(policy):
    res = mk_sim(policy).run(contended_workload())
    assert len(res.requests) == 80
    for r in res.requests:
        assert r.generated == r.output_len, \
            f"{policy}: rid {r.rid} emitted {r.generated}/{r.output_len}"
        assert len(r.emit_times) == r.output_len
        # no emission before admission is possible: arrival + >0 prefill
        assert r.emit_times[0] > r.arrival
        assert all(a <= b for a, b in zip(r.emit_times, r.emit_times[1:]))


# ---------------------------------------------------------------------------
# Preemption cap (§4.2 #4) — for the policies that declare it
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", CAP_POLICIES)
def test_preemption_cap_bounds_discretionary_preemptions(policy):
    """The §4.2 #4 cap bounds *discretionary* preemptions; memory-forced
    evictions are exempt (requests that no longer fit cannot be kept).
    End-to-end pin: tightening the cap monotonically shrinks the
    preemption count on the same trace, and an effectively-unbounded cap
    preempts strictly more than a tight one."""
    counts = {}
    for cap in (0.0, 1.0, 1e9):
        sched = make_scheduler(policy, KV, LAT,
                               SchedulerConfig(preemption_cap=cap))
        sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=KV))
        counts[cap] = sim.run(contended_workload()).preemptions
    assert counts[0.0] <= counts[1.0] <= counts[1e9], counts
    assert counts[0.0] < counts[1e9], \
        f"{policy}: cap has no effect ({counts})"


def test_apply_preemption_cap_unit():
    """The shared helper's exact guarantees, isolated from the serving
    loop: budget-limited sparing keeps the cheapest-context victims
    running, a zero budget spares every victim memory allows, and an
    ample budget leaves the decision untouched."""
    from repro.core import QoESpec
    from repro.core.request import ReqState, Request

    sched = make_scheduler("andes", 1000, LAT,
                           SchedulerConfig(preemption_cap=1.0))

    def mk(rid, ctx, state):
        r = Request(rid=rid, arrival=0.0, prompt_len=ctx, output_len=8,
                    spec=QoESpec(ttft=1.0, tds=4.8))
        r.state = state
        return r

    running = [mk(0, 100, ReqState.RUNNING), mk(1, 200, ReqState.RUNNING),
               mk(2, 300, ReqState.RUNNING)]
    newcomer = mk(3, 150, ReqState.WAITING)
    live = running + [newcomer]
    weights = sched._weights(live)

    # ample budget (10 requests seen, 0 preempted so far): untouched
    sched.total_requests, sched.total_preemptions = 10, 0
    chosen = [newcomer]
    assert sched._apply_preemption_cap(chosen, running, weights, live) \
        == chosen

    # zero budget: every would-be victim is spared (memory allows all)
    sched.total_requests, sched.total_preemptions = 10, 10
    out = sched._apply_preemption_cap([newcomer], running, weights, live)
    assert set(r.rid for r in out) == {0, 1, 2, 3}

    # budget of exactly one: the HIGHEST-context victim is the one
    # preempted (cheapest-to-keep are spared first)
    sched.total_requests, sched.total_preemptions = 10, 9
    out = sched._apply_preemption_cap([newcomer], running, weights, live)
    assert set(r.rid for r in out) == {0, 1, 3}

    # memory overrides sparing: with M too small for everyone, the spared
    # running set is repacked under M (running kept ahead of admissions)
    sched.M = 450
    sched.total_requests, sched.total_preemptions = 10, 10
    out = sched._apply_preemption_cap([newcomer], running, weights, live)
    kept = {r.rid for r in out}
    assert sum(r.kv_tokens() for r in out) <= 450
    assert all(r.state == ReqState.RUNNING for r in out
               if r.rid != 3) and kept <= {0, 1, 2, 3}


def test_cap_flag_covers_andes_and_burst():
    assert set(CAP_POLICIES) >= {"andes", "andes_dp", "burst"}


# ---------------------------------------------------------------------------
# reset() reproducibility + fresh-backend determinism (simulator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_same_backend_rerun_is_bit_identical(policy):
    """sim.run() calls reset(); a second run on the SAME simulator (same
    scheduler object, counters/queues dirty from run 1) must reproduce
    run 1 exactly — the policy's reset() has to clear everything."""
    sim = mk_sim(policy)
    wl = contended_workload()
    first = sim.run(copy.deepcopy(wl))
    assert sim.sched.total_requests > 0          # run 1 dirtied the state
    second = sim.run(copy.deepcopy(wl))
    assert fingerprint(first.requests) == fingerprint(second.requests)


@pytest.mark.parametrize("policy", POLICIES)
def test_fresh_backend_determinism(policy):
    wl = contended_workload()
    a = mk_sim(policy).run(copy.deepcopy(wl))
    b = mk_sim(policy).run(copy.deepcopy(wl))
    assert fingerprint(a.requests) == fingerprint(b.requests)


@pytest.mark.parametrize("policy", ["vtc", "wsc", "burst"])
def test_adversarial_trace_determinism(policy):
    """The new policies on the traces built to stress them."""
    wl = make_adversarial_workload("burst", 60, 6.0, seed=11)
    a = mk_sim(policy).run([r.clone() for r in wl])
    b = mk_sim(policy).run([r.clone() for r in wl])
    assert fingerprint(a.requests) == fingerprint(b.requests)
    assert all(r.generated == r.output_len for r in a.requests)


# ---------------------------------------------------------------------------
# Engine (k=0) determinism: every policy drives the real engine unchanged
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine_workload(cfg, n=6, seed=5):
    import numpy as np

    from repro.core import QoESpec
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    wl = []
    for i in range(n):
        plen = int(rng.integers(8, 24))
        wl.append(Request(
            rid=i, arrival=i * 0.02, prompt_len=plen,
            output_len=int(rng.integers(6, 12)),
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    return wl


@pytest.mark.parametrize("policy", POLICIES)
def test_engine_k0_rerun_determinism(engine_setup, policy):
    from repro.core import TPU_V5E
    from repro.serving import ServingEngine

    cfg, model, params = engine_setup
    lat = LatencyModel(cfg, TPU_V5E)
    cap = 160                                   # 3 slots: forces queueing
    eng = ServingEngine(model, params,
                        make_scheduler(policy, cap, lat), lat,
                        num_slots=3, max_seq=64, capacity_tokens=cap)
    wl = _engine_workload(cfg)

    wl1 = [r.clone() for r in wl]
    eng.run(wl1)
    wl2 = [r.clone() for r in wl]
    eng.run(wl2)                                # same engine, after reset()

    def fp(reqs):
        return [(r.rid, tuple(r.output_tokens), tuple(r.emit_times),
                 r.preemptions, r.final_qoe()) for r in reqs]

    assert fp(wl1) == fp(wl2)
    for r in wl1:
        assert r.generated == r.output_len
        assert r.emit_times[0] > r.arrival


# ---------------------------------------------------------------------------
# Observability: schedule events carry the acting policy + its summary,
# and the trace reconciles under non-Andes policies too
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_schedule_events_carry_policy_name_and_summary(policy):
    sim = mk_sim(policy)
    trace = TraceRecorder()
    sim.observer = trace
    sim.run(contended_workload())

    decisions = [e for e in trace.events if e.kind == "schedule"]
    assert decisions
    for d in decisions:
        assert d.data["policy"] == policy
        assert {"iteration", "n_live", "n_chosen",
                "chosen", "victims"} <= set(d.data)
    # the policy-specific pricing/decision summary rides along
    want = PAYLOAD_KEYS[policy]
    assert any(want <= set(d.data) for d in decisions), \
        f"{policy}: no decision carried {want}"
    if policy in ("andes", "andes_dp"):
        triggered = [d for d in decisions if d.data.get("triggered")]
        assert triggered, "tight KV never triggered the knapsack"
        assert all("q_wait_mean" in d.data for d in triggered)


@pytest.mark.parametrize("policy", ["fcfs", "vtc"])
def test_trace_reconciles_under_non_andes_policies(policy):
    sim = mk_sim(policy)
    trace = TraceRecorder()
    sim.observer = trace
    res = sim.run(contended_workload())

    traced = qoe_from_trace(trace.events)
    for r in res.requests:
        assert traced.get(r.rid, 0.0) == r.final_qoe(), r.rid
