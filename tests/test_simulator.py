"""Simulator behaviour + paper-claim validation at small scale."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    A100_4X,
    LatencyModel,
    SchedulerConfig,
    make_scheduler,
)
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_workload

CFG = get_config("opt-66b")
LAT = LatencyModel(CFG, A100_4X)
M = 65_000


def run(sched_name, rate, n=250, seed=1, **simkw):
    wl = make_workload(n, rate, seed=seed)
    sched = make_scheduler(sched_name, M, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=M, **simkw))
    return sim.run(wl)


def test_all_requests_complete():
    res = run("fcfs", 2.0)
    assert all(r.generated >= r.output_len for r in res.requests)
    assert res.total_tokens == sum(r.output_len for r in res.requests)


def test_underload_everyone_perfect():
    res = run("fcfs", 0.5)
    assert res.avg_qoe() > 0.98
    res = run("andes", 0.5)
    assert res.avg_qoe() > 0.98


def test_emit_monotone_and_counts():
    res = run("andes", 3.0)
    for r in res.requests:
        assert len(r.emit_times) == r.generated
        assert all(b >= a for a, b in zip(r.emit_times, r.emit_times[1:]))


def test_memory_never_exceeded():
    wl = make_workload(150, 3.5, seed=2)
    sched = make_scheduler("andes", 20_000, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=20_000))

    orig = sched.schedule
    peaks = []

    def wrapped(now, live, fluid):
        out = orig(now, live, fluid)
        peaks.append(sum(r.kv_tokens() for r in out))
        return out

    sched.schedule = wrapped
    sim.run(wl)
    assert max(peaks) <= 20_000


# ---------------------------------------------------------------------------
# paper claims (reduced scale; full scale in benchmarks/)
# ---------------------------------------------------------------------------

def run_tight(sched_name, rate=5.0, n=300, seed=1, m=25_000, **simkw):
    """Overloaded regime: small KV capacity makes memory bind immediately
    (the full-scale operating points live in benchmarks/)."""
    wl = make_workload(n, rate, seed=seed)
    sched = make_scheduler(sched_name, m, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=m, **simkw))
    return sim.run(wl)


@pytest.mark.slow
def test_andes_beats_fcfs_under_overload():
    """Core claim: under high load Andes improves avg QoE and tames TTFT."""
    fcfs = run_tight("fcfs")
    andes = run_tight("andes")
    assert andes.avg_qoe() > fcfs.avg_qoe() + 0.1
    assert np.percentile(andes.ttfts(), 90) < np.percentile(fcfs.ttfts(), 90) / 5


@pytest.mark.slow
def test_andes_throughput_drop_small():
    """Throughput cost of preemption stays bounded even in deep overload
    (paper's <=10% applies at its operating points; benchmarks reproduce
    that — this tight regime is ~1.7x over capacity)."""
    fcfs = run_tight("fcfs")
    andes = run_tight("andes")
    assert andes.throughput() > 0.75 * fcfs.throughput()


@pytest.mark.slow
def test_preemption_frequency_bounded():
    """Paper §6.2.3 / Fig 13: ~<= 1 preemption per request on average."""
    res = run_tight("andes")
    assert res.preemption_freq() <= 1.5


def test_recompute_mode_runs():
    res = run_tight("andes", n=150, preemption_mode="recompute")
    assert all(r.generated >= r.output_len for r in res.requests)


def test_round_robin_rotates():
    res = run_tight("round_robin")
    assert res.preemptions > 0
