"""Physically paged KV cache (ISSUE 10 tentpole, part a).

PR 8 made paging an *accounting* layer: `KVSlotManager` priced pages and
block tables while the device cache stayed one contiguous slab. This PR
backs the same tables with a real device page pool (models/cache.py
`init_paged_cache`, kernels/paged_attention.py). The verification spine
is differential: a physical engine must reproduce the accounting-only
engine **bit-for-bit** — token ids, emit timestamps, preemption counts,
final QoE — because the page layout changes where bytes live, never what
is computed. The sweep covers the degenerate oracles (page_size=1: page
arithmetic IS token arithmetic) and interior page sizes, uncontended and
under preemption pressure in both modes, plus chunked prefill and the
eager (bucketless) prefill path.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, SchedulerConfig, TPU_V5E, make_scheduler
from repro.models import Model
from repro.models import cache as cache_lib
from repro.serving import HotpathConfig, Request, ServingEngine, fingerprint


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _mk_workload(cfg, n, rng, out_len=12, stagger=0.05):
    wl = []
    for i in range(n):
        plen = int(rng.integers(5, 30))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen)))
    return wl


def _run(cfg, m, params, wl, **kw):
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler(
        "andes", kw.get("capacity_tokens", 4 * 64), lat,
        SchedulerConfig(delta_t=kw.pop("delta_t", 50.0)))
    eng = ServingEngine(m, params, sched, lat,
                        num_slots=kw.pop("num_slots", 4), max_seq=64, **kw)
    out = eng.run([r.clone() for r in wl], max_iterations=4000)
    return out, eng


# ---------------------------------------------------------------------------
# construction: capability detection and layout
# ---------------------------------------------------------------------------

def test_physical_auto_on_for_paged_dense(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 256, lat, SchedulerConfig())
    eng = ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64,
                        capacity_tokens=256, page_size=16)
    assert eng.physical_pages
    assert cache_lib.is_paged(eng.cache)
    # pool size IS the admission capacity, in pages
    assert eng.cache["k"].shape[1] == eng._pool_pages == eng.kv.total_pages
    assert eng.cache["k"].shape[2] == 16
    # contiguous engines keep the slab layout
    sched2 = make_scheduler("andes", 256, lat, SchedulerConfig())
    eng2 = ServingEngine(m, params, sched2, lat, num_slots=4, max_seq=64,
                         capacity_tokens=256)
    assert not eng2.physical_pages
    assert not cache_lib.is_paged(eng2.cache)


def test_physical_flag_validation(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)

    def mk(**kw):
        sched = make_scheduler("andes", 256, lat, SchedulerConfig())
        return ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64,
                             capacity_tokens=256, **kw)

    with pytest.raises(ValueError, match="paged engine"):
        mk(physical_pages=True)                     # no page_size
    # explicit False forces accounting-only even when auto would say yes
    eng = mk(page_size=16, physical_pages=False)
    assert not eng.physical_pages
    assert not cache_lib.is_paged(eng.cache)
    assert eng.kv.paged                             # accounting still pages


def test_physical_unsupported_family_falls_back():
    cfg = get_smoke_config("falcon-mamba-7b")       # ssm: no KV to page
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 256, lat, SchedulerConfig())
    eng = ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64,
                        capacity_tokens=256, page_size=16)
    assert not eng.physical_pages                   # auto declines
    with pytest.raises(ValueError, match="does not support"):
        ServingEngine(m, params,
                      make_scheduler("andes", 256, lat, SchedulerConfig()),
                      lat, num_slots=4, max_seq=64, capacity_tokens=256,
                      page_size=16, physical_pages=True)


# ---------------------------------------------------------------------------
# differential oracles: physical ≡ accounting-only, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size", [1, 16])
def test_physical_vs_accounting_uncontended(llama, page_size):
    """Same page_size, same scheduler view — only the byte layout differs.
    page_size=1 additionally chains to PR 8's oracle: accounting-paged ≡
    unpaged, so physical ≡ the original contiguous engine transitively."""
    cfg, m, params = llama
    rng = np.random.default_rng(0)
    wl = _mk_workload(cfg, 6, rng)
    acct, eng_a = _run(cfg, m, params, wl, page_size=page_size,
                       physical_pages=False)
    phys, eng_p = _run(cfg, m, params, wl, page_size=page_size)
    assert eng_p.physical_pages and not eng_a.physical_pages
    assert eng_p.page_scatters > 0, "prefill never hit the pool"
    assert fingerprint(phys) == fingerprint(acct)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["swap", "recompute"])
def test_physical_vs_accounting_contended(llama, mode):
    """Preemption pressure: eviction must free real rows (swap gathers
    pages to host and re-scatters on swap-in; recompute drops and
    re-prefills into whatever pages the pool hands back) without moving
    a single scheduling decision or token."""
    cfg, m, params = llama
    rng = np.random.default_rng(1)
    wl = _mk_workload(cfg, 8, rng, out_len=15, stagger=0.01)
    acct, eng_a = _run(cfg, m, params, wl, num_slots=2, capacity_tokens=100,
                       preemption_mode=mode, delta_t=5.0, page_size=8,
                       physical_pages=False)
    assert eng_a.preemptions > 0, "test requires contention"
    phys, eng_p = _run(cfg, m, params, wl, num_slots=2, capacity_tokens=100,
                       preemption_mode=mode, delta_t=5.0, page_size=8)
    assert eng_p.preemptions == eng_a.preemptions
    if mode == "swap":
        assert eng_p.page_gathers > 0
        assert eng_p.page_gather_bytes > 0
    assert fingerprint(phys) == fingerprint(acct)


def test_physical_chunked_prefill_differential(llama):
    """Chunked admission grows a resident's table one chunk at a time;
    every chunk's recomputed prefix must land in the (possibly moved)
    pages the manager currently assigns."""
    cfg, m, params = llama
    rng = np.random.default_rng(2)
    wl = _mk_workload(cfg, 6, rng)
    acct, _ = _run(cfg, m, params, wl, page_size=8, prefill_chunk=8,
                   physical_pages=False)
    phys, eng_p = _run(cfg, m, params, wl, page_size=8, prefill_chunk=8)
    assert eng_p.physical_pages
    assert fingerprint(phys) == fingerprint(acct)


def test_physical_eager_prefill_differential(llama):
    """The bucketless (eager exact-length) prefill path — what MoE and
    the benchmark baseline run — commits through its own paged branch."""
    cfg, m, params = llama
    rng = np.random.default_rng(3)
    wl = _mk_workload(cfg, 5, rng)
    hp = HotpathConfig(prefill_buckets=False, multi_step=1)
    acct, _ = _run(cfg, m, params, wl, page_size=16, physical_pages=False,
                   hotpath=hp)
    phys, eng_p = _run(cfg, m, params, wl, page_size=16, hotpath=hp)
    assert eng_p.physical_pages
    assert fingerprint(phys) == fingerprint(acct)


def test_pool_drains_after_run(llama):
    """Admission capacity is physical now: when the workload drains, every
    page is back in the pool and the device tables are all-sentinel on
    the next refresh."""
    cfg, m, params = llama
    rng = np.random.default_rng(4)
    wl = _mk_workload(cfg, 5, rng)
    _, eng = _run(cfg, m, params, wl, page_size=8)
    assert eng.kv.pages_used == 0
    assert eng.kv.physical_pages_used == 0
    assert sorted(eng.kv.free_pages) == list(range(eng.kv.total_pages))
