"""Tolerance harness + wall-clock engine differential (ISSUE 9 tentpole).

Unit layer: compare_requests gate mechanics on synthetic populations
(identical pass, perturbed fail, token mismatch, missing rid, cancelled
skip). Integration layer: a real smoke-model ServingEngine run twice on
the same trace — virtual clock vs clock="wall" — must deliver identical
token text and pass the timing gates; plus cancel() semantics on both
clocks and the simulator.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, TPU_V5E, make_scheduler
from repro.core.request import Request, ReqState
from repro.models import Model
from repro.serving import (ServingEngine, ServingSimulator, SimConfig,
                           Tolerance, ToleranceSpec, compare_requests)

SPEC = QoESpec(ttft=1.0, tds=4.8)


def served(rid, arrival, emits, tokens, cancelled=False):
    r = Request(rid=rid, arrival=arrival, prompt_len=8, output_len=len(emits),
                spec=SPEC)
    r.emit_times = list(emits)
    r.output_tokens = list(tokens)
    r.generated = len(emits)
    r.state = ReqState.FINISHED
    r.cancelled = cancelled
    return r


def population(n=12, seed=0, skew=0.0, jitter=0.0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        arr = i * 0.2
        e = arr + 0.4 + np.arange(10) * 0.12
        e = e + skew + (rng.uniform(0, jitter, 10) if jitter else 0.0)
        toks = list(100 * i + np.arange(10))
        out.append(served(i, arr, e, toks))
    return out


# ---------------------------------------------------------------------------
# gate mechanics
# ---------------------------------------------------------------------------

def test_identical_populations_pass():
    ref = population()
    rep = compare_requests(ref, population())
    assert rep.ok and rep.n_pairs == 12
    assert not rep.token_mismatches and not rep.missing_rids
    assert "OK" in rep.summary()
    rep.assert_ok()


def test_small_jitter_passes_large_skew_fails():
    ref = population()
    assert compare_requests(ref, population(jitter=0.004)).ok
    rep = compare_requests(ref, population(skew=1.0))
    assert not rep.ok
    failed = {g.name for g in rep.gates if not g.passed}
    assert "ttft_mean_diff" in failed
    with pytest.raises(AssertionError, match="FAIL"):
        rep.assert_ok()


def test_token_mismatch_is_a_hard_gate():
    ref = population()
    cand = population()
    cand[3].output_tokens[5] = -999
    rep = compare_requests(ref, cand)
    assert rep.token_mismatches == [3] and not rep.ok
    # ...unless identity is explicitly waived
    waived = compare_requests(
        ref, cand, dataclasses.replace(ToleranceSpec(),
                                       require_token_identity=False))
    assert waived.ok


def test_length_mismatch_counts_unless_cancelled():
    ref = population()
    cand = population()
    cand[2].output_tokens = cand[2].output_tokens[:4]  # truncated, same text
    assert compare_requests(ref, cand).token_mismatches == [2]
    # a cancelled request legitimately has a shorter (prefix) output
    cand[2].cancelled = True
    rep = compare_requests(ref, cand)
    assert not rep.token_mismatches
    assert 2 in rep.skipped_rids and rep.n_pairs == 11


def test_missing_rid_fails():
    ref = population()
    rep = compare_requests(ref, ref[:-1])
    assert rep.missing_rids == [11] and not rep.ok


def test_tolerance_relative_part():
    t = Tolerance(abs_tol=0.1, rel_tol=0.1)
    assert t.ok(10.0, 10.9)          # 0.9 <= 0.1 + 1.0
    assert not t.ok(10.0, 11.2)
    assert t.ok(float("nan"), float("nan"))


# ---------------------------------------------------------------------------
# wall-clock engine differential (the new verification spine)
# ---------------------------------------------------------------------------

def _mk_engine(clock):
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 4 * 64, lat)
    return cfg, ServingEngine(m, params, sched, lat, num_slots=4,
                              max_seq=64, clock=clock)


def _trace(cfg, n=6, out_len=10, stagger=0.03, seed=2):
    rng = np.random.default_rng(seed)
    wl = []
    for i in range(n):
        plen = int(rng.integers(5, 16))
        wl.append(Request(rid=i, arrival=i * stagger, prompt_len=plen,
                          output_len=out_len, spec=SPEC,
                          prompt_tokens=rng.integers(0, cfg.vocab_size,
                                                     plen)))
    return wl


@pytest.mark.slow
def test_wall_vs_virtual_engine_tolerance():
    """The acceptance-criteria differential, in-process: same trace through
    a virtual and a wall engine; token text identical, timing within the
    (CI-generous) gates. Exercised per-PR over a real socket by the server
    smoke job; marked slow here because the wall run takes real seconds."""
    cfg, eng_v = _mk_engine("virtual")
    ref = eng_v.run(_trace(cfg), max_iterations=2000)
    cfg, eng_w = _mk_engine("wall")
    # warmup: jit compilation would otherwise land in the first requests'
    # wall TTFTs (run() resets serving state but keeps the compile caches —
    # exactly what a real server's warmup request does)
    eng_w.run(_trace(cfg, n=2, out_len=4), max_iterations=200)
    cand = eng_w.run(_trace(cfg), max_iterations=2000)
    # paced wall clock never runs ahead of schedule by construction, and a
    # smoke-model virtual run finishes in a few wall seconds
    spec = ToleranceSpec(
        ttft_mean_diff=Tolerance(abs_tol=0.5),
        ttft_p95_diff=Tolerance(abs_tol=1.0),
        ttft_max_diff=Tolerance(abs_tol=2.0),
        tds_mean_diff=Tolerance(abs_tol=2.0, rel_tol=0.5),
        qoe_mean_diff=Tolerance(abs_tol=0.30),
        qoe_max_diff=Tolerance(abs_tol=0.60),
        qoe_mean_of=Tolerance(abs_tol=0.30),
    )
    rep = compare_requests(ref, cand, spec)
    assert not rep.token_mismatches, rep.summary()
    assert not rep.missing_rids
    rep.assert_ok()
    # wall timestamps are real monotonic readings: never behind virtual's
    # deterministic schedule by more than scheduling noise, and the run's
    # makespan is real elapsed time (> 0)
    assert eng_w.result().makespan > 0


def test_wall_clock_pacing_unit():
    """_tick pacing invariant without a model: deadlines accumulate, and
    the clock never runs ahead of the schedule."""
    import time

    class Eng:
        _tick = ServingEngine._tick
        wall_now = ServingEngine.wall_now

        def __init__(self):
            self.clock = "wall"
            self.now = 0.0
            self._wall0 = time.monotonic()

    e = Eng()
    for _ in range(5):
        e._tick(0.01)
    assert e.now >= 0.05 - 1e-6          # paced: slept the modeled time
    assert e.wall_now() >= e.now - 1e-6
    v = Eng(); v.clock = "virtual"
    v._tick(0.25)
    assert v.now == 0.25 and v.wall_now() == 0.25


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def _sim():
    cfg = get_smoke_config("llama3-8b")
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", 256, lat)
    return ServingSimulator(sched, lat, SimConfig(kv_capacity_tokens=256))


def test_simulator_cancel_pending_live_finished():
    sim = _sim()
    reqs = [Request(rid=i, arrival=i * 10.0, prompt_len=8, output_len=20,
                    spec=SPEC) for i in range(3)]
    for r in reqs:
        sim.submit(r)
    # live cancel: step until rid 0 has a few tokens
    while reqs[0].generated < 3:
        sim.step()
    assert sim.cancel(0)
    assert reqs[0].cancelled and reqs[0].state == ReqState.FINISHED
    assert reqs[0].generated == 3
    # pending cancel: rid 2 hasn't arrived yet
    assert sim.cancel(2)
    assert reqs[2].cancelled and not reqs[2].emit_times
    # unknown + already-finished cancels are no-ops
    assert not sim.cancel(99)
    assert not sim.cancel(0)
    # the remaining request still completes
    while sim.step():
        pass
    assert reqs[1].generated == 20 and not reqs[1].cancelled


def test_engine_cancel_running(llama_engine=None):
    cfg, eng = _mk_engine("virtual")
    wl = _trace(cfg, n=3, out_len=30, stagger=0.0)
    for r in wl:
        eng.submit(r)
    while wl[0].generated < 4:
        eng.step()
    slots_before = eng.kv.slots_in_use
    assert eng.cancel(0)
    gen_at_cancel = wl[0].generated   # multi-step may batch several tokens
    assert wl[0].cancelled and wl[0].state == ReqState.FINISHED
    assert eng.kv.slots_in_use == slots_before - 1   # slot freed
    assert not eng.cancel(0)
    while eng.step():
        pass
    # survivors finish with full token counts; cancelled kept its prefix
    assert wl[0].generated == gen_at_cancel >= 4
    assert all(r.generated == 30 for r in wl[1:])


def test_engine_cancel_tokens_unchanged_for_survivors():
    """Cancelling one stream must not change any other stream's text
    (row independence — the same argument behind wall-clock identity)."""
    cfg, ref_eng = _mk_engine("virtual")
    ref = ref_eng.run(_trace(cfg, n=3, out_len=12, stagger=0.0),
                      max_iterations=1000)
    cfg, eng = _mk_engine("virtual")
    wl = _trace(cfg, n=3, out_len=12, stagger=0.0)
    for r in wl:
        eng.submit(r)
    while wl[1].generated < 2:
        eng.step()
    eng.cancel(1)
    while eng.step():
        pass
    ref_by = {r.rid: r for r in ref}
    for r in (wl[0], wl[2]):
        assert r.output_tokens == ref_by[r.rid].output_tokens
    assert wl[1].output_tokens == ref_by[1].output_tokens[:wl[1].generated]
