"""Pinned near-tie flip classification (PR 8, satellite of PR 5).

PR 5's hot-path benchmark documents that padded-bucket prefill can flip
a greedy token against exact-length prefill ONLY on logit near-ties
(last-ulp reduction-order differences). That claim is now a gate, owned
by `repro.serving.lossless`: every observed flip is re-priced by the
exact-length model and must hide behind a sub-``FLIP_TOL`` top-2 margin.
These tests craft both sides of the tolerance path:

  * a crafted near-tie (the zeroed output head makes every logit equal,
    margin exactly 0) driven through the REAL padded-vs-exact prefill
    pair — a flip there must classify as a documented ulp flip;
  * a forged mismatch at a decisively-argmaxed position — that must
    classify as real divergence and fail `all_flips_documented`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import QoESpec
from repro.models import Model
from repro.serving import Request
from repro.serving.lossless import (FLIP_TOL, all_flips_documented,
                                    audit_flips, classify_flip, exact_margin,
                                    fingerprint, first_divergence,
                                    timing_fingerprint)


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _zero_head(params):
    """A model that is maximally undecided: all logits identical, so the
    top-2 margin at every position is exactly 0 — the hardest near-tie."""
    return dict(params, lm_head=jax.tree.map(jnp.zeros_like,
                                             params["lm_head"]))


def _mk_req(rid, cfg, rng, plen=12, toks=()):
    r = Request(rid=rid, arrival=0.0, prompt_len=plen, output_len=len(toks),
                spec=QoESpec(ttft=1.0, tds=4.8),
                prompt_tokens=rng.integers(0, cfg.vocab_size, plen))
    r.output_tokens = list(toks)
    r.generated = len(toks)
    r.emit_times = [0.1 * (i + 1) for i in range(len(toks))]
    return r


# --------------------------------------------------------------------------
# the classifier itself
# --------------------------------------------------------------------------
def test_classify_flip_threshold():
    assert classify_flip(0.0) == "documented_ulp_flip"
    assert classify_flip(5e-3) == "documented_ulp_flip"
    assert classify_flip(FLIP_TOL) == "documented_ulp_flip"
    assert classify_flip(2e-2) == "real_divergence"
    assert classify_flip(1.0) == "real_divergence"


def test_first_divergence():
    assert first_divergence([1, 2, 3], [1, 2, 3]) is None
    assert first_divergence([1, 2, 3], [1, 9, 3]) == 1
    assert first_divergence([1, 2], [1, 2, 3]) == 2   # length mismatch
    assert first_divergence([], []) is None


# --------------------------------------------------------------------------
# padded-bucket vs exact-length prefill: the real numerics under test
# --------------------------------------------------------------------------
def test_padded_prefill_gaps_are_ulp_scale(llama):
    """The PR 5 docstring's factual claim, pinned: padded lengths-masked
    prefill differs from exact-length prefill only at last-ulp scale.
    FLIP_TOL is orders of magnitude above this — it budgets for the
    amplification of this seed noise through decode steps, not for the
    seed itself, so the direct gap is pinned at the tighter 1e-5."""
    cfg, m, params = llama
    rng = np.random.default_rng(0)
    plen, bucket = 13, 32
    toks = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    cache = m.init_cache(1, bucket + 1)
    exact, _ = m.prefill(params, {"tokens": jnp.asarray(toks[None, :])},
                         cache)
    padded_toks = np.zeros(bucket, np.int32)
    padded_toks[:plen] = toks
    padded, _ = m.prefill(
        params, {"tokens": jnp.asarray(padded_toks[None, :]),
                 "lengths": jnp.asarray([plen], jnp.int32)}, cache)
    gap = float(np.max(np.abs(np.asarray(exact) - np.asarray(padded))))
    assert gap <= 1e-5, (
        f"padded-vs-exact prefill logit gap {gap} exceeds the documented "
        f"ulp scale — the near-tie flip story no longer holds")


def test_crafted_near_tie_classifies_as_documented(llama):
    """The crafted near-tie case: with the zeroed output head every
    logit is equal, so the exact-path margin at any position is 0 — run
    the REAL padded and exact prefill paths, forge the flip their ulp
    noise could produce, and require the gate to classify it as a
    documented ulp flip (the tolerance path under test)."""
    cfg, m, params = llama
    zp = _zero_head(params)
    rng = np.random.default_rng(1)
    plen = 12
    toks = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    # both prefill paths really run on the degenerate head
    cache = m.init_cache(1, 33)
    exact_logits, _ = m.prefill(zp, {"tokens": jnp.asarray(toks[None, :])},
                                cache)
    padded_toks = np.zeros(32, np.int32)
    padded_toks[:plen] = toks
    padded_logits, _ = m.prefill(
        zp, {"tokens": jnp.asarray(padded_toks[None, :]),
             "lengths": jnp.asarray([plen], jnp.int32)}, cache)
    assert float(np.max(np.abs(np.asarray(exact_logits)))) == 0.0
    assert float(np.max(np.abs(np.asarray(padded_logits)))) == 0.0
    # the flip such a tie permits: two runs that disagree on token 0
    a = _mk_req(0, cfg, np.random.default_rng(2), plen=plen, toks=(3, 7))
    b = _mk_req(0, cfg, np.random.default_rng(2), plen=plen, toks=(5, 7))
    a.prompt_tokens = b.prompt_tokens = toks
    flips = audit_flips(m, zp, [a], [b])
    assert len(flips) == 1
    assert flips[0]["position"] == 0
    assert flips[0]["margin"] == 0.0
    assert flips[0]["classification"] == "documented_ulp_flip"
    assert all_flips_documented(flips)


def test_real_divergence_fails_the_gate(llama):
    """With the real (decided) head, a forged token mismatch sits behind
    a macroscopic argmax margin — the gate must call it real divergence,
    NOT wave it through as a near-tie."""
    cfg, m, params = llama
    rng = np.random.default_rng(3)
    plen = 12
    toks = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    margin = exact_margin(m, params, toks, ())
    assert margin > FLIP_TOL, "smoke model unexpectedly near-tied; reseed"
    a = _mk_req(0, cfg, rng, plen=plen, toks=(3, 7))
    b = _mk_req(0, cfg, rng, plen=plen, toks=(5, 7))
    a.prompt_tokens = b.prompt_tokens = toks
    flips = audit_flips(m, params, [a], [b])
    assert len(flips) == 1
    assert flips[0]["classification"] == "real_divergence"
    assert not all_flips_documented(flips)


def test_token_identical_runs_audit_clean(llama):
    cfg, m, params = llama
    rng = np.random.default_rng(4)
    a = _mk_req(0, cfg, rng, toks=(1, 2, 3))
    b = _mk_req(0, cfg, rng, toks=(1, 2, 3))
    b.prompt_tokens = a.prompt_tokens
    flips = audit_flips(m, params, [a], [b])
    assert flips == []
    assert all_flips_documented(flips)


def test_fingerprints_roundtrip(llama):
    cfg, m, params = llama
    rng = np.random.default_rng(5)
    out = [_mk_req(i, cfg, rng, toks=(1, 2)) for i in range(3)]
    assert fingerprint(out) == fingerprint(out)
    assert timing_fingerprint(out) == timing_fingerprint(out)
    out2 = [_mk_req(i, cfg, rng, toks=(1, 9)) for i in range(3)]
    for r, r2 in zip(out, out2):
        r2.prompt_tokens = r.prompt_tokens
    # token ids differ -> exact fingerprint differs, timing agrees
    assert fingerprint(out2) != fingerprint(out)
    assert timing_fingerprint(out2) == timing_fingerprint(out)
