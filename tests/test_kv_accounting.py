"""KV-accounting bugfix regressions (PR 8).

Two bugs, two pinned failure modes:

1. `can_allocate` used to charge `burst_reserve` ONCE per admission.
   The reserve models speculative verify growth — up to k+1 tokens per
   step — but EVERY resident can take that step simultaneously, so the
   headroom must scale with the resident count. The regression here
   builds the k=4 synchronized-verify-burst scenario in which the old
   formula admits a request whose admission makes simultaneous bursts
   overfill capacity; post-fix admission refuses it.

2. `drop()` used to silently discard parked host slices: a swapped-out
   request that was then shed (or preempted again by recompute) vanished
   from the ledger with no counter movement, while `swap_out` counted
   its bytes in. Now drops are first-class: `drops_total` /
   `dropped_bytes_total` in `occupancy()` and the kv_* gauges, aligned
   with `swaps_out_total` — over both preemption modes.
"""
import numpy as np
import pytest

from repro.core import QoESpec
from repro.serving import KVSlotManager, Request


def mk_req(rid, ctx, out_len=8):
    return Request(rid=rid, arrival=0.0, prompt_len=ctx, output_len=out_len,
                   spec=QoESpec(ttft=1.0, tds=4.8))


# --------------------------------------------------------------------------
# bugfix 1: burst reserve must scale with the resident count
# --------------------------------------------------------------------------
class TestBurstReserve:
    K = 4                       # speculative depth: verify grows <= k+1
    RESERVE = K + 1             # per-request worst-case growth per step

    def test_reserve_scales_with_residents(self):
        """The k=4 synchronized-burst scenario. 3 residents at 20 tokens
        each, capacity 85: the old once-per-admission check (60 + 20 + 5
        = 85 <= 85) would admit a fourth 20-token request — after which
        ONE synchronized verify burst (+5 tokens x 4 residents) needs
        100 > 85 tokens. Post-fix the reserve is charged per resident
        (60 + 20 + 5*4 = 100 > 85) and admission refuses."""
        kv = KVSlotManager(num_slots=4, max_seq=64, capacity_tokens=85,
                           burst_reserve=self.RESERVE)
        residents = [mk_req(i, 20) for i in range(3)]
        for r in residents:
            assert kv.can_allocate(r)
            kv.allocate(r)
        assert kv.tokens_used == 60
        candidate = mk_req(3, 20)
        # THE regression assertion: fails pre-fix (old formula admits)
        assert not kv.can_allocate(candidate)

    def test_overfill_demonstration(self):
        """What admission-by-the-old-formula leads to: force-allocate the
        fourth request anyway and let every resident take one verify
        burst — capacity is overfilled. This is the harm the per-resident
        reserve exists to prevent (the ledger tolerates the overdraft;
        admission must not create it)."""
        kv = KVSlotManager(num_slots=4, max_seq=64, capacity_tokens=85,
                           burst_reserve=self.RESERVE)
        reqs = [mk_req(i, 20) for i in range(4)]
        for r in reqs:
            kv.allocate(r)          # bypasses can_allocate, as the old bug did
        for r in reqs:              # one synchronized verify burst at k=4
            kv.grow(r, self.RESERVE)
        assert kv.tokens_used == 100 > kv.capacity_tokens

    def test_reserve_headroom_is_sufficient(self):
        """Admission the fixed check allows really does survive a
        synchronized burst: capacity 100 admits the fourth request, and
        the worst-case burst lands exactly at capacity."""
        kv = KVSlotManager(num_slots=4, max_seq=64, capacity_tokens=100,
                           burst_reserve=self.RESERVE)
        reqs = [mk_req(i, 20) for i in range(4)]
        for r in reqs[:3]:
            kv.allocate(r)
        assert kv.can_allocate(reqs[3])
        kv.allocate(reqs[3])
        for r in reqs:
            kv.grow(r, self.RESERVE)
        assert kv.tokens_used <= kv.capacity_tokens

    def test_zero_reserve_unchanged(self):
        """burst_reserve=0 (every non-speculative engine) is untouched by
        the fix: admission is the plain token check."""
        kv = KVSlotManager(num_slots=4, max_seq=64, capacity_tokens=60)
        for i in range(2):
            kv.allocate(mk_req(i, 20))
        assert kv.can_allocate(mk_req(2, 20))
        assert not kv.can_allocate(mk_req(3, 21))

    def test_reserve_counts_candidate_in_paged_pool(self):
        """The paged admission check prices need+reserve in pages with
        the same per-resident scaling."""
        kv = KVSlotManager(num_slots=4, max_seq=64, capacity_tokens=85,
                           burst_reserve=self.RESERVE, page_size=5)
        for i in range(3):
            kv.allocate(mk_req(i, 20))
        assert not kv.can_allocate(mk_req(3, 20))


# --------------------------------------------------------------------------
# bugfix 2: drop() accounts for discarded parked slices
# --------------------------------------------------------------------------
def _host_slice(n_bytes):
    return {"k": np.zeros(n_bytes, np.uint8)}


class TestDropAccounting:
    def test_drop_of_parked_request_counts_bytes(self):
        """swap mode then shed: the parked slice's bytes were counted in
        by swap_out; the discard must show up in dropped_bytes_total —
        pre-fix this silently vanished (fails pre-fix: the counters did
        not exist)."""
        kv = KVSlotManager(num_slots=2, max_seq=32, capacity_tokens=64)
        r = mk_req(0, 10)
        kv.allocate(r)
        kv.swap_out(r, _host_slice(1024))
        assert kv.swaps_out_total == 1
        assert kv.swap_bytes_total == 1024
        kv.drop(r)                        # shed while parked
        assert kv.drops_total == 1
        assert kv.dropped_bytes_total == 1024
        assert len(kv.host_store) == 0

    def test_drop_of_resident_recompute_mode(self):
        """recompute mode: nothing is parked, so a drop frees slot and
        pages and counts the event with zero discarded bytes."""
        kv = KVSlotManager(num_slots=2, max_seq=32, capacity_tokens=64,
                           page_size=8)
        r = mk_req(0, 10)
        kv.allocate(r)
        assert kv.pages_used == 2
        kv.drop(r)
        assert kv.drops_total == 1
        assert kv.dropped_bytes_total == 0
        assert kv.pages_used == 0
        assert kv.slots_in_use == 0

    def test_draft_slice_counted(self):
        """A speculative request's parked draft slice rides along: its
        bytes count in on swap_out and out on drop."""
        kv = KVSlotManager(num_slots=2, max_seq=32, capacity_tokens=64)
        r = mk_req(0, 10)
        kv.allocate(r)
        kv.swap_out(r, _host_slice(1000), draft_slice=_host_slice(500))
        assert kv.swap_bytes_total == 1500
        kv.drop(r)
        assert kv.dropped_bytes_total == 1500
        assert len(kv.draft_store) == 0

    def test_occupancy_exposes_both_mode_counters(self):
        """occupancy() — the gauge source — carries the swap and drop
        ledgers side by side (fails pre-fix: keys absent)."""
        kv = KVSlotManager(num_slots=2, max_seq=32, capacity_tokens=64)
        occ = kv.occupancy()
        for key in ("swaps_out_total", "drops_total",
                    "dropped_bytes_total", "swap_bytes_total"):
            assert key in occ
        r0, r1 = mk_req(0, 8), mk_req(1, 8)
        kv.allocate(r0)
        kv.allocate(r1)
        kv.swap_out(r0, _host_slice(64))      # swap-mode preemption
        kv.drop(r1)                           # recompute-mode preemption
        kv.drop(r0)                           # shed of the parked one
        occ = kv.occupancy()
        assert occ["swaps_out_total"] == 1
        assert occ["drops_total"] == 2
        assert occ["dropped_bytes_total"] == 64

    def test_reset_clears_ledgers(self):
        kv = KVSlotManager(num_slots=2, max_seq=32, capacity_tokens=64)
        r = mk_req(0, 8)
        kv.allocate(r)
        kv.swap_out(r, _host_slice(64))
        kv.drop(r)
        kv.reset()
        occ = kv.occupancy()
        assert occ["swaps_out_total"] == 0
        assert occ["drops_total"] == 0
        assert occ["dropped_bytes_total"] == 0
        assert occ["swap_bytes_total"] == 0


# --------------------------------------------------------------------------
# engine integration: both preemption modes move the right counters
# --------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("mode", ["swap", "recompute"])
def test_engine_preemption_moves_mode_counters(mode):
    import jax

    from repro.configs import get_smoke_config
    from repro.core import LatencyModel, SchedulerConfig, TPU_V5E, make_scheduler
    from repro.models import Model
    from repro.serving import ServingEngine

    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(1)
    wl = []
    for i in range(8):
        plen = int(rng.integers(5, 20))
        wl.append(Request(
            rid=i, arrival=i * 0.01, prompt_len=plen, output_len=15,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen)))
    sched = make_scheduler("andes", 100, lat, SchedulerConfig(delta_t=5.0))
    eng = ServingEngine(m, params, sched, lat, num_slots=2, max_seq=64,
                        capacity_tokens=100, preemption_mode=mode)
    eng.run(wl, max_iterations=2000)
    assert eng.preemptions > 0, "test requires contention"
    occ = eng.kv.occupancy()
    if mode == "swap":
        assert occ["swaps_out_total"] == eng.preemptions
        assert occ["swap_bytes_total"] > 0
        assert occ["drops_total"] == 0
    else:
        assert occ["drops_total"] == eng.preemptions
        assert occ["dropped_bytes_total"] == 0    # nothing was parked
        assert occ["swaps_out_total"] == 0
