"""Roofline-derived latency model (Appendix B/D adaptation)."""
import pytest

from repro.configs import get_config
from repro.core import A100_4X, LatencyModel, TPU_V5E_POD


CFG = get_config("opt-66b")


def test_latency_linear_in_batch():
    """Paper Appendix B: iteration latency ~ a + b*B (memory-bound slope)."""
    lat = LatencyModel(CFG, A100_4X)
    l1 = lat.iter_latency(10, 10 * 500)
    l2 = lat.iter_latency(110, 110 * 500)
    l3 = lat.iter_latency(210, 210 * 500)
    assert l2 > l1 and l3 > l2
    slope1 = (l2 - l1) / 100
    slope2 = (l3 - l2) / 100
    assert slope1 == pytest.approx(slope2, rel=0.05)


def test_generation_speed_matches_paper():
    """Fig 3b: ~6.6-9 tok/s per request at operating batch on 4xA100."""
    lat = LatencyModel(CFG, A100_4X)
    rate = lat.token_rate(100, 100 * 550)
    assert 5.0 < rate < 10.0


def test_decode_memory_bound_prefill_compute_bound():
    lat = LatencyModel(CFG, A100_4X)
    # decode: memory term dominates
    b = 50
    flops_t = 2 * CFG.param_count() * b / lat._agg_flops
    mem_t = lat.param_bytes / lat._agg_bw
    assert mem_t > flops_t
    # prefill at long prompts: compute term dominates
    p = 2048
    flops_p = 2 * CFG.param_count() * p / lat._agg_flops
    assert flops_p > mem_t


def test_swap_cheaper_than_recompute_for_long_ctx():
    """Appendix D: swap ~ one iteration; recompute grows with context."""
    lat = LatencyModel(CFG, A100_4X)
    assert lat.swap_latency(500) < lat.recompute_latency(2000)


def test_max_batch_from_latency_monotone():
    lat = LatencyModel(CFG, A100_4X)
    b_fast = lat.max_batch_from_latency(1 / 8.0)    # stringent TDS
    b_slow = lat.max_batch_from_latency(1 / 3.0)    # lenient TDS
    assert b_slow >= b_fast >= 1


def test_ssm_state_weight():
    mamba = get_config("falcon-mamba-7b")
    assert mamba.kv_bytes_per_token() == 0
    assert mamba.ssm_state_bytes() > 0
    lat = LatencyModel(mamba, TPU_V5E_POD)
    # context length barely affects SSM decode latency
    l_small = lat.iter_latency(32, 32 * 100)
    l_big = lat.iter_latency(32, 32 * 100_000)
    assert l_big == pytest.approx(l_small, rel=1e-6)
