"""Speculative decoding: the lossless differential harness.

The whole feature is pinned by one gate: for every trace and config, the
speculative engine's per-request emitted token IDs are *identical* to the
baseline engine's (greedy verification is lossless by construction), while
decode steps shrink whenever proposals are accepted. The gate rests on a
foundation asserted first: `Model.verify_step` (one fused window) is
bit-identical to sequential `decode_step` calls — if an XLA version ever
breaks that identity, the foundation test fails before the differentials
get a chance to flake.

Draft regimes exercised:
  exact     draft params == target params  -> 100% acceptance (upper bound)
  perturbed target params + 1e-3 noise     -> partial, context-dependent
                                              acceptance (the real regime)
  foreign   independently-initialized tiny model, same vocab -> ~0%
            acceptance (adversarial draft; losslessness must still hold)

The llama3-8b smoke config is used because its *untied* embeddings make
random-init greedy chains wander through the vocab (tied embeddings
collapse to a fixed-point token, which would make every draft trivially
agree and the differential vacuous).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.configs import get_smoke_config
from repro.core import (
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    SpeculativeLatencyModel,
    TPU_V5E,
    make_scheduler,
)
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.serving.speculative import check_speculation_compatible


_CACHE = {}


def _target():
    # module-level cache rather than a fixture: the hypothesis-compat
    # @given wrapper cannot take pytest fixtures as arguments
    if "target" not in _CACHE:
        cfg = get_smoke_config("llama3-8b")
        m = Model(cfg)
        _CACHE["target"] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _CACHE["target"]


def _drafts():
    """name -> (draft_model, draft_params); all share the target's vocab."""
    if "drafts" not in _CACHE:
        cfg, m, params = _target()
        perturbed = jax.tree.map(
            lambda a: a + 1e-3 * jax.random.normal(
                jax.random.PRNGKey(9), a.shape, a.dtype), params
        )
        small_cfg = dataclasses.replace(
            cfg, name="llama3-8b-smoke-draft", num_layers=1, d_model=128,
            num_heads=4, num_kv_heads=2, d_ff=256,
        )
        small = Model(small_cfg)
        _CACHE["drafts"] = {
            "exact": (m, params),
            "perturbed": (m, perturbed),
            "foreign": (small, small.init(jax.random.PRNGKey(7))),
        }
    return _CACHE["drafts"]


@pytest.fixture(scope="module")
def target():
    return _target()


@pytest.fixture(scope="module")
def drafts():
    return _drafts()


def mk_wl(cfg, rng, n, out_len=10, stagger=0.05, plen_lo=5, plen_hi=20):
    wl = []
    for i in range(n):
        plen = int(rng.integers(plen_lo, plen_hi))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen,
            output_len=out_len, spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    return wl


def mk_baseline(target, sched="fcfs", cap=10_000, num_slots=4, max_seq=64,
                sched_cfg=None, **kw):
    cfg, m, params = target
    lat = LatencyModel(cfg, TPU_V5E)
    return ServingEngine(
        m, params, make_scheduler(sched, cap, lat, sched_cfg), lat,
        num_slots=num_slots, max_seq=max_seq, **kw,
    )


def mk_spec(target, draft, k, sched="fcfs", cap=10_000, num_slots=4,
            max_seq=64, sched_cfg=None, **kw):
    cfg, m, params = target
    dm, dparams = draft
    slat = SpeculativeLatencyModel(cfg, TPU_V5E, dm.cfg, k=k)
    return ServingEngine(
        m, params, make_scheduler(sched, cap, slat, sched_cfg), slat,
        num_slots=num_slots, max_seq=max_seq,
        draft_model=dm, draft_params=dparams, spec_k=k, **kw,
    )


def assert_tokens_identical(wl_a, wl_b):
    for a, b in zip(wl_a, wl_b):
        assert a.output_tokens == b.output_tokens, (
            f"rid {a.rid}: {a.output_tokens} != {b.output_tokens}"
        )
        assert a.generated >= a.output_len


# ---------------------------------------------------------------------------
# Foundation: fused verify == sequential decode, bit for bit
# ---------------------------------------------------------------------------

def test_verify_step_bitwise_matches_sequential_decode(target):
    cfg, m, params = target
    rng = np.random.default_rng(3)
    B, S, T = 3, 64, 4
    cache = m.init_cache(B, S, dtype=jnp.float32)
    prompt = rng.integers(0, cfg.vocab_size, (B, 12))
    _, cache = m.prefill(params, {"tokens": jnp.asarray(prompt)}, cache)
    window = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)

    fused_logits, fused_cache = jax.jit(m.verify_step)(params, window, cache)

    step = jax.jit(m.decode_step)
    seq_cache = cache
    seq_logits = []
    for j in range(T):
        lg, seq_cache = step(params, window[:, j], seq_cache)
        seq_logits.append(lg)
    seq_logits = jnp.stack(seq_logits, axis=1)

    np.testing.assert_array_equal(np.asarray(fused_logits),
                                  np.asarray(seq_logits))
    for a, b in zip(jax.tree.leaves(fused_cache), jax.tree.leaves(seq_cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_speculation_rejects_unsupported(target):
    cfg, m, _ = target
    ssm = Model(get_smoke_config("falcon-mamba-7b"))
    with pytest.raises(ValueError, match="dense"):
        check_speculation_compatible(m, ssm)
    other_vocab = Model(dataclasses.replace(cfg, vocab_size=cfg.vocab_size * 2))
    with pytest.raises(ValueError, match="vocab"):
        check_speculation_compatible(m, other_vocab)


# ---------------------------------------------------------------------------
# The lossless differential gate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("draft_name", ["exact", "perturbed", "foreign"])
def test_spec_engine_matches_baseline_tokens(target, drafts, draft_name):
    cfg, _, _ = target
    rng = np.random.default_rng(1)
    base_wl = mk_wl(cfg, rng, 4, out_len=10)
    spec_wl = [r.clone() for r in base_wl]

    base = mk_baseline(target)
    base.run(base_wl, max_iterations=500)
    spec = mk_spec(target, drafts[draft_name], k=3)
    spec.run(spec_wl, max_iterations=500)

    assert_tokens_identical(base_wl, spec_wl)
    stats = spec.spec_stats()
    # steps never increase; strictly fewer whenever anything was accepted
    assert spec.iterations <= base.iterations
    if stats["accepted"] > 0:
        assert spec.iterations < base.iterations


def test_draft_equals_target_is_full_acceptance(target, drafts):
    """The degenerate draft==target case: every proposal verifies, so each
    step commits exactly k+1 tokens (modulo end-of-request truncation) and
    the step count collapses by ~(k+1)x vs the PR 2 stepped oracle."""
    cfg, _, _ = target
    k = 3
    rng = np.random.default_rng(2)
    base_wl = mk_wl(cfg, rng, 3, out_len=12, stagger=0.0)
    spec_wl = [r.clone() for r in base_wl]

    base = mk_baseline(target)
    base.run(base_wl, max_iterations=500)
    spec = mk_spec(target, drafts["exact"], k=k)
    spec.run(spec_wl, max_iterations=500)

    assert_tokens_identical(base_wl, spec_wl)
    stats = spec.spec_stats()
    assert stats["acceptance_rate"] == 1.0
    assert spec.iterations < base.iterations
    # 12 tokens = 1 at prefill + 11 decoded; at k+1=4/step that is 3 steps
    decode_steps = [int(np.ceil((r.output_len - 1) / (k + 1)))
                    for r in spec_wl]
    assert spec.iterations == max(decode_steps)


def test_spec_k0_reduces_to_stepped_oracle(target):
    """k=0 disables speculation entirely: the engine must be the PR 2
    stepped engine bit-for-bit (emission timestamps and QoE included)."""
    cfg, _, _ = target
    rng = np.random.default_rng(4)
    base_wl = mk_wl(cfg, rng, 3, out_len=8)
    k0_wl = [r.clone() for r in base_wl]

    base = mk_baseline(target)
    base.run(base_wl, max_iterations=500)
    k0 = mk_baseline(target, spec_k=0)
    k0.run(k0_wl, max_iterations=500)

    for a, b in zip(base_wl, k0_wl):
        assert a.output_tokens == b.output_tokens
        assert a.emit_times == b.emit_times
        assert a.final_qoe() == b.final_qoe()
    assert base.iterations == k0.iterations
    assert base.now == k0.now


@given(st.integers(1, 4), st.integers(0, 10_000), st.integers(6, 14))
@settings(max_examples=5, deadline=None)
@pytest.mark.slow
def test_spec_lossless_property(k, seed, out_len):
    """Property form of the gate: any k, any trace, any draft regime —
    token streams identical, steps never more."""
    target = _target()
    cfg, _, _ = target
    rng = np.random.default_rng(seed)
    draft = _drafts()[("exact", "perturbed", "foreign")[seed % 3]]
    base_wl = mk_wl(cfg, rng, 3, out_len=out_len,
                    stagger=float(rng.uniform(0.0, 0.2)))
    spec_wl = [r.clone() for r in base_wl]

    base = mk_baseline(target)
    base.run(base_wl, max_iterations=500)
    spec = mk_spec(target, draft, k=k)
    spec.run(spec_wl, max_iterations=500)

    assert_tokens_identical(base_wl, spec_wl)
    assert spec.iterations <= base.iterations
    if spec.spec_stats()["accepted"] > 0:
        assert spec.iterations < base.iterations


def test_spec_rerun_is_reproducible(target, drafts):
    """run() promises reset-to-fresh semantics; the acceptance EMA lives
    in the SpeculativeLatencyModel (shared with the scheduler), so reset()
    must restore it to its prior — otherwise a second run() on the same
    engine clocks (and therefore schedules) differently than the first."""
    cfg, _, _ = target
    rng = np.random.default_rng(14)
    proto = mk_wl(cfg, rng, 3, out_len=10)
    spec = mk_spec(target, drafts["perturbed"], k=3)

    runs = []
    for _ in range(2):
        wl = [r.clone() for r in proto]
        spec.run(wl, max_iterations=500)
        runs.append(([r.output_tokens for r in wl],
                     [r.emit_times for r in wl], spec.now))
    assert runs[0] == runs[1]


def test_spec_lossless_at_max_seq_boundary(target, drafts):
    """Requests whose context walks right up to max_seq: verify windows
    cross the boundary on the final steps, where the engine's padded
    physical cache (max_seq + k + 1) must keep every window write
    unclamped and the m_safe cap must stop emission exactly at the
    logical max_seq — token identity with the baseline throughout."""
    cfg, _, _ = target
    max_seq = 48
    for draft_name, k in (("exact", 3), ("perturbed", 4)):
        rng = np.random.default_rng(13)
        proto = []
        for i, plen in enumerate((max_seq - 14, max_seq - 15)):
            proto.append(Request(
                rid=i, arrival=0.0, prompt_len=plen, output_len=14,
                spec=QoESpec(ttft=1.0, tds=4.8),
                prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
            ))
        base_wl = [r.clone() for r in proto]
        base = mk_baseline(target, max_seq=max_seq)
        base.run(base_wl, max_iterations=200)
        spec_wl = [r.clone() for r in proto]
        spec = mk_spec(target, drafts[draft_name], k=k, max_seq=max_seq)
        spec.run(spec_wl, max_iterations=200)
        assert_tokens_identical(base_wl, spec_wl)
        for r in spec_wl:
            assert r.prompt_len + r.generated <= max_seq


# ---------------------------------------------------------------------------
# Memory pressure: losslessness must survive preemption
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_spec_preemption_pressure_token_identity(target, drafts):
    """Andes scheduler + tiny KV budget: requests get preempted (and with
    them, their draft caches) mid-stream; the committed token streams must
    still equal an uncontended baseline run's.

    Swap mode only: swap restores bit-identical cache slices, so token
    identity through arbitrary organic preemption is a hard guarantee.
    Recompute rebuilds the cache in prefill layout (no position gap), whose
    logits can legitimately flip near-tie argmaxes vs the stepwise layout —
    a pre-existing engine property, independent of speculation; the
    recompute differential therefore pins spec against a non-spec engine
    preempted at the *same* point instead
    (test_engine_preemption.py::test_spec_recompute_matches_nonspec_recompute).
    """
    mode = "swap"
    cfg, _, _ = target
    rng = np.random.default_rng(5)
    wl_proto = mk_wl(cfg, rng, 8, out_len=15, stagger=0.01)

    base_wl = [r.clone() for r in wl_proto]
    base = mk_baseline(target, num_slots=8)
    base.run(base_wl, max_iterations=2000)

    spec_wl = [r.clone() for r in wl_proto]
    spec = mk_spec(target, drafts["perturbed"], k=2, sched="andes",
                   cap=100, num_slots=2,
                   sched_cfg=SchedulerConfig(delta_t=5.0),
                   capacity_tokens=100, preemption_mode=mode)
    spec.run(spec_wl, max_iterations=4000)

    assert spec.preemptions > 0, "test requires contention"
    assert_tokens_identical(base_wl, spec_wl)
    # everything released on drain, draft parking included
    assert spec.kv.tokens_used == 0
    assert not spec.kv.host_store and not spec.kv.draft_store


# ---------------------------------------------------------------------------
# Fleet: speculative replicas in the cluster layer
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cluster_speculative_and_mixed_fleet(target, drafts):
    """A 2-replica fleet of speculative engines — and a mixed spec/non-spec
    fleet — serve one trace; every request's token stream matches the bare
    baseline engine's (weights are shared, so placement cannot change
    tokens), and the spec fleet does it in fewer engine steps."""
    from repro.cluster import (
        ClusterConfig, ClusterSimulator, engine_backend, mixed_backends,
        speculative_backend,
    )

    cfg, m, params = target
    dm, dparams = drafts["perturbed"]
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(6)
    wl_proto = mk_wl(cfg, rng, 8, out_len=8, stagger=0.1)

    ref_wl = [r.clone() for r in wl_proto]
    ref = mk_baseline(target, num_slots=8)
    ref.run(ref_wl, max_iterations=2000)
    ref_tokens = {r.rid: r.output_tokens for r in ref_wl}

    spec_factory = speculative_backend(
        m, params, dm, dparams, spec_k=2, num_slots=4, max_seq=64,
        capacity_tokens=200,
    )
    plain_factory = engine_backend(
        m, params, num_slots=4, max_seq=64, capacity_tokens=200,
    )
    for factory in (spec_factory,
                    mixed_backends([spec_factory, plain_factory])):
        res = ClusterSimulator(lat, ClusterConfig(
            n_replicas=2, router="round_robin", kv_capacity_tokens=200,
            backend_factory=factory,
        )).run([r.clone() for r in wl_proto])
        assert len(res.admitted) == len(wl_proto)
        for r in res.admitted:
            assert r.generated >= r.output_len
            assert r.output_tokens == ref_tokens[r.rid], r.rid
