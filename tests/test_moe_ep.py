"""shard_map expert-parallel MoE dispatch == GSPMD reference (bit-exact).

Runs in a subprocess with faked host devices (same pattern as
test_distributed.py; this process is pinned to 1 device by conftest).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
import repro.models.moe as moe
from repro.distributed.moe_ep import moe_apply_ep
from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.launch.mesh import make_debug_mesh

moe.CAPACITY_FACTOR = 8.0   # no-drop regime: outputs must match exactly
failures = []

# divisible experts (4 experts, tp=4)
cfg = get_smoke_config("qwen2-moe-a2.7b")
mesh = make_debug_mesh(4, 4)
p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.3
y_ref, aux_ref = moe.moe_apply(p, x, cfg)
y_ep, aux_ep = jax.jit(lambda p_, x_: moe_apply_ep(p_, x_, cfg, mesh))(p, x)
if float(jnp.max(jnp.abs(y_ep - y_ref))) > 1e-5:
    failures.append("divisible")
if abs(float(aux_ep - aux_ref)) > 1e-5:
    failures.append("aux")

# padded experts (6 experts, tp=8) + ragged valid mask
cfg2 = ModelConfig(name="padtest", kind="moe", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=4, d_ff=32, vocab_size=128,
                   moe=MoEConfig(num_experts=6, num_shared_experts=1,
                                 top_k=2, d_expert=32))
mesh2 = make_debug_mesh(2, 8)
p2 = moe.init_moe(jax.random.PRNGKey(2), cfg2, jnp.float32)
x2 = jax.random.normal(jax.random.PRNGKey(3), (4, 8, cfg2.d_model)) * 0.3
valid = jnp.arange(8)[None] < jnp.array([8, 4, 8, 2])[:, None]
y_ref2, _ = moe.moe_apply(p2, x2, cfg2, valid=valid)
y_ep2, _ = jax.jit(
    lambda p_, x_, v_: moe_apply_ep(p_, x_, cfg2, mesh2, valid=v_)
)(p2, x2, valid)
if float(jnp.max(jnp.abs(y_ep2 - y_ref2))) > 1e-5:
    failures.append("padded+masked")

# gradients: EP must differentiate like the reference (train path, iter 4)
def loss_ref(pp):
    y, aux = moe.moe_apply(pp, x2, cfg2)
    return jnp.sum(y ** 2) + aux
def loss_ep(pp):
    y, aux = moe_apply_ep(pp, x2, cfg2, mesh2)
    return jnp.sum(y ** 2) + aux
g_ref = jax.grad(loss_ref)(p2)
g_ep = jax.jit(jax.grad(loss_ep))(p2)
for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_ep)):
    if float(jnp.max(jnp.abs(a - b))) > 1e-4:
        failures.append("grad")
        break

print("FAILURES:" + ",".join(failures) if failures else "OK")
"""


@pytest.mark.slow
def test_moe_ep_matches_reference():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.strip().splitlines()[-1] == "OK", out.stdout
