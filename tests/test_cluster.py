"""Cluster layer: router policies, admission control, autoscaling, and the
1-replica bit-for-bit invariance with the single-node simulator."""
import copy

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    A40_4X,
    A100_4X,
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    fleet_avg_qoe,
    fleet_min_qoe,
    fleet_slo_attainment,
    make_scheduler,
    predict_request_qoe,
)
from repro.core.request import Request
from repro.cluster import (
    AdmissionConfig,
    AutoscalerConfig,
    ClusterConfig,
    ClusterSimulator,
    JSQRouter,
    QoEAwareRouter,
    Replica,
    RoundRobinRouter,
    marginal_qoe_gain,
)
from repro.cluster.router import (
    RouterConfig,
    capability,
    normalized_queue,
    shared_token_rate,
)
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import (
    DEFAULT_TENANTS,
    make_multitenant_workload,
    make_workload,
)

CFG = get_config("opt-66b")
LAT = LatencyModel(CFG, A100_4X)
LAT_SLOW = LatencyModel(CFG, A40_4X)
M = 65_000


def make_replica(rid, lat=LAT, kv=M, scheduler="andes"):
    sched = make_scheduler(scheduler, kv, lat, SchedulerConfig())
    sim = ServingSimulator(sched, lat, SimConfig(kv_capacity_tokens=kv))
    return Replica(rid, sim, lat)


def req(rid, arrival=0.0, prompt=200, out=200, tds=4.8, ttft=1.0, tenant=0):
    return Request(rid=rid, arrival=arrival, prompt_len=prompt,
                   output_len=out, spec=QoESpec(ttft=ttft, tds=tds),
                   tenant=tenant)


# ---------------------------------------------------------------------------
# 1-replica invariance: the cluster layer must not perturb the engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["andes", "fcfs"])
@pytest.mark.parametrize("router", ["round_robin", "qoe"])
def test_one_replica_cluster_matches_single_node(scheduler, router):
    wl = make_workload(120, 3.0, seed=7, arrival="gamma")
    single = ServingSimulator(
        make_scheduler(scheduler, M, LAT, SchedulerConfig()), LAT,
        SimConfig(kv_capacity_tokens=M),
    ).run(copy.deepcopy(wl))
    cluster = ClusterSimulator(LAT, ClusterConfig(
        n_replicas=1, router=router, scheduler=scheduler,
        kv_capacity_tokens=M,
    )).run(copy.deepcopy(wl))

    assert len(cluster.shed) == 0
    s = {r.rid: r for r in single.requests}
    c = {r.rid: r for r in cluster.admitted}
    assert set(s) == set(c)
    for rid in s:
        # bit-for-bit: identical token emission timelines
        assert s[rid].emit_times == c[rid].emit_times
        assert s[rid].preemptions == c[rid].preemptions


# ---------------------------------------------------------------------------
# Router units
# ---------------------------------------------------------------------------

def test_round_robin_cycles():
    reps = [make_replica(i) for i in range(3)]
    router = RoundRobinRouter()
    picks = [router.route(req(i), reps, 0.0).replica.id for i in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_jsq_ties_break_to_lowest_id():
    reps = [make_replica(i) for i in range(3)]
    assert JSQRouter().route(req(0), reps, 0.0).replica.id == 0


def test_jsq_prefers_shortest_committed_queue():
    reps = [make_replica(i) for i in range(2)]
    reps[0].submit(req(0))
    assert JSQRouter().route(req(1), reps, 0.0).replica.id == 1


def test_qoe_router_ties_break_to_lowest_id():
    reps = [make_replica(i) for i in range(3)]
    assert QoEAwareRouter().route(req(0), reps, 0.0).replica.id == 0


def test_qoe_router_memory_aware_placement():
    """A KV-overcommitted replica loses decisively to an idle one."""
    reps = [make_replica(0, kv=8_000), make_replica(1, kv=8_000)]
    for i in range(40):                     # ~32k prompt tokens >> 8k KV
        reps[0].submit(req(i, prompt=800))
    decision = QoEAwareRouter().route(req(99), reps, 0.0)
    assert decision.replica.id == 1
    assert decision.scores[0] < decision.scores[1]


def test_qoe_router_capability_aware_on_heterogeneous_fleet():
    """Equal queue depth, unequal hardware: route to the faster replica.
    Count-based JSQ cannot distinguish these."""
    fast, slow = make_replica(0, lat=LAT), make_replica(1, lat=LAT_SLOW)
    assert capability(fast) > capability(slow)
    for i in range(6):
        fast.submit(req(i))
        slow.submit(req(10 + i))
    assert normalized_queue(slow) > normalized_queue(fast)
    assert QoEAwareRouter().route(req(99), [fast, slow], 0.0).replica.id == 0
    # JSQ sees identical queues and just takes the lowest id
    assert JSQRouter().route(req(99), [fast, slow], 0.0).replica.id == 0


def test_marginal_gain_idle_vs_saturated():
    idle = make_replica(0, kv=8_000)
    full = make_replica(1, kv=8_000)
    for i in range(60):
        full.submit(req(i, prompt=800))
    cfg = RouterConfig()
    g_idle = marginal_qoe_gain(idle, req(99), 0.0, cfg)
    g_full = marginal_qoe_gain(full, req(99), 0.0, cfg)
    assert g_idle == pytest.approx(1.0, abs=0.05)
    assert g_full < g_idle - 0.5


def test_shared_token_rate_memory_cap():
    # doubling live requests beyond the memory cap halves the shared rate
    r_fit = shared_token_rate(LAT, 10, 10 * 400, kv_capacity=100_000)
    r_over = shared_token_rate(LAT, 100, 100 * 400, kv_capacity=10_000)
    assert r_over < r_fit
    # idle
    assert shared_token_rate(LAT, 0, 0, 10_000) == 0.0


def test_router_does_not_mutate_replica_fluid_state():
    rep = make_replica(0)
    rep.submit(req(0))
    for _ in range(5):
        rep.step()
    before = {f: getattr(rep.fluid, f).copy() for f in rep.fluid.FIELDS}
    QoEAwareRouter().route(req(1, arrival=rep.clock), [rep], rep.clock)
    for f, arr in before.items():
        np.testing.assert_array_equal(arr, getattr(rep.fluid, f))


# ---------------------------------------------------------------------------
# Admission control under gamma bursts
# ---------------------------------------------------------------------------

def surge_cluster(policy, n=200, rate=18.0, seed=2):
    cfg = ClusterConfig(
        n_replicas=2, router="qoe", kv_capacity_tokens=10_000,
        admission=AdmissionConfig(policy=policy),
    )
    wl = make_workload(n, rate, seed=seed, arrival="gamma", cv=3.0)
    return ClusterSimulator(LAT, cfg).run(wl)


def test_admission_none_admits_everything():
    res = surge_cluster("none")
    assert len(res.shed) == 0 and res.n_defer_events == 0
    assert len(res.admitted) == 200


@pytest.mark.slow
def test_admission_shed_protects_served_qoe():
    base = surge_cluster("none")
    shed = surge_cluster("shed")
    assert len(shed.shed) > 0
    assert shed.shed_rate() < 0.5                  # degrade, don't collapse
    assert (shed.avg_qoe(include_shed=False)
            > base.avg_qoe(include_shed=False) + 0.02)
    # shed requests never received a token and count as QoE 0
    assert all(not r.emit_times for r in shed.shed)
    assert shed.avg_qoe() < shed.avg_qoe(include_shed=False)


@pytest.mark.slow
def test_admission_defer_retries_before_shedding():
    shed = surge_cluster("shed")
    defer = surge_cluster("defer")
    assert defer.n_defer_events > 0
    # retrying lets some deferred requests land instead of being dropped
    assert len(defer.shed) <= len(shed.shed)
    # every admitted request still completes
    assert all(r.generated >= r.output_len for r in defer.admitted)


def test_underload_admits_everything_regardless_of_policy():
    cfg = ClusterConfig(
        n_replicas=2, router="qoe", kv_capacity_tokens=M,
        admission=AdmissionConfig(policy="shed"),
    )
    wl = make_workload(60, 0.5, seed=1, arrival="gamma", cv=3.0)
    res = ClusterSimulator(LAT, cfg).run(wl)
    assert len(res.shed) == 0
    assert res.avg_qoe() > 0.97


# ---------------------------------------------------------------------------
# Autoscaler
# ---------------------------------------------------------------------------

def test_autoscaler_scales_up_under_surge_and_drains_back():
    cfg = ClusterConfig(
        n_replicas=1, router="qoe", kv_capacity_tokens=15_000,
        autoscaler=AutoscalerConfig(
            min_replicas=1, max_replicas=4,
            provision_delay=5.0, cooldown=10.0, window=15.0,
        ),
    )
    wl = make_workload(200, 8.0, seed=2, arrival="gamma", cv=3.0)
    res = ClusterSimulator(LAT, cfg).run(wl)
    assert res.peak_replicas > 1
    assert any(e.action == "scale_up" for e in res.scale_events)
    # drained replicas finished their in-flight work: nothing lost
    assert all(r.generated >= r.output_len for r in res.admitted)
    total = sum(len(rr.requests) for rr in res.replica_results.values())
    assert total == len(res.admitted)


def test_autoscaler_respects_max_replicas():
    cap = AutoscalerConfig(min_replicas=1, max_replicas=2,
                           provision_delay=1.0, cooldown=2.0, window=10.0)
    cfg = ClusterConfig(n_replicas=1, router="qoe", kv_capacity_tokens=8_000,
                        autoscaler=cap)
    wl = make_workload(150, 12.0, seed=3, arrival="gamma", cv=3.0)
    res = ClusterSimulator(LAT, cfg).run(wl)
    assert res.peak_replicas <= 2


def test_fixed_fleet_has_no_scale_events():
    cfg = ClusterConfig(n_replicas=2, router="jsq", kv_capacity_tokens=M)
    res = ClusterSimulator(LAT, cfg).run(make_workload(50, 2.0, seed=1))
    assert res.scale_events == []
    assert res.peak_replicas == 2


# ---------------------------------------------------------------------------
# Multi-tenant workload + fleet aggregation
# ---------------------------------------------------------------------------

def test_multitenant_workload_shapes():
    wl = make_multitenant_workload(300, 5.0, seed=0)
    assert len(wl) == 300
    tenants = {r.tenant for r in wl}
    assert tenants == set(range(len(DEFAULT_TENANTS)))
    # dominant tenant has the largest share
    counts = np.bincount([r.tenant for r in wl])
    assert int(np.argmax(counts)) == 0
    # batch tenant got its lenient fixed spec
    batch = [r for r in wl if r.tenant == 2]
    assert all(r.spec.ttft == DEFAULT_TENANTS[2].ttft for r in batch)
    arrivals = [r.arrival for r in wl]
    assert arrivals == sorted(arrivals)


def test_per_tenant_reporting():
    cfg = ClusterConfig(n_replicas=2, router="qoe", kv_capacity_tokens=M)
    res = ClusterSimulator(LAT, cfg).run(
        make_multitenant_workload(120, 3.0, seed=1))
    per = res.per_tenant_avg_qoe()
    assert set(per) <= set(range(len(DEFAULT_TENANTS)))
    assert all(0.0 <= v <= 1.0 for v in per.values())


def test_fleet_aggregation_helpers():
    a, b = np.array([1.0, 0.8]), np.array([0.6])
    assert fleet_avg_qoe([a, b]) == pytest.approx(0.8)
    assert fleet_min_qoe([a, b]) == pytest.approx(0.6)
    assert fleet_slo_attainment([a, b], threshold=0.7) == pytest.approx(2 / 3)
    # shed requests count as zeros
    assert fleet_avg_qoe([a, b], n_shed=1) == pytest.approx(0.6)
    assert fleet_min_qoe([a, b], n_shed=1) == 0.0
    assert fleet_avg_qoe([]) == 1.0


def test_predict_request_qoe_monotone_in_delay():
    spec = QoESpec(ttft=1.0, tds=4.8)
    qs = [predict_request_qoe(spec, d, rate=10.0, dt=30.0, exp_len=200)
          for d in (0.0, 2.0, 5.0, 15.0, 30.0)]
    assert qs[0] == pytest.approx(1.0, abs=1e-6)
    assert all(x >= y - 1e-9 for x, y in zip(qs, qs[1:]))
    assert qs[-1] == 0.0


@pytest.mark.parametrize("charge_overhead", [False, True])
def test_unschedulable_request_halts_instead_of_hanging(charge_overhead):
    """A prompt larger than KV capacity can never be scheduled; the
    simulator must halt (request unfinished, QoE 0), not spin forever —
    the cluster drain loop runs `while rep.step()`. With
    charge_scheduler_overhead the clock creeps by wall time each
    iteration, so the guard must key on work signals, not the clock."""
    kv = 1_000
    sched = make_scheduler("andes", kv, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(
        kv_capacity_tokens=kv, charge_scheduler_overhead=charge_overhead))
    rep = Replica(0, sim, LAT)
    rep.submit(req(0, prompt=2_000, out=50))
    rep.submit(req(1, arrival=0.1, prompt=200, out=20))
    steps = 0
    while rep.step():
        steps += 1
        assert steps < 10_000, "simulator failed to terminate"
    res = rep.result()
    by_rid = {r.rid: r for r in res.requests}
    assert by_rid[1].generated >= 20          # schedulable one completes
    assert by_rid[0].generated == 0           # impossible one gives up
    assert by_rid[0].final_qoe() == 0.0


def test_submit_after_deadlock_resumes_service():
    """A deadlock halt is transient: a later (schedulable) submit must
    un-stick the simulator — one oversized prompt must not blackhole the
    replica for every future request the router places on it."""
    rep = make_replica(0, kv=1_000)
    rep.submit(req(0, prompt=2_000, out=50))
    while rep.step():
        pass
    assert rep.backend.stuck
    rep.submit(req(1, arrival=rep.clock + 1.0, prompt=200, out=20))
    while rep.step():
        pass
    by_rid = {r.rid: r for r in rep.result().requests}
    assert by_rid[1].generated >= 20


def test_fleet_scaled_to_zero_recovers_on_arrival():
    """min_replicas=0 can drain the whole fleet during a lull; the next
    arrival must provision a replica, not crash."""
    cfg = ClusterConfig(
        n_replicas=1, router="qoe", kv_capacity_tokens=M,
        autoscaler=AutoscalerConfig(
            min_replicas=0, max_replicas=2,
            provision_delay=1.0, cooldown=5.0, window=10.0,
        ),
    )
    wl = make_workload(20, 2.0, seed=1)
    late = make_workload(5, 2.0, seed=2)
    for r in late:
        r.rid += 100
        r.arrival += 500.0        # long lull: fleet drains to zero
    res = ClusterSimulator(LAT, cfg).run(wl + late)
    assert len(res.admitted) == 25
    assert all(r.generated >= r.output_len for r in res.admitted)


def test_deferred_request_scored_with_aged_qoe_clock():
    """marginal_qoe_gain must not re-score an old (deferred) request as
    fresh: dead time on the QoE clock lowers achievable QoE."""
    rep = make_replica(0)
    cfg = RouterConfig()
    fresh = marginal_qoe_gain(rep, req(0, arrival=0.0), 0.0, cfg)
    aged = marginal_qoe_gain(rep, req(1, arrival=0.0), 10.0, cfg)
    assert aged < fresh - 0.1


def test_autoscaler_pending_provisions_cancelled_after_drain():
    """A provision still in flight when the trace ends must not material-
    ize a phantom replica that never serves anything."""
    cfg = ClusterConfig(
        n_replicas=1, router="qoe", kv_capacity_tokens=15_000,
        autoscaler=AutoscalerConfig(
            min_replicas=1, max_replicas=8,
            provision_delay=10_000.0,      # never ready during the trace
            cooldown=5.0, window=10.0,
        ),
    )
    wl = make_workload(100, 8.0, seed=2, arrival="gamma", cv=3.0)
    res = ClusterSimulator(LAT, cfg).run(wl)
    assert res.peak_replicas == 1
    assert all(rr.requests for rr in res.replica_results.values())


def test_cluster_config_rejects_empty_fleet():
    with pytest.raises(ValueError):
        ClusterSimulator(LAT, ClusterConfig(n_replicas=0))
    with pytest.raises(ValueError):
        ClusterSimulator([], ClusterConfig(n_replicas=1))


def test_draining_replica_rejects_submissions():
    rep = make_replica(0)
    rep.drain()
    with pytest.raises(RuntimeError):
        rep.submit(req(0))
    assert rep.drained        # no work -> immediately drained
